"""Serve the (FL-trained) global model: batched autoregressive decoding
with a KV cache — the deployment path the decode_32k / long_500k dry-run
shapes exercise at production scale.

Run:  PYTHONPATH=src python examples/serve_batched.py --arch qwen3-1.7b
      (uses the reduced smoke variant so it runs on CPU; on a real slice
       drop --smoke to serve the full config)
"""
import argparse
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    args, rest = ap.parse_known_args()
    sys.argv = ["serve", "--arch", args.arch, "--smoke", "--batch", "4",
                "--prompt-len", "16", "--gen", "16"] + rest
    serve_main()

"""End-to-end reproduction of the paper's §5 experiment (Fig. 1).

30 clients x 1500 samples, non-IID, LeNet backbone, buffered-async server
(K=10), heterogeneous device speeds. Runs the paper's method and all
baselines over enough server rounds to separate the curves, and writes
the comparison CSV. The client population comes from the scenario
registry — ``--scenario diurnal-phones`` (or any name from
``repro.sim.registry()``) re-runs the whole comparison under that
behavior on identical client timelines.

This is the full-scale driver (several minutes on CPU); pass --quick for
a reduced run. See benchmarks/bench_fig1_convergence.py for the harness.

Run:  PYTHONPATH=src:. python examples/paper_experiment.py [--quick]
          [--scenario paper-fig1] [--engine vectorized]
"""
import argparse

from benchmarks.bench_fig1_convergence import run
from repro.sim import registry

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--scenario", default="paper-fig1",
                    choices=sorted(registry()))
    ap.add_argument("--engine", default="vectorized",
                    choices=["vectorized", "legacy"])
    args = ap.parse_args()
    run(rounds=args.rounds, quick=args.quick, scenario=args.scenario,
        engine=args.engine)

"""Quickstart: contribution-aware async FL in ~40 lines.

Simulates 8 heterogeneous clients training LeNet on a non-IID synthetic
image dataset; compares the paper's weighting against uniform FedBuff.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import LatencyModel, run_async
from repro.data import make_federated_image_dataset
from repro.models.lenet import apply_lenet, init_lenet, lenet_loss

# 1. federated non-IID data (Dirichlet label skew) + heterogeneous speeds
clients, (x_test, y_test) = make_federated_image_dataset(
    num_clients=8, samples_per_client=300, alpha=0.25, noise=1.0, seed=0)
latency = LatencyModel.heterogeneous(8, max_slowdown=8.0, seed=0)

# 2. model + evaluation
params = init_lenet(jax.random.PRNGKey(0))
eval_jit = jax.jit(lambda p: jnp.mean(
    (jnp.argmax(apply_lenet(p, x_test[:512]), -1) == y_test[:512])
    .astype(jnp.float32)))
eval_fn = lambda p: {"acc": float(eval_jit(p))}

# 3. run the buffered-async server with both weightings
for weighting in ("paper", "fedbuff"):
    fl = FLConfig(num_clients=8, buffer_size=4, local_steps=4, local_lr=0.05,
                  batch_size=32, weighting=weighting)
    res = run_async(lenet_loss, params, clients, fl, total_rounds=20,
                    eval_fn=eval_fn, eval_every=5, latency=latency, seed=0)
    curve = " ".join(f"r{h['round']}:{h['acc']:.2f}" for h in res.history)
    print(f"{weighting:8s} | {curve} | sim_time={res.sim_time:.1f}")

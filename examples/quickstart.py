"""Quickstart: contribution-aware async FL in ~50 lines.

Simulates 8 heterogeneous clients training LeNet under a named
client-behavior scenario; compares the paper's weighting against uniform
FedBuff on identical client timelines (per-client seeded duration
streams — see DESIGN.md §4).

Pick any scenario from the registry (``python examples/quickstart.py
--list``): e.g. ``--scenario diurnal-phones`` puts the clients on a
day/night duty cycle, ``--scenario dropout-bernoulli`` loses 15% of
uploads, ``--scenario dirichlet-extreme`` gives each client ~1-2 label
classes.

Run:  PYTHONPATH=src python examples/quickstart.py [--scenario NAME]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import run_async
from repro.models.lenet import apply_lenet, init_lenet, lenet_loss
from repro.sim import get_scenario, metrics, registry

ap = argparse.ArgumentParser()
ap.add_argument("--scenario", default="paper-fig1",
                choices=sorted(registry()))
ap.add_argument("--list", action="store_true",
                help="print the scenario registry and exit")
args = ap.parse_args()
if args.list:
    for name, sc in sorted(registry().items()):
        print(f"{name:20s} {sc.description}")
    raise SystemExit(0)

# 1. a scenario bundles non-IID data (Dirichlet label skew wired to
#    data/partition.py) with client behavior (speeds, availability,
#    dropouts, network tiers)
scenario = get_scenario(args.scenario)
clients, (x_test, y_test) = scenario.make_dataset(
    num_clients=8, samples_per_client=300, seed=0)

# 2. model + evaluation
params = init_lenet(jax.random.PRNGKey(0))
eval_jit = jax.jit(lambda p: jnp.mean(
    (jnp.argmax(apply_lenet(p, x_test[:512]), -1) == y_test[:512])
    .astype(jnp.float32)))
eval_fn = lambda p: {"acc": float(eval_jit(p))}

# 3. run the buffered-async server with both weightings; same seed =>
#    identical per-client duration draws => a fair comparison
for weighting in ("paper", "fedbuff"):
    fl = FLConfig(num_clients=8, buffer_size=4, local_steps=4, local_lr=0.05,
                  batch_size=32, weighting=weighting)
    res = run_async(lenet_loss, params, clients, fl, total_rounds=20,
                    eval_fn=eval_fn, eval_every=5, scenario=scenario, seed=0)
    curve = " ".join(f"r{h['round']}:{h['acc']:.2f}" for h in res.history)
    tele = metrics.summarize(res.round_log, 8)
    print(f"{weighting:8s} | {curve} | sim_time={res.sim_time:.1f} "
          f"tau_mean={tele['tau_mean']:.2f} "
          f"gini={tele['participation_gini']:.2f}")

"""Federated LM training with the COMPILED cohort step — the production
path (core/cohort.py) on a host mesh, end to end.

Each data-parallel slot is one FL client with its own non-IID synthetic
token stream; the arrival schedule follows the heterogeneous latency model,
so staleness really occurs; the server applies eq. 3/4/5 each round.

Default is a CPU-sized decoder (~12M params). --model-dim/--layers scale it
up (e.g. --model-dim 768 --layers 12 --vocab 32768 ~ 100M params for a real
machine); --rounds controls duration.

Run:  PYTHONPATH=src python examples/train_lm_federated.py --rounds 20
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, ModelConfig
from repro.core import init_cohort_state, make_cohort_step
from repro.core.simulator import LatencyModel
from repro.data.synthetic import make_lm_token_stream
from repro.launch.train import arrival_schedule
from repro.models.model import build_model
from repro.utils import tree_count_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--buffer-k", type=int, default=3)
    ap.add_argument("--model-dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--weighting", default="paper")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="fl-lm", family="dense", num_layers=args.layers,
        d_model=args.model_dim, num_heads=max(2, args.model_dim // 64),
        num_kv_heads=max(2, args.model_dim // 128), d_ff=4 * args.model_dim,
        vocab_size=args.vocab)
    model = build_model(cfg)
    fl = FLConfig(buffer_size=args.buffer_k, local_steps=2, local_lr=5e-3,
                  weighting=args.weighting)

    params = model.init(jax.random.PRNGKey(0))
    print(f"model params: {tree_count_params(params):,}")
    state = init_cohort_state(params, args.cohort)
    step = jax.jit(make_cohort_step(model.loss, fl), donate_argnums=0)

    latency = LatencyModel.heterogeneous(args.cohort, max_slowdown=6.0, seed=0)
    sched = arrival_schedule(args.cohort, args.buffer_k, latency, args.rounds)
    sizes = jnp.asarray(np.random.default_rng(0).integers(
        500, 2000, args.cohort), jnp.float32)

    # per-client non-IID token streams (different bigram structure per slot)
    def round_batch(r):
        local, probe = [], []
        for c in range(args.cohort):
            t = make_lm_token_stream(args.vocab, args.seq,
                                     fl.local_steps * args.batch + 2,
                                     seed=1000 * c + r)
            lt = t[:fl.local_steps * args.batch].reshape(
                fl.local_steps, args.batch, -1)
            local.append(lt)
            probe.append(t[-2:])
        local = np.stack(local)  # (C, M, b, S+1)
        probe = np.stack(probe)  # (C, 2, S+1)
        return {
            "local": {"tokens": jnp.asarray(local[..., :-1]),
                      "labels": jnp.asarray(local[..., 1:])},
            "probe": {"tokens": jnp.asarray(probe[..., :-1]),
                      "labels": jnp.asarray(probe[..., 1:])},
            "arrival": jnp.asarray(sched[r]),
            "data_sizes": sizes,
        }

    for r in range(args.rounds):
        t0 = time.time()
        state, mets = step(state, round_batch(r))
        print(f"round {r + 1:3d}: probe_ce={float(mets['fresh_loss_mean']):.4f} "
              f"S_min={float(mets['staleness_min']):.3f} "
              f"arrivals={int(sched[r].sum())} ({time.time() - t0:.1f}s)")
    print("final version:", int(state.version))


if __name__ == "__main__":
    main()

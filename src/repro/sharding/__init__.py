from repro.sharding.specs import (  # noqa: F401
    batch_pspecs,
    cache_pspecs,
    cohort_state_pspecs,
    dist_state_pspecs,
    flat_param_pspec,
    flat_stacked_pspec,
    kclient_pspec,
    mesh_axis_size,
    param_pspecs,
    ring_pspec,
)

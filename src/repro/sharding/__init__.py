from repro.sharding.specs import (  # noqa: F401
    batch_pspecs,
    cache_pspecs,
    cohort_state_pspecs,
    dist_state_pspecs,
    param_pspecs,
)

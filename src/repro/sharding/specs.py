"""PartitionSpec rules for every parameter / state / batch pytree.

Sharding scheme (mesh axes: optional "pod", "data", "model"):

* Tensor parallelism over ``model``: attention QKV/O, MLP up/down, SSM
  in/out projections, MoE experts (expert-parallel on the E axis), and the
  embedding/LM head (vocab-sharded when divisible, else d-sharded).
* ``data`` carries FL cohort slots (replicated-client mode) or FSDP
  (distributed-client mode: the largest not-yet-sharded dim of each large
  weight is sharded over ``data``).
* ``pod`` is a second data-parallel tier (more cohort slots / batch).

Rules are name+shape driven over pytree key-paths; specs are padded on the
left with None for stacking axes (layer stack L, cohort stack C, expert E),
and every sharded dim is checked for divisibility — falling back to
replication rather than producing an invalid spec (the fallback is logged
via ``collect_fallbacks``).
"""
from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

MODEL_AXIS = "model"
DATA_AXIS = "data"


def _axis_size(mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return int(dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1))


def mesh_axis_size(mesh, name) -> int:
    """Size of mesh axis ``name`` (1 when absent or ``mesh`` is None)."""
    if mesh is None:
        return 1
    return _axis_size(mesh, name)


# ---------------------------------------------------------------------------
# sharded round substrate (DESIGN.md §5)
# ---------------------------------------------------------------------------
# The mesh-sharded round (core/round_body.py + core/server_pass.py) works on
# two layouts: the padded flat f32 parameter vector, partitioned over the
# ``model`` axis, and K-client stacked pytrees, partitioned over ``data``.


def flat_param_pspec() -> P:
    """(Np,) padded flat parameter vector: partitioned over ``model``."""
    return P(MODEL_AXIS)


def flat_stacked_pspec() -> P:
    """(K, Np) stacked flat bases/deltas: K replicated, Np over ``model``."""
    return P(None, MODEL_AXIS)


def ring_pspec() -> P:
    """(R, Np) flat version ring: versions replicated, Np over ``model``.

    The engine's version ring stores each of the R retained versions as a
    ``ShardedFlatSpec`` padded flat row (DESIGN.md §6), so per device the
    ring costs ``R * n_padded / model_shards`` floats instead of R full
    replicas — the layout that makes a deep ring pod-viable. Same layout
    as ``flat_stacked_pspec`` (leading axis replicated, flat dim over
    ``model``) — delegate so the two can never drift.
    """
    return flat_stacked_pspec()


def ring_codes_pspec() -> P:
    """(R, Np) int8 codewords of the compressed version ring.

    The ``int8`` codec (core/version_store.py, DESIGN.md §11) stores each
    ring row as Np codewords on the SAME flat layout as the f32 ring, so
    the codeword matrix shards exactly like it: versions replicated, the
    flat dim over ``model``. Delegates to ``flat_stacked_pspec`` so the
    compressed and identity layouts can never drift.
    """
    return flat_stacked_pspec()


def ring_scales_pspec() -> P:
    """(R, Np // qblock) per-block scale/zero arrays: blocks over ``model``.

    ``resolve_qblock`` guarantees the quantization block divides the
    per-shard tile, so the block axis partitions evenly over ``model``
    and every device holds exactly the (scale, zero) columns its codeword
    slice needs — the fused dequantize-distance kernel never reads a
    remote scale.
    """
    return flat_stacked_pspec()


def kclient_pspec() -> P:
    """(K, ...) client-stacked leaves: K over ``data``, rest replicated.

    Used as a pytree-prefix spec: trailing (unmentioned) dims replicate.
    """
    return P(DATA_AXIS)


def client_state_pspec() -> P:
    """(N,) per-client population state/statics: N over ``data``.

    The device-resident scenario engine (``sim/population.py``) keeps the
    whole client state machine as ``(N,)``-leading arrays; sharding them
    over ``data`` is what lets a process-spanning mesh materialize only
    its addressable shard of a million-client population (no host event
    walk to replay). Callers fall back to replication when N does not
    divide the data-axis size.
    """
    return P(DATA_AXIS)


def info_pspec() -> P:
    """(K,) per-round info arrays (weights, sq_dists, ...): replicated.

    This is a multi-host CONTRACT, not just a layout: the round's info
    outputs are pinned fully replicated so every process can read the
    round log from its own addressable shards (DESIGN.md §7) — the
    engine never issues a ``jax.device_get`` on a non-addressable array.
    ``core/server_pass.py`` enforces it with a sharding constraint on the
    mesh path.
    """
    return P()


def _div(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def _base_rule(name: str, path: str, shape: Tuple[int, ...], mesh,
               fsdp: bool) -> List[Optional[Any]]:
    """Spec for the TRAILING dims of a leaf (left-padding added later).

    Returns a list of axis assignments for the last ``len(spec)`` dims.
    """
    msize = _axis_size(mesh, MODEL_AXIS)
    dsize = _axis_size(mesh, DATA_AXIS)
    in_moe = ("'moe'" in path or ".moe" in path) and "'shared'" not in path
    is_expert = in_moe and name in ("w_gate", "w_up", "w_down")

    if name == "embed":
        v, d = shape[-2], shape[-1]
        if _div(v, msize * (dsize if fsdp else 1)) and fsdp:
            return [(MODEL_AXIS, DATA_AXIS), None]
        if _div(v, msize):
            return [MODEL_AXIS, DATA_AXIS if (fsdp and _div(d, dsize)) else None]
        return [None, MODEL_AXIS if _div(d, msize) else None]
    if name == "lm_head":
        d, v = shape[-2], shape[-1]
        if _div(v, msize):
            return [DATA_AXIS if (fsdp and _div(d, dsize)) else None, MODEL_AXIS]
        return [MODEL_AXIS if _div(d, msize) else None, None]
    if name == "projector":
        return [None, MODEL_AXIS if _div(shape[-1], msize) else None]
    if is_expert:
        # (E, d, ff) or (E, ff, d): expert-parallel over model
        e = shape[-3]
        return [MODEL_AXIS if _div(e, msize) else None,
                DATA_AXIS if (fsdp and _div(shape[-2], dsize)) else None,
                None]
    if name == "router":
        return [None, None]
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "dt_proj"):
        out_ok = _div(shape[-1], msize)
        in_ok = fsdp and _div(shape[-2], dsize)
        return [DATA_AXIS if in_ok else None, MODEL_AXIS if out_ok else None]
    if name in ("wo", "w_down", "out_proj", "x_proj"):
        in_ok = _div(shape[-2], msize)
        out_ok = fsdp and _div(shape[-1], dsize)
        return [MODEL_AXIS if in_ok else None, DATA_AXIS if out_ok else None]
    if name in ("bq", "bk", "bv", "b_up"):
        return [MODEL_AXIS if _div(shape[-1], msize) else None]
    if name == "conv_w":
        return [None, MODEL_AXIS if _div(shape[-1], msize) else None]
    if name in ("conv_b", "dt_bias", "D"):
        return [MODEL_AXIS if _div(shape[-1], msize) else None]
    if name == "A_log":
        return [MODEL_AXIS if _div(shape[-2], msize) else None, None]
    # norms, scalar-ish leaves: replicated
    return [None] * min(len(shape), 1)


def _leaf_spec(path, leaf, mesh, fsdp: bool, extra_leading: int = 0) -> P:
    """Build the full PartitionSpec for one leaf."""
    shape = tuple(leaf.shape)
    keys = [k.key for k in path if hasattr(k, "key")]
    name = keys[-1] if keys else ""
    pathstr = _keystr(path)
    trailing = _base_rule(name, pathstr, shape, mesh, fsdp)
    trailing = trailing[-len(shape):] if len(trailing) > len(shape) else trailing
    pad = len(shape) - len(trailing)
    spec = [None] * pad + list(trailing)
    # cohort stacking axis (client dim) handled by cohort_state_pspecs
    for _ in range(extra_leading):
        spec = [None] + spec
    return P(*spec)


def param_pspecs(params_shape: Any, mesh, fsdp: bool = False) -> Any:
    """PartitionSpec pytree matching ``params_shape`` (arrays or SDS)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [_leaf_spec(p, l, mesh, fsdp) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def cohort_state_pspecs(state_shape: Any, mesh, fsdp: bool = False,
                        client_axes=(DATA_AXIS,)) -> Any:
    """Specs for CohortState: client-stacked pytrees get the client dim
    sharded over the data(+pod) axes; global params are TP-only."""
    from repro.core.cohort import CohortState

    client_axis = client_axes if len(client_axes) > 1 else client_axes[0]

    def stacked_spec(tree):
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        specs = []
        for p, l in flat:
            inner = _leaf_spec(p, jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                               mesh, False)
            specs.append(P(client_axis, *inner))
        return jax.tree_util.tree_unflatten(treedef, specs)

    return CohortState(
        global_params=param_pspecs(state_shape.global_params, mesh, fsdp),
        client_params=stacked_spec(state_shape.client_params),
        client_base=stacked_spec(state_shape.client_base),
        client_version=P(client_axis),
        version=P(),
    )


def dist_state_pspecs(state_shape: Any, mesh) -> Any:
    """Specs for DistFLState (FSDP x TP params + same-sharded accumulator)."""
    from repro.core.cohort import DistFLState

    pspec = param_pspecs(state_shape.global_params, mesh, fsdp=True)
    return DistFLState(
        global_params=pspec,
        accum=pspec,
        v_buf=P(),
        count=P(),
        version=P(),
        update_norm_ring=P(),
    )


def batch_pspecs(batch_shape: Any, batch_axes=(DATA_AXIS,)) -> Any:
    """Shard the leading (batch/cohort) dim of every batch leaf."""
    ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def leaf(l):
        if l.ndim == 0:
            return P()
        return P(ax, *([None] * (l.ndim - 1)))

    return jax.tree.map(leaf, batch_shape)


def cache_pspecs(cache_shape: Any, mesh, batch_axes=(DATA_AXIS,),
                 batch_size: int = 0) -> Any:
    """KV/SSM cache specs: batch dim over data(+pod) when divisible, the
    head_dim / d_inner dim over model when divisible.

    Cache leaves (from init_stack_cache): leading L, then
      kv k/v : (L, B, len, Hkv, hd)   -> (None, B_ax, None, None, model)
      ssm conv: (L, B, K-1, di)       -> (None, B_ax, None, model)
      ssm h  : (L, B, di, N)          -> (None, B_ax, model, None)
      cross k/v: (L, B, S_enc, Hkv, hd) same as kv
    """
    msize = _axis_size(mesh, MODEL_AXIS)
    bsize = int(np.prod([_axis_size(mesh, a) for a in batch_axes]))
    b_ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def leaf_spec(path, l):
        keys = [k.key for k in path if hasattr(k, "key")]
        shape = l.shape
        b_ok = len(shape) >= 2 and shape[1] % bsize == 0
        bspec = b_ax if b_ok else None
        if "kv" in keys or "cross" in keys:
            hd_ok = shape[-1] % msize == 0
            return P(None, bspec, None, None, MODEL_AXIS if hd_ok else None)
        if keys[-1] == "conv":
            return P(None, bspec, None, MODEL_AXIS if shape[-1] % msize == 0 else None)
        if keys[-1] == "h":
            return P(None, bspec, MODEL_AXIS if shape[-2] % msize == 0 else None, None)
        return P(*([None] * len(shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(treedef,
                                        [leaf_spec(p, l) for p, l in flat])

"""Legacy per-event simulator + synchronous FedAvg baseline.

``run_async_legacy`` is the original event-driven heapq loop: one jitted
``local_update`` dispatch and one ``AsyncServer.receive`` per client
upload. It is kept as the semantic reference for the vectorized engine
(tests/test_sim_engine.py checks round-log parity event-for-event) and as
the baseline side of benchmarks/bench_sim_engine.py. New code should use
``repro.sim.engine.run_vectorized`` (the default behind
``repro.core.run_async``).

Both runners draw client durations from ``ClientBehavior``'s per-client
seeded streams — draw ``k`` of client ``i`` is identical no matter which
protocol consumes it, so sync-vs-async wall-clock comparisons are fair
(the seed simulator consumed one shared RNG in protocol-dependent order).

``run_sync`` supports partial participation via ``FLConfig.
clients_per_round`` (0 = all N): each round samples a uniform subset,
waits for its slowest member, and averages only those updates — the
FedAvg comparison setting the paper uses.
"""
from __future__ import annotations

import functools
import heapq
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.core.client import make_fresh_loss_fn, make_local_update_fn
from repro.core.server import AsyncServer, SyncServer
from repro.sim.base import (
    SimResult,
    make_batches,
    record_eval,
    resolve_behavior,
)
from repro.sim.scenarios import ClientBehavior, LatencyModel, Scenario
from repro.sim.traces import EventTrace


@functools.lru_cache(maxsize=64)
def _jitted_local_update(loss_fn: Callable, local_steps: int, local_lr: float,
                         momentum: float) -> Callable:
    """One jit wrapper per (loss_fn, hyperparams) so repeated runs reuse
    the compiled program instead of re-tracing every call."""
    return jax.jit(make_local_update_fn(loss_fn, local_steps, local_lr,
                                        momentum))


def run_async_legacy(loss_fn: Callable, init_params: Any, clients: Sequence,
                     fl: FLConfig, total_rounds: int,
                     eval_fn: Optional[Callable[[Any], Dict]] = None,
                     eval_every: int = 5,
                     latency: Optional[LatencyModel] = None,
                     seed: int = 0,
                     behavior: Optional[ClientBehavior] = None,
                     scenario: Optional[Scenario] = None,
                     trace: Optional[EventTrace] = None,
                     record_trace: bool = False) -> SimResult:
    """Buffered-async FL, one dispatch per client event (the reference)."""
    n = len(clients)
    beh = resolve_behavior(n, seed, behavior, scenario, latency, trace)
    local_update = _jitted_local_update(loss_fn, fl.local_steps, fl.local_lr,
                                        fl.local_momentum)
    server = AsyncServer(init_params, fl, make_fresh_loss_fn(loss_fn))

    # every client starts training at t=0 (availability-gated) from version 0
    base_version = {i: 0 for i in range(n)}
    events = []
    for cid in range(n):
        start = beh.next_start(cid, 0.0)
        events.append((start + beh.duration(cid, start), cid))
    heapq.heapify(events)
    history: List[Dict] = []
    event_log: List = []
    now = 0.0
    num_events = 0

    def maybe_eval(force=False):
        record_eval(history, eval_fn, server.version, now, server.params,
                    eval_every, force)

    def reschedule(cid, t):
        start = beh.next_start(cid, t)
        heapq.heappush(events, (start + beh.duration(cid, start), cid))

    maybe_eval(force=True)
    while server.version < total_rounds:
        now, cid = heapq.heappop(events)
        num_events += 1
        upload_idx, lost = beh.next_upload(cid)
        if lost:  # upload lost: re-pull current model, retrain
            base_version[cid] = server.version
            reschedule(cid, now)
            continue
        ds = clients[cid]
        bx, by = make_batches(ds, fl.batch_size, fl.local_steps)
        base = server.history.get(base_version[cid])
        if base is None:  # fell out of the ring: resync (modelled as re-pull)
            base = server.params
            base_version[cid] = server.version
        event_log.append((now, cid, upload_idx, server.version))
        delta, _ = local_update(base, (bx, by))
        fresh = (lambda d=ds: d.batch(fl.batch_size))
        advanced = server.receive(cid, delta, base_version[cid], ds.size,
                                  fresh_batch_fn=fresh)
        # client immediately pulls the newest model and restarts (async)
        base_version[cid] = server.version
        reschedule(cid, now)
        if advanced:
            maybe_eval()
    maybe_eval(force=True)
    trace_out = (EventTrace.from_behavior(beh, event_log)
                 if record_trace else None)
    return SimResult(history=history, server_rounds=server.version,
                     sim_time=now, round_log=server.round_log,
                     num_events=num_events, trace=trace_out)


def run_sync(loss_fn: Callable, init_params: Any, clients: Sequence,
             fl: FLConfig, total_rounds: int,
             eval_fn: Optional[Callable[[Any], Dict]] = None,
             eval_every: int = 5,
             latency: Optional[LatencyModel] = None,
             seed: int = 0,
             behavior: Optional[ClientBehavior] = None,
             scenario: Optional[Scenario] = None,
             trace: Optional[EventTrace] = None) -> SimResult:
    """Synchronous FedAvg: each round samples ``fl.clients_per_round``
    clients (0 = all N) and waits for the slowest of them — the straggler
    cost the paper's Problem statement describes."""
    n = len(clients)
    beh = resolve_behavior(n, seed, behavior, scenario, latency, trace)
    m = min(fl.clients_per_round, n) if fl.clients_per_round else n
    sel_rng = np.random.default_rng(np.random.SeedSequence((seed, 909)))
    local_update = _jitted_local_update(loss_fn, fl.local_steps, fl.local_lr,
                                        fl.local_momentum)
    server = SyncServer(init_params, fl)
    history: List[Dict] = []
    now = 0.0
    for _ in range(total_rounds):
        sel = (np.sort(sel_rng.choice(n, size=m, replace=False))
               if m < n else np.arange(n))
        durations = [beh.duration(int(cid), now) for cid in sel]
        now += max(durations)  # wait for the slowest selected straggler
        deltas = []
        for cid in sel:
            bx, by = make_batches(clients[cid], fl.batch_size, fl.local_steps)
            d, _ = local_update(server.params, (bx, by))
            deltas.append(d)
        server.round(deltas, [clients[cid].size for cid in sel])
        if eval_fn and server.version % eval_every == 0:
            history.append({"round": server.version, "time": now,
                            **eval_fn(server.params)})
    if eval_fn:
        history.append({"round": server.version, "time": now,
                        **eval_fn(server.params)})
    return SimResult(history=history, server_rounds=server.version,
                     sim_time=now, round_log=[],
                     num_events=int(total_rounds * m))

"""Record / replay of simulation timelines (DESIGN.md §4).

A client's upload timeline is independent of the server protocol: each
client trains, uploads after a sampled duration, immediately re-pulls and
repeats — so upload ``k`` of client ``i`` lands at the same sim-time no
matter the buffer size K or weighting policy. An ``EventTrace`` therefore
only needs the per-client *duration draws* (in consumption order) and the
*dropped upload indices*; replaying those through ``ClientBehavior`` puts
paper / FedBuff / FedAsync / sync FedAvg on byte-identical client
timelines, which is the precondition for a fair wall-clock comparison.

Format (JSON, versioned):

    {"version": 1, "num_clients": N, "seed": s, "scenario": "name",
     "durations": [[d_00, d_01, ...], ...],   # per client, draw order
     "drops": [[cid, k], ...],                 # uploads that were lost
     "events": [[t, cid, k, round], ...]}      # optional upload log

``events`` is a human-readable upload log the engine appends for
debugging/plotting; replay only consumes ``durations`` + ``drops``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

from repro.sim.scenarios import ClientBehavior, Scenario

TRACE_VERSION = 1


@dataclasses.dataclass
class EventTrace:
    num_clients: int
    seed: int
    scenario: str
    durations: List[List[float]]  # per-client draws, consumption order
    drops: List[Tuple[int, int]]  # (client, upload index) lost uploads
    events: List[Tuple[float, int, int, int]]  # (t, cid, k, server_round)

    # ------------------------------------------------------------------
    @staticmethod
    def from_behavior(behavior: ClientBehavior,
                      events: Optional[List[Tuple[float, int, int, int]]] = None
                      ) -> "EventTrace":
        log = behavior.drain_log()
        return EventTrace(num_clients=behavior.num_clients,
                          seed=behavior.seed,
                          scenario=behavior.scenario.name,
                          durations=log["durations"],
                          drops=[tuple(d) for d in log["drops"]],
                          events=list(events or []))

    def replay_behavior(self, scenario: Scenario) -> ClientBehavior:
        """A ``ClientBehavior`` that re-issues this trace's draws verbatim.

        ``scenario`` supplies the deterministic parts (availability gating);
        durations and drops come from the trace, so protocols compared on
        the returned behavior see identical client timelines.
        """
        b = ClientBehavior(scenario, self.num_clients, self.seed)
        b._replay_dur = [list(d) for d in self.durations]
        b._replay_drops = frozenset(tuple(d) for d in self.drops)
        return b

    # ------------------------------------------------------------------
    def to_json(self) -> Dict:
        return {"version": TRACE_VERSION, "num_clients": self.num_clients,
                "seed": self.seed, "scenario": self.scenario,
                "durations": self.durations,
                "drops": [list(d) for d in self.drops],
                "events": [list(e) for e in self.events]}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path

    @staticmethod
    def load(path: str) -> "EventTrace":
        with open(path) as f:
            obj = json.load(f)
        if obj.get("version") != TRACE_VERSION:
            raise ValueError(f"unsupported trace version {obj.get('version')!r}")
        return EventTrace(
            num_clients=int(obj["num_clients"]), seed=int(obj["seed"]),
            scenario=str(obj["scenario"]),
            durations=[[float(x) for x in d] for d in obj["durations"]],
            drops=[(int(c), int(k)) for c, k in obj["drops"]],
            events=[(float(t), int(c), int(k), int(r))
                    for t, c, k, r in obj["events"]])

"""Per-round simulation telemetry (DESIGN.md §4).

Pure-numpy summaries computed from a run's ``round_log`` (the engine and
the legacy loop emit the same schema, so these work on either). Three
views the paper's analysis needs:

* **staleness** — how stale the buffered updates actually were (τ in
  rounds and the eq.-3 degree S∈(0,1]);
* **participation** — which clients actually reach the buffer (fast
  devices dominate async FL; the Gini coefficient quantifies it);
* **weight entropy** — how concentrated each round's aggregation weights
  are (uniform FedBuff is log2(K) bits; contribution-aware weighting
  spends bits to discount stale/unhelpful updates).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


def weight_entropy(weights: Sequence[float]) -> float:
    """Shannon entropy (bits) of one round's normalised |weights|."""
    w = np.abs(np.asarray(weights, np.float64))
    tot = w.sum()
    if tot <= 0:
        return 0.0
    p = w / tot
    p = p[p > 0]
    return float(-(p * np.log2(p)).sum())


def participation_counts(round_log: List[Dict], num_clients: int) -> np.ndarray:
    """(N,) how many buffered updates each client contributed."""
    counts = np.zeros(num_clients, np.int64)
    for log in round_log:
        for cid in log["clients"]:
            counts[cid] += 1
    return counts


def gini(values: np.ndarray) -> float:
    """Gini coefficient in [0, 1): 0 = perfectly even participation."""
    v = np.sort(np.asarray(values, np.float64))
    n = v.size
    if n == 0 or v.sum() == 0:
        return 0.0
    cum = np.cumsum(v)
    return float((n + 1 - 2 * (cum / cum[-1]).sum()) / n)


def per_round(round_log: List[Dict]) -> List[Dict]:
    """One telemetry dict per server round."""
    out = []
    for log in round_log:
        taus = np.asarray(log["tau"], np.float64)
        s = np.asarray(log["staleness_deg"], np.float64)
        out.append({
            "version": log["version"],
            "tau_mean": float(taus.mean()),
            "tau_max": float(taus.max()),
            "staleness_deg_min": float(s.min()),
            "staleness_deg_mean": float(s.mean()),
            "weight_entropy": weight_entropy(log["weights"]),
            "unique_clients": len(set(log["clients"])),
        })
    return out


def summarize(round_log: List[Dict], num_clients: int) -> Dict:
    """Whole-run roll-up of the per-round telemetry."""
    if not round_log:
        return {"rounds": 0}
    rows = per_round(round_log)
    counts = participation_counts(round_log, num_clients)
    ks = np.asarray([len(log["weights"]) for log in round_log], np.float64)
    return {
        "rounds": len(rows),
        "tau_mean": float(np.mean([r["tau_mean"] for r in rows])),
        "tau_max": int(max(r["tau_max"] for r in rows)),
        "staleness_deg_mean": float(np.mean(
            [r["staleness_deg_mean"] for r in rows])),
        "weight_entropy_mean": float(np.mean(
            [r["weight_entropy"] for r in rows])),
        "weight_entropy_uniform": float(np.log2(max(ks.max(), 1.0))),
        "participation_gini": gini(counts),
        "clients_never_heard": int((counts == 0).sum()),
        "uploads_per_client_mean": float(counts.mean()),
    }

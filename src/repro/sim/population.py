"""Device-resident population engine: million-client scenarios (§10).

``run_vectorized`` keeps every client's state on the host — one NumPy
PCG64 generator pair per client, a Python heapq event loop, and (on a
process-spanning mesh) every process replaying the same host event walk.
That caps N at thousands: building 1e6 generators costs seconds and
gigabytes before the first round runs, and each K-upload window costs
O(K) heap pops plus per-event RNG calls on the host.

This module moves the whole scenario state machine onto the device:

* **Counter-based RNG.** Every stochastic draw of client ``cid`` is a
  pure function of ``(seed, stream, cid, k)`` via
  ``jax.random.fold_in(fold_in(stream_key, cid), k)`` — no mutable
  generator state, so draws are random-access and the *order* the engine
  consumes them in is irrelevant. The per-client state that remains is
  just the draw counters, packed as plain ``(N,)`` int32 arrays
  (retiring the ``utils/rngstate.py`` PCG64 pack on this path).

* **Vmapped behavior kernel.** Availability gating, duration draws,
  Bernoulli/trace dropouts and straggler-burst multipliers evaluate as
  one vmapped kernel over the ``(N,)``-leading ``PopState`` array pytree
  (FLGo-style state machine — start/complete/drop/reschedule — preserved
  as arrays), sharded over the mesh's ``data`` axis
  (``sharding/specs.client_state_pspec``). On a process-spanning mesh
  the state init runs under ``out_shardings``, so each process only
  materializes its addressable shard — no host event walk to replay.

* **Device top-k window selection.** A window is the K lexicographically
  smallest ``(t, cid)`` *accepted* uploads. Each client's next accepted
  upload time is computed by a vmapped drop-chain walk (``_peek``), then
  ``jax.lax.top_k`` picks the window (XLA top-k is stable, so time ties
  resolve to the lower cid exactly like the host heap). A re-entry check
  (can a selected client's *next* accept land back inside this window?)
  guards the top-k fast path; when it trips — only plausible at small
  N/K ratios — a ``lax.while_loop`` replica of the host event loop runs
  the window exactly. Either way the window feeds straight into the
  shared ``core/round_body.py`` ring round, and a whole
  ``rounds_per_launch`` chunk of windows + training rounds compiles to
  ONE fused ``lax.scan`` — **zero host syncs per window**, O(1) syncs
  per eval/run regardless of K (the engine's host walk costs O(K) heap
  pops + RNG calls per window).

Event-for-event parity with the host walk is the contract, pinned at
small N by tests/test_population.py: ``CounterBehavior`` /
``CounterDataset`` are host twins that consume the SAME counter streams
through the same jitted scalar kernels, so ``run_vectorized`` driven by
them reproduces this engine's event sequence (and round log) exactly.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.round_body import make_ring_round
from repro.core.version_store import ring_state_to_host
from repro.data.synthetic import ClientDataset
from repro.launch.multihost import (
    fetch_replicated,
    mesh_spans_processes,
    put_replicated,
    put_with_sharding,
)
from repro.obs.metrics import MetricsRegistry, default_registry
from repro.obs.trace import (
    NULL_TRACER,
    SPAN_APPLY,
    SPAN_CHECKPOINT,
    SPAN_HOST_SYNC,
    Tracer,
)
from repro.sharding.specs import DATA_AXIS, client_state_pspec, mesh_axis_size
from repro.sim.base import (
    SimResult,
    history_from_arrays,
    history_to_arrays,
    record_eval,
    round_log_from_arrays,
    round_log_rows,
    round_log_to_arrays,
)
from repro.sim.engine import init_version_ring
from repro.sim.scenarios import ClientBehavior, Scenario

P = jax.sharding.PartitionSpec

# stream tags: every draw is fold_in(fold_in(PRNGKey(seed) ^ tag, cid), k)
_TAG_DUR = 101     # lognormal duration draws      (mirrors SeedSequence 101)
_TAG_DROP = 202    # Bernoulli dropout draws       (mirrors SeedSequence 202)
_TAG_TRAIN = 303   # local-step batch index draws
_TAG_PROBE = 304   # eq.-4 probe batch index draws
_TAG_TIER = 401    # static: compute tier assignment
_TAG_SPREAD = 402  # static: log-uniform in-tier spread
_TAG_COMM = 403    # static: comm tier assignment
_TAG_PHASE = 404   # static: diurnal phase offset


@functools.lru_cache(maxsize=None)
def _stream_key(seed: int, tag: int):
    return jax.random.fold_in(jax.random.PRNGKey(seed), tag)


class PopStatics(NamedTuple):
    """Immutable per-client population statics, ``(N,)`` f32 each."""

    speed: Any  # multiplicative slowness (sorted, like ClientBehavior)
    comm: Any   # additive upload latency
    phase: Any  # diurnal phase offset


class PopState(NamedTuple):
    """The whole mutable scenario state machine, as arrays.

    ``(N,)`` leading per-client fields plus three scalars — this IS the
    checkpoint payload (plain arrays; no PCG64 state to pack):

    * ``t_next``  f32: completion time of the client's pending attempt
    * ``k_next``  i32: upload-attempt index of that pending attempt
                  (doubles as the duration-draw counter: attempt k's
                  duration is draw k of the ``_TAG_DUR`` stream, and the
                  drop verdict is draw k of ``_TAG_DROP``)
    * ``batch_k`` i32: train-batch draw counter (advances ``local_steps``
                  per accepted upload; probe draws live on their own
                  stream indexed by the accept count ``batch_k // M``)
    * ``base_version`` i32: version of the model the client trains from
    """

    t_next: Any
    k_next: Any
    batch_k: Any
    base_version: Any
    version: Any     # () i32 server version
    now: Any         # () f32 sim time of the last aggregation
    num_events: Any  # () i32 uploads processed (incl. dropped)


class _BehaviorFns(NamedTuple):
    """Pure counter-based scalar draw kernels (vmappable)."""

    gate: Callable      # (phase, t) -> earliest start >= t
    duration: Callable  # (cid, k, t, speed, comm) -> f32 train+upload time
    dropped: Callable   # (cid, k) -> bool upload-k lost
    has_drops: bool


@functools.lru_cache(maxsize=64)
def make_behavior_fns(sc: Scenario, seed: int) -> _BehaviorFns:
    """The scenario's stochastic pieces as pure functions of counters.

    Same semantics as ``ClientBehavior`` (diurnal gate, lognormal
    durations with burst multipliers, trace-then-Bernoulli drops), with
    the PCG64 streams replaced by threefry counter draws.
    """
    log_mean = float(math.log(sc.base_mean))
    k_dur = _stream_key(seed, _TAG_DUR)
    k_drop = _stream_key(seed, _TAG_DROP)
    period = np.float32(sc.diurnal_period)
    on = np.float32(sc.diurnal_duty * sc.diurnal_period)
    has_drops = sc.dropout_p > 0.0 or bool(sc.dropout_trace)
    trace_c = jnp.asarray([c for c, _ in sc.dropout_trace], jnp.int32)
    trace_k = jnp.asarray([k for _, k in sc.dropout_trace], jnp.int32)

    def gate(phase, t):
        if not sc.diurnal:
            return t
        local = jnp.mod(t - phase, period)
        return jnp.where(local < on, t, t + (period - local))

    def _burst_mult(cid, t):
        if sc.burst_every <= 0.0:
            return jnp.float32(1.0)
        be = np.float32(sc.burst_every)
        j = jnp.floor(t / be).astype(jnp.int32)  # burst index
        in_burst = jnp.mod(t, be) < np.float32(sc.burst_len)
        stride = max(1, int(round(1.0 / max(sc.burst_frac, 1e-9))))
        hit = jnp.mod(cid + j, stride) == 0
        return jnp.where(in_burst & hit, np.float32(sc.burst_factor),
                         jnp.float32(1.0))

    def duration(cid, k, t, speed, comm):
        key = jax.random.fold_in(jax.random.fold_in(k_dur, cid), k)
        z = jax.random.normal(key, (), jnp.float32)
        draw = jnp.exp(np.float32(log_mean) + np.float32(sc.sigma) * z)
        return (speed * draw * _burst_mult(cid, t) + comm).astype(jnp.float32)

    def dropped(cid, k):
        if not has_drops:
            return jnp.bool_(False)
        hit = jnp.bool_(False)
        if sc.dropout_trace:
            hit = jnp.any((trace_c == cid) & (trace_k == k))
        if sc.dropout_p > 0.0:
            key = jax.random.fold_in(jax.random.fold_in(k_drop, cid), k)
            u = jax.random.uniform(key, (), jnp.float32)
            hit = hit | (u < np.float32(sc.dropout_p))
        return hit

    return _BehaviorFns(gate=gate, duration=duration, dropped=dropped,
                        has_drops=has_drops)


def _n_pspec(mesh, n: int):
    """Spec for ``(N,)`` client arrays: ``P(data)`` when it divides."""
    if mesh is None:
        return P()
    d = mesh_axis_size(mesh, DATA_AXIS)
    return client_state_pspec() if d > 1 and n % d == 0 else P()


def _shardings(mesh, pspec_tree):
    return jax.tree.map(
        lambda p: jax.sharding.NamedSharding(mesh, p), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


@functools.lru_cache(maxsize=16)
def _make_statics_fn(sc: Scenario, n: int, seed: int,
                     mesh: Optional[Any]) -> Callable:
    """Jitted statics init; per-client draws are counter-based, so with a
    mesh the ``out_shardings`` partitioning makes every process compute
    only its addressable ``data``-axis shard (no replayed host init)."""
    tiers = jnp.asarray(sc.compute_tiers, jnp.float32)
    comms = jnp.asarray(sc.comm_tiers, jnp.float32)
    log_slow = np.float32(math.log(max(sc.max_slowdown, 1.0 + 1e-9)))
    k_tier = _stream_key(seed, _TAG_TIER)
    k_spread = _stream_key(seed, _TAG_SPREAD)
    k_comm = _stream_key(seed, _TAG_COMM)
    k_phase = _stream_key(seed, _TAG_PHASE)

    def init() -> PopStatics:
        def per_client(cid):
            tier = jax.random.randint(jax.random.fold_in(k_tier, cid), (),
                                      0, tiers.shape[0])
            spread = jnp.exp(jax.random.uniform(
                jax.random.fold_in(k_spread, cid), (), jnp.float32,
                0.0, log_slow))
            comm = comms[jax.random.randint(jax.random.fold_in(k_comm, cid),
                                            (), 0, comms.shape[0])]
            phase = jax.random.uniform(
                jax.random.fold_in(k_phase, cid), (), jnp.float32,
                0.0, np.float32(sc.diurnal_period))
            return tiers[tier] * spread, comm, phase
        speed, comm, phase = jax.vmap(per_client)(
            jnp.arange(n, dtype=jnp.int32))
        # sorted like ClientBehavior: speed rank decorrelated from cid
        return PopStatics(speed=jnp.sort(speed), comm=comm, phase=phase)

    if mesh is None:
        return jax.jit(init)
    pspec = _n_pspec(mesh, n)
    out = _shardings(mesh, PopStatics(speed=pspec, comm=pspec, phase=pspec))
    return jax.jit(init, out_shardings=out)


@functools.lru_cache(maxsize=16)
def _make_init_state_fn(sc: Scenario, n: int, seed: int,
                        mesh: Optional[Any]) -> Callable:
    """Jitted initial PopState: every client starts training at t=0
    (availability-gated) from version 0, duration draw 0."""
    fns = make_behavior_fns(sc, seed)

    def init(statics: PopStatics) -> PopState:
        cids = jnp.arange(n, dtype=jnp.int32)
        start = jax.vmap(fns.gate)(statics.phase, jnp.zeros(n, jnp.float32))
        dur = jax.vmap(fns.duration)(cids, jnp.zeros(n, jnp.int32), start,
                                     statics.speed, statics.comm)
        zi = jnp.zeros(n, jnp.int32)
        return PopState(t_next=start + dur, k_next=zi, batch_k=zi,
                        base_version=zi, version=jnp.int32(0),
                        now=jnp.float32(0.0), num_events=jnp.int32(0))

    if mesh is None:
        return jax.jit(init)
    pspec = _n_pspec(mesh, n)
    out = PopState(t_next=pspec, k_next=pspec, batch_k=pspec,
                   base_version=pspec, version=P(), now=P(), num_events=P())
    return jax.jit(init, out_shardings=_shardings(mesh, out))


@functools.lru_cache(maxsize=32)
def make_window_step(sc: Scenario, fl: FLConfig, n: int, seed: int,
                     mesh: Optional[Any] = None) -> Callable:
    """One K-upload window as a pure device function.

    ``window_step(statics, state) -> (new_state, win)`` where ``win``
    holds the (K,) window arrays in host-heap order — ``cids``, ``taus``
    f32, ``slots`` (ring rows), ``bk0`` (pre-advance train-batch
    counters) and ``t`` (upload times). Semantics match
    ``run_vectorized``'s ``collect_window`` + trigger bookkeeping
    event-for-event (see module docstring for the fast/exact split).
    """
    fns = make_behavior_fns(sc, seed)
    k = fl.buffer_size
    ring_depth = fl.max_staleness + 1
    max_stal = fl.max_staleness
    m = fl.local_steps
    cids_all = jnp.arange(n, dtype=jnp.int32)
    force_exact = k > n

    def _peek(cid, t, ki, speed, comm, phase):
        """Follow the pending drop chain to the next ACCEPTED upload:
        (t_accept, k_accept, drops consumed on the way). Pure — counter
        draws are random-access, so peeking never perturbs state."""
        if not fns.has_drops:
            return t, ki, jnp.int32(0)

        def cond(c):
            return fns.dropped(cid, c[1])

        def body(c):
            t_, k_, nd = c
            s = fns.gate(phase, t_)
            return (s + fns.duration(cid, k_ + 1, s, speed, comm),
                    k_ + 1, nd + 1)

        return jax.lax.while_loop(cond, body, (t, ki, jnp.int32(0)))

    def _resched(cid, t, k_new, speed, comm, phase):
        s = fns.gate(phase, t)
        return s + fns.duration(cid, k_new, s, speed, comm)

    def _exact(st: PopState, statics: PopStatics):
        """The host event loop, verbatim, as a lax.while_loop: pop the
        lexicographically smallest (t, cid) pending event until K
        uploads are accepted. O(K + drops) iterations with an O(N)
        argmin each — the correctness fallback for re-entry windows."""
        v = st.version
        zf = jnp.zeros(k, jnp.float32)
        zi = jnp.zeros(k, jnp.int32)

        def cond(c):
            return c[5] < k

        def body(c):
            (t_next, k_next, batch_k, bv, nev, count,
             w_c, w_tau, w_slot, w_bk, w_t) = c
            i = jnp.argmin(t_next).astype(jnp.int32)  # first min: lowest cid
            t = t_next[i]
            ki = k_next[i]
            drop = fns.dropped(i, ki)
            t_new = _resched(i, t, ki + 1, statics.speed[i], statics.comm[i],
                             statics.phase[i])
            t_next = t_next.at[i].set(t_new)
            k_next = k_next.at[i].set(ki + 1)
            bvi = bv[i]
            bvi = jnp.where(bvi < v - max_stal, v, bvi)  # ring resync
            acc = ~drop
            idx = count  # the window slot this accept (if any) fills
            w_c = w_c.at[idx].set(jnp.where(acc, i, w_c[idx]))
            w_tau = w_tau.at[idx].set(
                jnp.where(acc, (v - bvi).astype(jnp.float32), w_tau[idx]))
            w_slot = w_slot.at[idx].set(
                jnp.where(acc, jnp.mod(bvi, ring_depth), w_slot[idx]))
            w_bk = w_bk.at[idx].set(jnp.where(acc, batch_k[i], w_bk[idx]))
            w_t = w_t.at[idx].set(jnp.where(acc, t, w_t[idx]))
            batch_k = batch_k.at[i].add(jnp.where(acc, m, 0))
            bv = bv.at[i].set(v)  # drop AND non-trigger accept re-pull v
            return (t_next, k_next, batch_k, bv, nev + 1,
                    count + acc.astype(jnp.int32),
                    w_c, w_tau, w_slot, w_bk, w_t)

        (t_next, k_next, batch_k, bv, nev, _,
         w_c, w_tau, w_slot, w_bk, w_t) = jax.lax.while_loop(
            cond, body,
            (st.t_next, st.k_next, st.batch_k, st.base_version,
             st.num_events, jnp.int32(0), zi, zf, zi, zi, zf))
        trig = w_c[k - 1]
        bv = bv.at[trig].set(v + 1)  # the K-th upload pulls the NEW version
        new_st = PopState(t_next=t_next, k_next=k_next, batch_k=batch_k,
                          base_version=bv, version=v + 1, now=w_t[k - 1],
                          num_events=nev)
        return new_st, {"cids": w_c, "taus": w_tau, "slots": w_slot,
                        "bk0": w_bk, "t": w_t}

    def window_step(statics: PopStatics, st: PopState):
        if force_exact:
            return _exact(st, statics)
        v = st.version
        t_acc, k_acc, nd_pre = jax.vmap(_peek)(
            cids_all, st.t_next, st.k_next, statics.speed, statics.comm,
            statics.phase)
        # K smallest accepted times; XLA top-k is stable, so equal times
        # select ascending cid — the host heap's (t, cid) order
        neg, sel = jax.lax.top_k(-t_acc, k)
        # the barrier keeps TopK a custom call: fusing the t_w/trig
        # scalar slices below into it makes XLA CPU re-lower the whole
        # thing as a full O(N log N) sort per window (~30 ms at N=1e5)
        t_sel, sel = jax.lax.optimization_barrier((-neg, sel))
        t_w = t_sel[k - 1]
        trig = sel[k - 1]
        # staleness bookkeeping, host order: an in-window drop re-pulled
        # v first; then the ring resync check
        bv = st.base_version[sel]
        bv = jnp.where(nd_pre[sel] > 0, v, bv)
        bv = jnp.where(bv < v - max_stal, v, bv)
        taus = (v - bv).astype(jnp.float32)
        slots = jnp.mod(bv, ring_depth).astype(jnp.int32)
        bk0 = st.batch_k[sel]
        sp_s = statics.speed[sel]
        cm_s = statics.comm[sel]
        ph_s = statics.phase[sel]
        # post-accept reschedule, then the re-entry check: if any selected
        # client's NEXT accepted upload lands lexicographically before the
        # trigger event, the host walk would have put it IN this window —
        # the top-k of first-accepts is wrong, take the exact path
        t_re = jax.vmap(_resched)(sel, t_sel, k_acc[sel] + 1, sp_s, cm_s,
                                  ph_s)
        t_acc2, _, _ = jax.vmap(_peek)(sel, t_re, k_acc[sel] + 1, sp_s,
                                       cm_s, ph_s)
        reenter = jnp.any((t_acc2 < t_w) | ((t_acc2 == t_w) & (sel < trig)))

        def fast(_):
            t_next = st.t_next.at[sel].set(t_re)
            k_next = st.k_next.at[sel].set(k_acc[sel] + 1)
            batch_k = st.batch_k.at[sel].add(m)
            base_version = st.base_version.at[sel].set(v)
            nev = st.num_events + k + jnp.sum(nd_pre[sel])
            if fns.has_drops:
                # consume every remaining drop with event order <= the
                # trigger (the host walk popped those this window)
                def consume(cid, t, ki, speed, comm, phase):
                    def cond(c):
                        t_, k_, nd = c
                        before = (t_ < t_w) | ((t_ == t_w) & (cid < trig))
                        return before & fns.dropped(cid, k_)

                    def body(c):
                        t_, k_, nd = c
                        s = fns.gate(phase, t_)
                        return (s + fns.duration(cid, k_ + 1, s, speed,
                                                 comm), k_ + 1, nd + 1)

                    return jax.lax.while_loop(cond, body,
                                              (t, ki, jnp.int32(0)))

                t_next, k_next, nd_post = jax.vmap(consume)(
                    cids_all, t_next, k_next, statics.speed, statics.comm,
                    statics.phase)
                base_version = jnp.where(nd_post > 0, v, base_version)
                nev = nev + jnp.sum(nd_post)
            base_version = base_version.at[trig].set(v + 1)
            new_st = PopState(t_next=t_next, k_next=k_next, batch_k=batch_k,
                              base_version=base_version, version=v + 1,
                              now=t_w, num_events=nev)
            return new_st, {"cids": sel, "taus": taus, "slots": slots,
                            "bk0": bk0, "t": t_sel}

        return jax.lax.cond(reenter, lambda _: _exact(st, statics), fast,
                            None)

    return window_step


# ---------------------------------------------------------------------------
# device data pool
# ---------------------------------------------------------------------------


class DevicePool(NamedTuple):
    """All clients' samples as one device-resident pool.

    ``x``/``y`` are the concatenated sample arrays; client ``cid`` owns
    rows ``[offsets[cid], offsets[cid] + sizes[cid])``. Batch indices are
    counter draws (``_TAG_TRAIN``/``_TAG_PROBE``), so the pool gather for
    a whole window is one fused op inside the round scan. ``shared``
    overlaps client slices on a small pool — the layout that keeps a
    1e6-client sweep in flat host memory.
    """

    x: Any        # (P, ...) features
    y: Any        # (P,) labels
    offsets: Any  # (N,) i32 first row per client
    sizes: Any    # (N,) i32 rows per client

    @property
    def num_clients(self) -> int:
        return int(self.offsets.shape[0])

    @staticmethod
    def from_clients(clients: Sequence[ClientDataset]) -> "DevicePool":
        """Concatenate per-client datasets (the small-N parity path)."""
        sizes = np.asarray([c.size for c in clients], np.int32)
        offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int32)
        return DevicePool(
            x=np.concatenate([np.asarray(c.x) for c in clients]),
            y=np.concatenate([np.asarray(c.y) for c in clients]),
            offsets=offsets, sizes=sizes)

    @staticmethod
    def shared(x: np.ndarray, y: np.ndarray, num_clients: int,
               samples_per_client: int) -> "DevicePool":
        """N overlapping client slices over one fixed pool: O(pool) memory
        independent of N (a prime-stride walk decorrelates neighbors)."""
        total = int(np.asarray(x).shape[0])
        if samples_per_client > total:
            raise ValueError(f"samples_per_client {samples_per_client} "
                             f"exceeds pool size {total}")
        span = total - samples_per_client + 1
        offsets = (np.arange(num_clients, dtype=np.int64) * 7919) % span
        return DevicePool(x=x, y=y, offsets=offsets.astype(np.int32),
                          sizes=np.full(num_clients, samples_per_client,
                                        np.int32))


# ---------------------------------------------------------------------------
# fused chunk: S x (window kernel -> pool gather -> ring round)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _make_pop_chunk(loss_fn: Callable, fl: FLConfig, sc: Scenario, n: int,
                    s: int, seed: int, mesh: Optional[Any]) -> Callable:
    """Compile S whole server rounds — window selection, batch gather,
    K local trainings, eq. 3/4/5 — into ONE jitted ``lax.scan``. Unlike
    the host-walk engine there is no per-window host work at all: the
    event machine advances on device inside the scan carry."""
    window_step = make_window_step(sc, fl, n, seed, mesh)
    ring_round = make_ring_round(loss_fn, fl, mesh=mesh)
    b = fl.batch_size
    m = fl.local_steps
    ring_depth = fl.max_staleness + 1
    k_train = _stream_key(seed, _TAG_TRAIN)
    k_probe = _stream_key(seed, _TAG_PROBE)
    rep = (jax.sharding.NamedSharding(mesh, P())
           if mesh is not None else None)

    def draw_indices(cid, bk0, offset, size):
        """(M, B) train + (B,) probe pool rows for one accepted upload.

        Train draws are counters ``bk0 .. bk0+M-1`` of the train stream;
        the probe is draw ``bk0 // M`` (== the accept count) of its own
        stream, so the interleaving order host twins consume draws in
        cannot shift either stream."""
        kc_t = jax.random.fold_in(k_train, cid)

        def one_train(j):
            return offset + jax.random.randint(
                jax.random.fold_in(kc_t, bk0 + j), (b,), 0, size)

        idx_t = jax.vmap(one_train)(jnp.arange(m, dtype=jnp.int32))
        kp = jax.random.fold_in(jax.random.fold_in(k_probe, cid),
                                bk0 // m)
        idx_p = offset + jax.random.randint(kp, (b,), 0, size)
        return idx_t, idx_p

    @jax.jit
    def chunk(params, ring, state, statics, pool_x, pool_y, offsets, sizes):
        def one_round(carry, _):
            params, ring, st = carry
            st, win = window_step(statics, st)
            cids = win["cids"]
            idx_t, idx_p = jax.vmap(draw_indices)(
                cids, win["bk0"], offsets[cids], sizes[cids])
            batch = (pool_x[idx_t], pool_y[idx_t])
            probe = (pool_x[idx_p], pool_y[idx_p])
            dsz = sizes[cids].astype(jnp.float32)
            new_slot = jnp.mod(st.version, ring_depth).astype(jnp.int32)
            params, ring, info = ring_round(params, ring, win["slots"],
                                            batch, probe, dsz, win["taus"],
                                            new_slot)
            out = {**info, "clients": cids, "tau": win["taus"]}
            if rep is not None:
                # multi-host contract (DESIGN.md §7): round-log outputs
                # are fully replicated so every process reads them from
                # its own addressable shards
                out = jax.lax.with_sharding_constraint(out, rep)
            return (params, ring, st), out

        (params, ring, state), outs = jax.lax.scan(
            one_round, (params, ring, state), None, length=s)
        return params, ring, state, outs

    return chunk


@functools.lru_cache(maxsize=32)
def _make_collect_scan(sc: Scenario, fl: FLConfig, n: int, num_windows: int,
                       seed: int, mesh: Optional[Any]) -> Callable:
    """Events-only: scan the window kernel alone (no training). The
    device counterpart of ``host_walk_windows`` for parity tests and the
    population-scale benchmark."""
    window_step = make_window_step(sc, fl, n, seed, mesh)

    @jax.jit
    def run(statics, state):
        def body(st, _):
            st, win = window_step(statics, st)
            return st, win

        state, wins = jax.lax.scan(body, state, None, length=num_windows)
        return state, wins

    return run


def init_population(scenario: Scenario, n: int, fl: FLConfig, seed: int = 0,
                    mesh: Optional[Any] = None
                    ) -> Tuple[PopStatics, PopState]:
    """Fresh device-resident statics + state for an N-client population."""
    statics = _make_statics_fn(scenario, n, seed, mesh)()
    state = _make_init_state_fn(scenario, n, seed, mesh)(statics)
    return statics, state


def collect_windows(scenario: Scenario, n: int, fl: FLConfig,
                    num_windows: int, seed: int = 0,
                    mesh: Optional[Any] = None,
                    statics: Optional[PopStatics] = None,
                    state: Optional[PopState] = None) -> Dict[str, Any]:
    """Run ``num_windows`` windows of the device event machine (no
    training): host-order (T, K) arrays + the final state. One dispatch,
    one sync — the O(1)-host-syncs-per-window contract in its purest
    form."""
    if statics is None or state is None:
        statics, state = init_population(scenario, n, fl, seed, mesh)
    state, wins = _make_collect_scan(scenario, fl, n, num_windows, seed,
                                     mesh)(statics, state)
    host = fetch_replicated((state, wins)) if any(
        isinstance(l, jax.Array) and not l.is_fully_addressable
        for l in jax.tree.leaves((state, wins))) \
        else jax.device_get((state, wins))
    state_h, wins_h = host
    return {"clients": np.asarray(wins_h["cids"], np.int64),
            "tau": np.asarray(wins_h["taus"], np.int64),
            "slots": np.asarray(wins_h["slots"], np.int64),
            "t": np.asarray(wins_h["t"], np.float64),
            "num_events": int(state_h.num_events),
            "now": float(state_h.now),
            "state": state}


def host_walk_windows(behavior: ClientBehavior, fl: FLConfig,
                      num_windows: int) -> Dict[str, Any]:
    """The engine's host event walk, events only (no data plane): the
    reference the device path is pinned against, and the baseline the
    population-scale benchmark measures speedup over."""
    import heapq

    n = behavior.num_clients
    k = fl.buffer_size
    ring_depth = fl.max_staleness + 1
    base_version = np.zeros(n, np.int64)
    version = 0
    num_events = 0
    events = []
    for cid in range(n):
        start = behavior.next_start(cid, 0.0)
        events.append((start + behavior.duration(cid, start), cid))
    heapq.heapify(events)

    def reschedule(cid, t):
        start = behavior.next_start(cid, t)
        heapq.heappush(events, (start + behavior.duration(cid, start), cid))

    out_c = np.zeros((num_windows, k), np.int64)
    out_tau = np.zeros((num_windows, k), np.int64)
    out_slot = np.zeros((num_windows, k), np.int64)
    out_t = np.zeros((num_windows, k), np.float64)
    now = 0.0
    for w in range(num_windows):
        filled = 0
        while filled < k:
            t, cid = heapq.heappop(events)
            num_events += 1
            _, lost = behavior.next_upload(cid)
            if lost:
                base_version[cid] = version
                reschedule(cid, t)
                continue
            bv = int(base_version[cid])
            if bv < version - fl.max_staleness:
                bv = version
                base_version[cid] = version
            out_c[w, filled] = cid
            out_tau[w, filled] = version - bv
            out_slot[w, filled] = bv % ring_depth
            out_t[w, filled] = t
            filled += 1
            if filled < k:
                base_version[cid] = version
                reschedule(cid, t)
        version += 1
        now = out_t[w, k - 1]
        trig = int(out_c[w, k - 1])
        base_version[trig] = version
        reschedule(trig, now)
    return {"clients": out_c, "tau": out_tau, "slots": out_slot, "t": out_t,
            "num_events": num_events, "now": float(now)}


# ---------------------------------------------------------------------------
# host twins: the SAME counter streams, consumed by the host engine
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _scalar_fns(sc: Scenario, n: int, seed: int):
    """Jitted scalar kernels over the cached device statics — what
    ``CounterBehavior`` calls per event so host and device runs share
    every draw bit-for-bit."""
    fns = make_behavior_fns(sc, seed)
    statics = _make_statics_fn(sc, n, seed, None)()

    @jax.jit
    def dur(cid, k, t):
        return fns.duration(cid, k, t, statics.speed[cid], statics.comm[cid])

    @jax.jit
    def drop(cid, k):
        return fns.dropped(cid, k)

    @jax.jit
    def gate(cid, t):
        return fns.gate(statics.phase[cid], t)

    return statics, dur, drop, gate


class CounterBehavior(ClientBehavior):
    """Host ``ClientBehavior`` drawing from the population engine's
    counter streams (threefry ``fold_in`` by ``(cid, k)``) instead of
    per-client PCG64 generators.

    Drives ``run_vectorized``'s host event walk with the exact draws the
    device kernel uses — the bridge the small-N parity tests cross. Its
    checkpoint state is counters only (``get_state`` packs no PCG64
    rows): with this behavior the vectorized path no longer needs
    ``utils/rngstate.py``.
    """

    def __init__(self, scenario: Scenario, num_clients: int, seed: int = 0):
        super().__init__(scenario, num_clients, seed)
        statics, dur, drop, gate = _scalar_fns(scenario, int(num_clients),
                                               int(seed))
        # replace the PCG64-drawn statics with the device population's
        self.speed = np.asarray(statics.speed, np.float64)
        self.comm = np.asarray(statics.comm, np.float64)
        self.phase = np.asarray(statics.phase, np.float64)
        self._dur_fn, self._drop_fn, self._gate_fn = dur, drop, gate
        self._dur_rng = self._drop_rng = None  # PCG64 streams retired

    def next_start(self, cid: int, t: float) -> float:
        if not self.scenario.diurnal:
            return t
        # f32 gate, like the device: the host's running time is the f64
        # image of the same f32 value, so casting loses nothing
        return float(self._gate_fn(np.int32(cid), np.float32(t)))

    def duration(self, cid: int, t: float = 0.0) -> float:
        if self._replay_dur is not None:
            return super().duration(cid, t)
        k = len(self._durations[cid])
        dur = float(self._dur_fn(np.int32(cid), np.int32(k), np.float32(t)))
        self._durations[cid].append(dur)
        return dur

    def next_upload(self, cid: int) -> Tuple[int, bool]:
        k = int(self._upload_idx[cid])
        self._upload_idx[cid] += 1
        if self._replay_drops is not None:
            hit = (cid, k) in self._replay_drops
        else:
            hit = bool(self._drop_fn(np.int32(cid), np.int32(k)))
        if hit:
            self._drops.append((cid, k))
        return k, hit

    # -- checkpointing: counters ARE the whole stream state -------------
    def get_state(self) -> Dict[str, np.ndarray]:
        return {"upload_idx": self._upload_idx.copy(),
                "draw_counts": np.asarray([len(d) for d in self._durations],
                                          np.int64)}

    def set_state(self, state: Dict[str, np.ndarray]) -> None:
        upload_idx = np.asarray(state["upload_idx"], np.int64)
        if len(upload_idx) != self.num_clients:
            raise ValueError(f"state has {len(upload_idx)} clients, "
                             f"behavior has {self.num_clients}")
        self._upload_idx = upload_idx.copy()
        counts = np.asarray(state["draw_counts"], np.int64)
        self._durations = [[float("nan")] * int(c) for c in counts]
        self._drops = []


@functools.lru_cache(maxsize=None)
def _host_index_fns(seed: int, batch_size: int):
    k_train = _stream_key(seed, _TAG_TRAIN)
    k_probe = _stream_key(seed, _TAG_PROBE)

    @jax.jit
    def train_idx(cid, k, size):
        key = jax.random.fold_in(jax.random.fold_in(k_train, cid), k)
        return jax.random.randint(key, (batch_size,), 0, size)

    @jax.jit
    def probe_idx(cid, k, size):
        key = jax.random.fold_in(jax.random.fold_in(k_probe, cid), k)
        return jax.random.randint(key, (batch_size,), 0, size)

    return train_idx, probe_idx


@dataclasses.dataclass
class CounterDataset(ClientDataset):
    """Host twin of the device pool's batch sampling.

    Train batches (``batches``) and probe batches (``batch``) consume
    separate counter streams — order-independent, so the engine's
    probes-after-all-train-draws convention and the device's per-accept
    draws index identically even when a client appears twice in one
    window. Checkpoint state is the two counters (no PCG64).
    """

    cid: int = 0
    stream_seed: int = 0

    def __post_init__(self):
        super().__post_init__()
        self._k_train = 0
        self._k_probe = 0

    def batch_indices(self, batch_size: int) -> np.ndarray:
        raise NotImplementedError(
            "CounterDataset draws are stream-specific: use batch() "
            "(probe stream) or batches() (train stream)")

    def batches(self, batch_size: int, count: int):
        fn, _ = _host_index_fns(self.stream_seed, batch_size)
        idx = np.concatenate([
            np.asarray(fn(np.int32(self.cid), np.int32(self._k_train + j),
                          np.int32(self.size))) for j in range(count)])
        self._k_train += count
        return (self.x[idx].reshape(count, batch_size, *self.x.shape[1:]),
                self.y[idx].reshape(count, batch_size, *self.y.shape[1:]))

    def batch(self, batch_size: int):
        _, fn = _host_index_fns(self.stream_seed, batch_size)
        idx = np.asarray(fn(np.int32(self.cid), np.int32(self._k_probe),
                            np.int32(self.size)))
        self._k_probe += 1
        return self.x[idx], self.y[idx]

    def rng_state(self) -> np.ndarray:
        return np.asarray([self._k_train, self._k_probe, 0, 0, 0, 0],
                          np.uint64)

    def set_rng_state(self, row: np.ndarray) -> None:
        row = np.asarray(row).reshape(-1)
        self._k_train = int(row[0])
        self._k_probe = int(row[1])


def make_counter_clients(clients: Sequence[ClientDataset],
                         seed: int = 0) -> List[CounterDataset]:
    """Wrap existing per-client datasets as counter-stream twins of the
    ``DevicePool.from_clients`` sampling (shares the x/y arrays)."""
    return [CounterDataset(x=c.x, y=c.y, seed=c.seed, cid=i,
                           stream_seed=seed)
            for i, c in enumerate(clients)]


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


class PopulationEngineState(NamedTuple):
    """Host snapshot of a ``run_population`` run at a round boundary.

    The client state machine is four plain ``(N,)`` arrays + three
    scalars (PopState) — counter-based RNG means there is NO generator
    state to pack, unlike ``EngineState``'s PCG64 rows. Statics are not
    stored: they are a pure function of (scenario, n, seed)."""

    version: int
    now: float
    num_events: int
    t_next: np.ndarray        # (N,) f32
    k_next: np.ndarray        # (N,) i32
    batch_k: np.ndarray       # (N,) i32
    base_version: np.ndarray  # (N,) i32
    params: Any               # host pytree
    ring: Any                 # codec host state: (R, n_padded) f32 for the
    # f32 codec, dict of arrays for int8/delta (version_store)
    history: List[Dict]
    round_log: List[Dict]


def population_state_to_tree(state: PopulationEngineState) -> Dict[str, Any]:
    """PopulationEngineState -> pytree of plain arrays (npz-safe)."""
    return {
        "meta": {"version": np.int64(state.version),
                 "now": np.float64(state.now),
                 "num_events": np.int64(state.num_events)},
        "t_next": np.asarray(state.t_next, np.float32),
        "k_next": np.asarray(state.k_next, np.int32),
        "batch_k": np.asarray(state.batch_k, np.int32),
        "base_version": np.asarray(state.base_version, np.int32),
        "params": state.params,
        "ring": (dict(state.ring) if isinstance(state.ring, dict)
                 else np.asarray(state.ring, np.float32)),
        "round_log": round_log_to_arrays(state.round_log),
        "history": history_to_arrays(state.history),
    }


def population_state_from_tree(tree: Dict[str, Any]) -> PopulationEngineState:
    """Inverse of ``population_state_to_tree``."""
    return PopulationEngineState(
        version=int(tree["meta"]["version"]),
        now=float(tree["meta"]["now"]),
        num_events=int(tree["meta"]["num_events"]),
        t_next=np.asarray(tree["t_next"], np.float32),
        k_next=np.asarray(tree["k_next"], np.int32),
        batch_k=np.asarray(tree["batch_k"], np.int32),
        base_version=np.asarray(tree["base_version"], np.int32),
        params=tree["params"],
        ring=(dict(tree["ring"]) if isinstance(tree["ring"], dict)
              else np.asarray(tree["ring"], np.float32)),
        history=history_from_arrays(tree["history"]),
        round_log=round_log_from_arrays(tree["round_log"]),
    )


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------


def run_population(loss_fn: Callable, init_params: Any,
                   data: Any, fl: FLConfig, total_rounds: int,
                   eval_fn: Optional[Callable[[Any], Dict]] = None,
                   eval_every: int = 5,
                   scenario: Optional[Scenario] = None,
                   seed: int = 0,
                   latency: Optional[Any] = None,
                   rounds_per_launch: int = 8,
                   mesh: Optional[Any] = None,
                   shard_ring: bool = True,
                   init_state: Optional[PopulationEngineState] = None,
                   capture_state: bool = False,
                   registry: Optional[MetricsRegistry] = None,
                   tracer: Optional[Tracer] = None) -> SimResult:
    """Simulate buffered-async FL with the client state machine resident
    on device (see module docstring).

    ``data`` is a ``DevicePool`` or a sequence of ``ClientDataset`` (then
    pooled via ``DevicePool.from_clients`` — that path samples batches on
    the counter streams, matching ``CounterDataset`` twins rather than
    the PCG64 ``ClientDataset`` draws). Scenario-driven only: behaviors,
    traces, and ``LatencyModel`` stay on the host engine. Host syncs per
    run: one per eval (params + sim time) plus one final round-log fetch
    — independent of K, N and ``total_rounds / rounds_per_launch``.

    ``capture_state=True`` attaches a ``PopulationEngineState`` to
    ``SimResult.final_state``; passing it back as ``init_state`` (same
    loss/pool/config/seed) resumes BIT-identically to the uninterrupted
    run. ``total_rounds`` counts from round 0, as in ``run_vectorized``.
    """
    if latency is not None:
        raise ValueError("run_population is scenario-driven; LatencyModel "
                         "populations need the host engine "
                         "(engine='vectorized')")
    sc = scenario if scenario is not None else Scenario(
        name="population-default",
        description="heterogeneous lognormal population")
    pool = data if isinstance(data, DevicePool) else \
        DevicePool.from_clients(data)
    n = pool.num_clients
    k = fl.buffer_size
    spans = mesh_spans_processes(mesh)
    pspec_n = _n_pspec(mesh, n)

    reg = registry if registry is not None else default_registry()
    tr = tracer if tracer is not None else NULL_TRACER
    _dispatches = reg.counter("engine_dispatches_total")
    _launch_hist = reg.histogram("engine_launch_seconds")
    _syncs = reg.counter("engine_host_syncs_total")
    _dispatches_start = _dispatches.value

    # ---- place the pool --------------------------------------------------
    if mesh is not None:
        pool_x = put_with_sharding(np.asarray(pool.x), mesh, P())
        pool_y = put_with_sharding(np.asarray(pool.y), mesh, P())
        offsets = put_with_sharding(np.asarray(pool.offsets, np.int32),
                                    mesh, pspec_n)
        sizes = put_with_sharding(np.asarray(pool.sizes, np.int32),
                                  mesh, pspec_n)
    else:
        pool_x = jnp.asarray(pool.x)
        pool_y = jnp.asarray(pool.y)
        offsets = jnp.asarray(pool.offsets, jnp.int32)
        sizes = jnp.asarray(pool.sizes, jnp.int32)

    statics = _make_statics_fn(sc, n, seed, mesh)()

    # ---- init / resume ---------------------------------------------------
    if init_state is None:
        params = init_params
        _, ring = init_version_ring(init_params, fl, mesh=mesh,
                                    shard_ring=shard_ring)
        state = _make_init_state_fn(sc, n, seed, mesh)(statics)
        version = 0
        history: List[Dict] = []
        round_log_prefix: List[Dict] = []
    else:
        if len(init_state.base_version) != n:
            raise ValueError(
                f"checkpoint has {len(init_state.base_version)} clients, "
                f"this run has {n}")
        params = init_state.params
        _, ring = init_version_ring(init_params, fl, mesh=mesh,
                                    shard_ring=shard_ring,
                                    rows=init_state.ring)
        version = init_state.version

        def _place(arr, dtype):
            a = np.asarray(arr, dtype)
            return put_with_sharding(a, mesh, pspec_n) if mesh is not None \
                else jnp.asarray(a)

        state = PopState(
            t_next=_place(init_state.t_next, np.float32),
            k_next=_place(init_state.k_next, np.int32),
            batch_k=_place(init_state.batch_k, np.int32),
            base_version=_place(init_state.base_version, np.int32),
            version=jnp.int32(version),
            now=jnp.float32(init_state.now),
            num_events=jnp.int32(init_state.num_events))
        history = [dict(h) for h in init_state.history]
        if eval_fn and history and history[-1]["round"] == version \
                and version % eval_every:
            # drop the snapshot run's trailing forced eval (off-cadence)
            # so the resumed history matches the uninterrupted run
            history.pop()
        round_log_prefix = [dict(r) for r in init_state.round_log]
    if mesh is not None:
        params = (put_replicated(params, mesh) if spans
                  else jax.device_put(params, jax.sharding.NamedSharding(
                      mesh, P())))

    def _fetch(tree):
        if any(isinstance(l, jax.Array) and not l.is_fully_addressable
               for l in jax.tree.leaves(tree)):
            return fetch_replicated(tree)
        return jax.device_get(tree)

    def maybe_eval(force=False):
        if eval_fn is None or not (force or version % eval_every == 0):
            return
        with tr.span(SPAN_HOST_SYNC, what="eval", version=version):
            _syncs.inc()
            now = float(_fetch(state.now))
        record_eval(history, eval_fn, version, now, params, eval_every,
                    force)

    pending: List[Dict] = []
    if init_state is None:
        maybe_eval(force=True)
    while version < total_rounds:
        horizon = total_rounds - version
        if eval_fn:
            horizon = min(horizon, eval_every - version % eval_every)
        s = min(rounds_per_launch, horizon)
        chunk = _make_pop_chunk(loss_fn, fl, sc, n, s, seed, mesh)
        with tr.span(SPAN_APPLY, rounds=s, version=version):
            t0 = time.perf_counter()
            _dispatches.inc()
            params, ring, state, outs = chunk(params, ring, state, statics,
                                              pool_x, pool_y, offsets, sizes)
            _launch_hist.observe(time.perf_counter() - t0)
        # the host mirrors `version` deterministically — no sync needed
        # for loop control
        version += s
        pending.append({"v_end": version, "outs": outs})
        maybe_eval()
    maybe_eval(force=True)

    # ---- single device->host sync for the whole run's round log ---------
    outs_list = [p.pop("outs") for p in pending]
    with tr.span(SPAN_HOST_SYNC, what="round_log", launches=len(outs_list)):
        _syncs.inc()
        fetched = _fetch(outs_list)
        state_h = _fetch(state)
    round_log = list(round_log_prefix)
    for meta, logs in zip(pending, fetched):
        s_chunk = len(logs["clients"])
        round_log.extend(round_log_rows(
            meta["v_end"] - s_chunk, k, logs["clients"], logs["tau"], logs))
    now = float(state_h.now)
    num_events = int(state_h.num_events)

    final_state = None
    if capture_state:
        with tr.span(SPAN_CHECKPOINT, version=version):
            _syncs.inc()
            final_state = PopulationEngineState(
                version=version, now=now, num_events=num_events,
                t_next=np.asarray(state_h.t_next, np.float32),
                k_next=np.asarray(state_h.k_next, np.int32),
                batch_k=np.asarray(state_h.batch_k, np.int32),
                base_version=np.asarray(state_h.base_version, np.int32),
                params=_fetch(params),
                ring=ring_state_to_host(fl, _fetch(ring)),
                history=[dict(h) for h in history],
                round_log=[dict(r) for r in round_log])
    return SimResult(history=history, server_rounds=version, sim_time=now,
                     round_log=round_log, num_events=num_events,
                     num_launches=int(_dispatches.value - _dispatches_start),
                     final_state=final_state)

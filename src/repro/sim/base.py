"""Engine-independent simulation types and helpers.

Kept free of ``repro.core`` imports so ``repro.core.simulator`` (the
compatibility shim) can re-export these at module level without creating
an import cycle: ``repro.sim.engine`` -> ``repro.core.client`` ->
``repro.core.__init__`` -> ``repro.core.simulator`` -> (this module).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.sim.scenarios import ClientBehavior, LatencyModel, Scenario
from repro.sim.traces import EventTrace


@dataclasses.dataclass
class SimResult:
    history: List[Dict]  # per-eval: {round, time, **metrics}
    server_rounds: int
    sim_time: float
    round_log: List[Dict]
    num_events: int = 0  # uploads processed (incl. dropped)
    num_launches: int = 0  # XLA dispatches issued (0 = runner doesn't count)
    trace: Optional[EventTrace] = None

    def rounds_to_target(self, metric: str, target: float) -> Optional[int]:
        for h in self.history:
            if h.get(metric, -np.inf) >= target:
                return h["round"]
        return None

    def time_to_target(self, metric: str, target: float) -> Optional[float]:
        for h in self.history:
            if h.get(metric, -np.inf) >= target:
                return h["time"]
        return None


def record_eval(history: List[Dict], eval_fn, version: int, now: float,
                params, eval_every: int, force: bool = False) -> None:
    """Append an eval row, shared by every runner (one cadence rule).

    Rows dedup on (round) unless time advanced: the trailing forced eval
    at run end must not duplicate the final row when
    ``total_rounds % eval_every == 0``.
    """
    if eval_fn is None or not (force or version % eval_every == 0):
        return
    if history and history[-1]["round"] == version \
            and history[-1]["time"] == now:
        return
    history.append({"round": version, "time": now, **eval_fn(params)})


def make_batches(ds, batch_size: int, steps: int):
    """(M, B, ...) stacked local-step batches from a ClientDataset.

    One vectorized gather (``ClientDataset.batches``) with the same index
    stream as ``steps`` sequential ``.batch()`` calls, so every runner
    (legacy loop, vectorized engine, sync FedAvg) sees identical data.
    """
    return ds.batches(batch_size, steps)


def resolve_behavior(n: int, seed: int,
                     behavior: Optional[ClientBehavior] = None,
                     scenario: Optional[Scenario] = None,
                     latency: Optional[LatencyModel] = None,
                     trace: Optional[EventTrace] = None) -> ClientBehavior:
    """One rule for every runner: trace > behavior > scenario > latency.

    A replayed trace needs its scenario's *deterministic* parts back
    (diurnal gating etc.): an explicit ``scenario=``/``behavior=`` wins;
    otherwise the scenario name recorded in the trace is looked up in
    the registry. Unregistered composed scenarios must be re-passed
    explicitly alongside the trace.
    """
    if trace is not None:
        from repro.sim.scenarios import registry
        if scenario is not None:
            sc = scenario
        elif behavior is not None:
            sc = behavior.scenario
        else:
            sc = registry().get(trace.scenario,
                                Scenario(name=trace.scenario or "replay"))
        return trace.replay_behavior(sc)
    if behavior is not None:
        return behavior
    if scenario is not None:
        return scenario.behavior(n, seed)
    latency = latency or LatencyModel.heterogeneous(n, seed=seed)
    return ClientBehavior.from_latency(latency, n, seed)

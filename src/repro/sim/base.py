"""Engine-independent simulation types and helpers.

Kept free of ``repro.core`` imports so ``repro.core.simulator`` (the
compatibility shim) can re-export these at module level without creating
an import cycle: ``repro.sim.engine`` -> ``repro.core.client`` ->
``repro.core.__init__`` -> ``repro.core.simulator`` -> (this module).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.sim.scenarios import ClientBehavior, LatencyModel, Scenario
from repro.sim.traces import EventTrace


@dataclasses.dataclass
class SimResult:
    history: List[Dict]  # per-eval: {round, time, **metrics}
    server_rounds: int
    sim_time: float
    round_log: List[Dict]
    num_events: int = 0  # uploads processed (incl. dropped)
    trace: Optional[EventTrace] = None

    def rounds_to_target(self, metric: str, target: float) -> Optional[int]:
        for h in self.history:
            if h.get(metric, -np.inf) >= target:
                return h["round"]
        return None

    def time_to_target(self, metric: str, target: float) -> Optional[float]:
        for h in self.history:
            if h.get(metric, -np.inf) >= target:
                return h["time"]
        return None


def make_batches(ds, batch_size: int, steps: int):
    """(M, B, ...) stacked local-step batches from a ClientDataset."""
    xs, ys = zip(*[ds.batch(batch_size) for _ in range(steps)])
    return np.stack(xs), np.stack(ys)


def resolve_behavior(n: int, seed: int,
                     behavior: Optional[ClientBehavior] = None,
                     scenario: Optional[Scenario] = None,
                     latency: Optional[LatencyModel] = None,
                     trace: Optional[EventTrace] = None) -> ClientBehavior:
    """One rule for every runner: trace > behavior > scenario > latency.

    A replayed trace needs its scenario's *deterministic* parts back
    (diurnal gating etc.): an explicit ``scenario=``/``behavior=`` wins;
    otherwise the scenario name recorded in the trace is looked up in
    the registry. Unregistered composed scenarios must be re-passed
    explicitly alongside the trace.
    """
    if trace is not None:
        from repro.sim.scenarios import registry
        if scenario is not None:
            sc = scenario
        elif behavior is not None:
            sc = behavior.scenario
        else:
            sc = registry().get(trace.scenario,
                                Scenario(name=trace.scenario or "replay"))
        return trace.replay_behavior(sc)
    if behavior is not None:
        return behavior
    if scenario is not None:
        return scenario.behavior(n, seed)
    latency = latency or LatencyModel.heterogeneous(n, seed=seed)
    return ClientBehavior.from_latency(latency, n, seed)

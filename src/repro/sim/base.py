"""Engine-independent simulation types and helpers.

Kept free of ``repro.core`` imports so ``repro.core.simulator`` (the
compatibility shim) can re-export these at module level without creating
an import cycle: ``repro.sim.engine`` -> ``repro.core.client`` ->
``repro.core.__init__`` -> ``repro.core.simulator`` -> (this module).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.sim.scenarios import ClientBehavior, LatencyModel, Scenario
from repro.sim.traces import EventTrace


@dataclasses.dataclass
class SimResult:
    history: List[Dict]  # per-eval: {round, time, **metrics}
    server_rounds: int
    sim_time: float
    round_log: List[Dict]
    num_events: int = 0  # uploads processed (incl. dropped)
    num_launches: int = 0  # XLA dispatches issued (0 = runner doesn't count)
    trace: Optional[EventTrace] = None
    # engine checkpointing (run_vectorized(capture_state=True)): the
    # host-side EngineState snapshot a resumed run restarts from
    final_state: Optional[object] = None

    def rounds_to_target(self, metric: str, target: float) -> Optional[int]:
        for h in self.history:
            if h.get(metric, -np.inf) >= target:
                return h["round"]
        return None

    def time_to_target(self, metric: str, target: float) -> Optional[float]:
        for h in self.history:
            if h.get(metric, -np.inf) >= target:
                return h["time"]
        return None


def record_eval(history: List[Dict], eval_fn, version: int, now: float,
                params, eval_every: int, force: bool = False) -> None:
    """Append an eval row, shared by every runner (one cadence rule).

    Rows dedup on (round) unless time advanced: the trailing forced eval
    at run end must not duplicate the final row when
    ``total_rounds % eval_every == 0``.
    """
    if eval_fn is None or not (force or version % eval_every == 0):
        return
    if history and history[-1]["round"] == version \
            and history[-1]["time"] == now:
        return
    history.append({"round": version, "time": now, **eval_fn(params)})


def round_log_rows(v0: int, k: int, clients, taus, logs) -> List[Dict]:
    """Round-log rows for one launch chunk, shared by both engines.

    ``clients``/``taus`` are (S, K) per-round sequences (host lists for
    the event-walk engine, fetched device arrays for the population
    engine); ``logs`` holds the chunk's fetched info arrays (``weights``,
    ``staleness``, ``stat_effect``, ``sq_dists``, each (S, K)). Row ``j``
    documents server version ``v0 + j + 1`` — the version the round
    PRODUCED. Taus/clients are int-cast so device f32 staleness and host
    int lists serialize identically (``round_log_to_arrays`` round-trip).
    """
    rows: List[Dict] = []
    for j in range(len(clients)):
        rows.append({
            "version": v0 + j + 1,
            "weights": np.asarray(logs["weights"][j]).tolist(),
            "staleness_deg": np.asarray(logs["staleness"][j]).tolist(),
            "stat_effect": np.asarray(logs["stat_effect"][j]).tolist(),
            "sq_dists": np.asarray(logs["sq_dists"][j]).tolist(),
            "tau": [int(t) for t in taus[j]],
            "clients": [int(c) for c in clients[j]],
            "k": k,
        })
    return rows


def round_log_to_arrays(round_log: List[Dict]) -> Dict[str, np.ndarray]:
    """Engine round log (list of per-round dicts) -> dict of stacked arrays.

    The npz-friendly form ``checkpoint/ckpt.py`` stores: every per-slot
    field becomes a (T, K) array (f32 — the dtype the device produced, so
    the round-trip is bit-exact), ``clients`` (T, K) int64, ``version``
    (T,) int64. Requires the constant K the engine guarantees
    (K = buffer_size on every round).
    """
    if not round_log:
        return {"version": np.zeros((0,), np.int64)}
    ks = {r["k"] for r in round_log}
    if len(ks) != 1:
        raise ValueError(f"round log mixes buffer sizes {sorted(ks)}")
    out = {
        "version": np.asarray([r["version"] for r in round_log], np.int64),
        "k": np.asarray([r["k"] for r in round_log], np.int64),
        "clients": np.asarray([r["clients"] for r in round_log], np.int64),
        "tau": np.asarray([r["tau"] for r in round_log], np.int64),
    }
    for key in ("weights", "staleness_deg", "stat_effect", "sq_dists"):
        out[key] = np.asarray([r[key] for r in round_log], np.float32)
    return out


def round_log_from_arrays(arrays: Dict[str, np.ndarray]) -> List[Dict]:
    """Inverse of ``round_log_to_arrays``."""
    versions = np.asarray(arrays["version"])
    out: List[Dict] = []
    for j in range(len(versions)):
        out.append({
            "version": int(versions[j]),
            "weights": np.asarray(arrays["weights"][j]).tolist(),
            "staleness_deg": np.asarray(arrays["staleness_deg"][j]).tolist(),
            "stat_effect": np.asarray(arrays["stat_effect"][j]).tolist(),
            "sq_dists": np.asarray(arrays["sq_dists"][j]).tolist(),
            "tau": [int(t) for t in arrays["tau"][j]],
            "clients": [int(c) for c in arrays["clients"][j]],
            "k": int(arrays["k"][j]),
        })
    return out


def history_to_arrays(history: List[Dict]) -> Dict[str, np.ndarray]:
    """Eval history -> dict of (E,) arrays (uniform keys per run)."""
    if not history:
        return {"round": np.zeros((0,), np.int64)}
    keys = set(history[0])
    for h in history:
        if set(h) != keys:
            raise ValueError("history rows have differing keys; cannot stack")
    out: Dict[str, np.ndarray] = {
        "round": np.asarray([h["round"] for h in history], np.int64)}
    for key in sorted(keys - {"round"}):
        out[key] = np.asarray([h[key] for h in history], np.float64)
    return out


def history_from_arrays(arrays: Dict[str, np.ndarray]) -> List[Dict]:
    """Inverse of ``history_to_arrays``."""
    rounds = np.asarray(arrays["round"])
    out: List[Dict] = []
    for j in range(len(rounds)):
        row = {"round": int(rounds[j])}
        for key in sorted(k for k in arrays if k != "round"):
            row[key] = float(np.asarray(arrays[key])[j])
        out.append(row)
    return out


def make_batches(ds, batch_size: int, steps: int):
    """(M, B, ...) stacked local-step batches from a ClientDataset.

    One vectorized gather (``ClientDataset.batches``) with the same index
    stream as ``steps`` sequential ``.batch()`` calls, so every runner
    (legacy loop, vectorized engine, sync FedAvg) sees identical data.
    """
    return ds.batches(batch_size, steps)


def resolve_behavior(n: int, seed: int,
                     behavior: Optional[ClientBehavior] = None,
                     scenario: Optional[Scenario] = None,
                     latency: Optional[LatencyModel] = None,
                     trace: Optional[EventTrace] = None) -> ClientBehavior:
    """One rule for every runner: trace > behavior > scenario > latency.

    A replayed trace needs its scenario's *deterministic* parts back
    (diurnal gating etc.): an explicit ``scenario=``/``behavior=`` wins;
    otherwise the scenario name recorded in the trace is looked up in
    the registry. Unregistered composed scenarios must be re-passed
    explicitly alongside the trace.
    """
    if trace is not None:
        from repro.sim.scenarios import registry
        if scenario is not None:
            sc = scenario
        elif behavior is not None:
            sc = behavior.scenario
        else:
            sc = registry().get(trace.scenario,
                                Scenario(name=trace.scenario or "replay"))
        return trace.replay_behavior(sc)
    if behavior is not None:
        return behavior
    if scenario is not None:
        return scenario.behavior(n, seed)
    latency = latency or LatencyModel.heterogeneous(n, seed=seed)
    return ClientBehavior.from_latency(latency, n, seed)

"""Continuous-arrival upload streams for the serving loop (DESIGN.md §8).

``TrafficGenerator`` turns a ``sim/`` scenario — the same per-client
seeded ``ClientBehavior`` timelines the simulation engines replay — into
an in-process traffic source for ``core/serving.py``: a heap of pending
(time, client) upload completions, realized one at a time into
``Upload`` messages carrying the client's local-step batches and eq.-4
probe. Because every duration/dropout draw comes from the per-client
streams, the arrival process is deterministic under a seed and identical
across protocols — the property the scenario registry was built around.

Client lifecycle per event:

    pop (t, cid) -> realize: consume the behavior's next upload
      * scenario dropout       -> lost in transit; re-pull + retrain
      * pending retry          -> re-offer the SAME upload (same base
                                  version — it got staler while waiting)
    offer to the controller -> settle:
      * admitted / dropped-stale -> re-pull the CURRENT version, train,
                                    next upload at t + duration
      * queue full             -> hold the upload, retry at
                                  t + retry_after (admission backpressure)

The re-pull after a stale drop mirrors the engine's ring-resync
semantics: the client's base fell out of the version window, so it
restarts from the current model rather than shipping unweightable work.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import FLConfig
from repro.core.serving import Admission, REJECT_QUEUE_FULL, Upload
from repro.sim.scenarios import ClientBehavior


def draw_upload(ds, cid: int, fl: FLConfig, *, base_version: int,
                t: float, seq: int = -1) -> Upload:
    """One local round: the client's next seeded batch draw as an Upload.

    THE shared draw (DESIGN.md §12): this in-process twin, the real
    socket clients (``transport/client.py``), and the loopback-parity
    journal replay (``launch/serve_fl.py --replay-journal``) all
    materialize uploads through it, so a client's seq-th upload is
    bit-identical everywhere. ``seq`` counts the client's dataset draw
    pairs (dropped-in-transit events consume NO draws).
    """
    batch = ds.batches(fl.batch_size, fl.local_steps)
    probe = ds.batch(fl.batch_size)
    return Upload(client_id=cid, base_version=int(base_version),
                  data_size=float(ds.size), batch=batch, probe=probe,
                  sent_at=t, seq=seq)


class TrafficGenerator:
    """Scenario-driven arrival stream with retry/re-pull bookkeeping.

    Together with ``core.serving.serve_stream`` this is the
    deterministic in-process twin of the socket path: the same uploads
    a real client fleet would push through ``transport/`` arrive on the
    scenario's seeded sim clock instead — the CI path the loopback
    parity gate compares the transport against.
    """

    def __init__(self, clients: Sequence, behavior: ClientBehavior,
                 fl: FLConfig):
        self.clients = clients
        self.beh = behavior
        self.fl = fl
        n = len(clients)
        self.base_version = np.zeros(n, np.int64)
        self.upload_seq = np.zeros(n, np.int64)  # per-client draw index
        self.pending: Dict[int, Upload] = {}  # cid -> upload awaiting retry
        self.lost = 0  # scenario dropouts (upload never reached the server)
        self.retries = 0  # queue-full re-offers scheduled
        self._events: List[Tuple[float, int]] = []
        for cid in range(n):
            start = behavior.next_start(cid, 0.0)
            self._events.append(
                (start + behavior.duration(cid, start), cid))
        heapq.heapify(self._events)

    # -- event stream ----------------------------------------------------
    def empty(self) -> bool:
        return not self._events

    def pop(self) -> Tuple[float, int]:
        """Next (time, client) upload completion, global time order."""
        return heapq.heappop(self._events)

    def realize(self, cid: int, t: float, version: int) -> Optional[Upload]:
        """Materialize client ``cid``'s upload at time ``t``.

        Returns None when the scenario drops it in transit (the client
        immediately re-pulls and retrains). A pending queue-full retry is
        returned as-is — same payload, same base version, now staler.
        """
        retry = self.pending.pop(cid, None)
        if retry is not None:
            return retry
        _, dropped = self.beh.next_upload(cid)
        if dropped:
            self.lost += 1
            self.repull(cid, t, version)
            return None
        seq = int(self.upload_seq[cid])
        self.upload_seq[cid] += 1
        return draw_upload(self.clients[cid], cid, self.fl,
                           base_version=int(self.base_version[cid]),
                           t=t, seq=seq)

    def settle(self, cid: int, t: float, adm: Admission, version: int,
               upload: Upload) -> None:
        """Apply the admission outcome to the client's timeline."""
        if not adm.accepted and adm.reason == REJECT_QUEUE_FULL:
            # backpressure: hold the upload, re-offer after the hint
            self.pending[cid] = upload
            self.retries += 1
            heapq.heappush(self._events, (t + adm.retry_after, cid))
            return
        # admitted, or dropped as hopelessly stale: either way the client
        # re-pulls the current model and starts its next local round
        self.repull(cid, t, version)

    def repull(self, cid: int, t: float, version: int) -> None:
        self.base_version[cid] = version
        start = self.beh.next_start(cid, t)
        heapq.heappush(self._events,
                       (start + self.beh.duration(cid, start), cid))

"""Vectorized, device-resident asynchronous-FL simulation engine.

The legacy simulator (now ``sim/legacy.py``) walks the event heap one
upload at a time and dispatches one jitted ``local_update`` per client
event — O(K) XLA launches plus O(K) host round-trips per server round.
This engine exploits the FedBuff structure instead: the buffer drains
completely at every aggregation, so **every server round is exactly one
window of K uploads**, and within a window no aggregation happens until
the K-th upload. All K clients' local training therefore depends only on
state known at the window start, and the whole round compiles to ONE
program (``_make_chunk_step``, scanning the shared
``core/round_body.py`` implementation — the same body the compiled
cohort step runs, optionally mesh-sharded over (data, model) per
DESIGN.md §5):

    ring   (R, Np)   device-resident version ring (R = max_staleness + 1)
                     of padded FLAT parameter rows — sharded P(None,
                     "model") on a mesh, R * Np / model_shards per device
    bases  = ring[base_slots]                      # flat gather
    deltas = vmap(local_update)(unflatten(bases))  # K clients, one launch
    losses = vmap(loss(params, probe_k))           # eq. 4 probes
    x', info = apply_server_round(...)             # eq. 3 + 4 + 5
    ring'  = ring.at[slot(t+1)].set(x')            # flat write, no round-trip

Because a client's upload timeline never depends on server state (it
trains, uploads after a sampled duration, immediately re-pulls), the
host can walk the event heap **ahead of the device**: it pre-computes up
to ``rounds_per_launch`` windows of (batches, base slots, staleness,
probes) as stacked ``(S, K, ...)`` arrays and drives all S rounds
through one ``jax.lax.scan`` launch, the version ring advancing
on-device between rounds. The round log is fetched once at the end of
the run — ``jax.device_get`` on one host, process-local addressable
shards on a process-spanning mesh (DESIGN.md §7) — so a T-round
simulation costs O(T / rounds_per_launch) launches and O(1) log syncs
instead of the legacy O(T*K) launches and O(T) syncs. Launch chunks are
clipped to eval boundaries, so the eval cadence is identical to the
legacy loop.

Event semantics match the legacy loop event-for-event on the scenarios
both can express (tested in tests/test_sim_engine.py): uploads are
processed in (time, client) heap order; a client that uploads without
triggering aggregation immediately re-pulls the *current* version; the
K-th client pulls the new version; bases older than the ring resync to
the current model with staleness 0. On top of that the engine supports
the behaviors the legacy loop cannot: availability gating, dropped
uploads (the client re-pulls and retrains; no delta is computed for the
lost upload), and trace replay (see sim/traces.py).
"""
from __future__ import annotations

import functools
import heapq
import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.obs.metrics import MetricsRegistry, default_registry
from repro.obs.trace import (
    NULL_TRACER,
    SPAN_APPLY,
    SPAN_CHECKPOINT,
    SPAN_COLLECT,
    SPAN_HOST_SYNC,
    Tracer,
)
from repro.core.round_body import make_ring_round
from repro.core.server_pass import flatten_tree, make_flat_spec
from repro.core.version_store import build_ring, ring_state_to_host
from repro.launch.multihost import (
    fetch_replicated,
    mesh_spans_processes,
    put_replicated,
)
from repro.sim.base import (  # noqa: F401  (re-exported for callers)
    SimResult,
    history_from_arrays,
    history_to_arrays,
    make_batches,
    record_eval,
    resolve_behavior,
    round_log_from_arrays,
    round_log_rows,
    round_log_to_arrays,
)
from repro.sim.scenarios import ClientBehavior, LatencyModel, Scenario
from repro.sim.traces import EventTrace


def init_version_ring(init_params: Any, fl: FLConfig, *,
                      mesh: Optional[Any] = None, shard_ring: bool = True,
                      rows: Optional[Any] = None):
    """Build the device-resident version store (``core/version_store.py``).

    Each of the R = max_staleness + 1 retained versions is one padded
    flat parameter vector on the ``make_flat_spec`` layout (DESIGN.md
    §6), stored by the ``fl.ring_codec`` codec (DESIGN.md §11): the
    default ``f32`` keeps the raw (R, n_padded) f32 matrix — bit-
    compatible with every pre-codec caller of this function — while
    ``int8`` / ``delta`` keep a compressed NamedTuple state. With a mesh
    whose ``model`` axis has size m > 1 the state is placed on the
    codec's pspecs (f32/int8 rows ``P(None, "model")`` — per device
    ``R * n_padded / m`` (bytes-per-param-scaled) instead of R full
    replicas; on a process-spanning mesh (DESIGN.md §7) each PROCESS
    holds only its model slice of every row). ``shard_ring=False`` keeps
    the same layout but replicates (the bit-parity reference the
    multi-device tests pin against). ``rows`` restores from the
    checkpointed host representation (``version_store.ring_state_to_host``)
    instead of encoding the initial params; a codec or layout mismatch
    raises naming the codec and its expected layout. Returns
    ``(spec, ring)``.
    """
    return build_ring(init_params, fl, mesh=mesh, shard_ring=shard_ring,
                      rows=rows)


class EngineState(NamedTuple):
    """Host-side snapshot of a ``run_vectorized`` run at a round boundary.

    Everything a resumed run needs to be BIT-identical to the
    uninterrupted one: the version ring + params, the host event heap,
    the per-client behavior RNG streams (``ClientBehavior.get_state``),
    and the round log / eval history accumulated so far. Serialise with
    ``engine_state_to_tree`` (arrays only — ``checkpoint/ckpt.py``
    npz-safe) and restore with ``engine_state_from_tree``.
    """

    version: int
    now: float
    num_events: int
    base_version: np.ndarray  # (n,) int64
    events: Tuple[Tuple[float, int], ...]  # pending (t, cid) uploads
    params: Any  # host pytree
    ring: Any  # codec host state: (R, n_padded) f32 matrix for the f32
    # codec (pre-codec byte layout), dict of arrays for int8/delta
    # (version_store.ring_state_to_host)
    behavior: Dict[str, np.ndarray]
    dataset_rng: np.ndarray  # (n, 6) uint64 ClientDataset batch streams
    history: List[Dict]
    round_log: List[Dict]


def engine_state_to_tree(state: EngineState) -> Dict[str, Any]:
    """EngineState -> pytree of plain arrays (``save_checkpoint``-able)."""
    ev = np.asarray(sorted(state.events), np.float64).reshape(-1, 2)
    return {
        "meta": {"version": np.int64(state.version),
                 "now": np.float64(state.now),
                 "num_events": np.int64(state.num_events)},
        "base_version": np.asarray(state.base_version, np.int64),
        "events": ev,
        "params": state.params,
        # f32 codec: the bare (R, Np) matrix (existing checkpoints stay
        # byte-compatible); compressed codecs: a stamped dict of arrays
        # (ckpt.py keypath-flattens nested dicts)
        "ring": (dict(state.ring) if isinstance(state.ring, dict)
                 else np.asarray(state.ring, np.float32)),
        "behavior": dict(state.behavior),
        "dataset_rng": np.asarray(state.dataset_rng, np.uint64),
        "round_log": round_log_to_arrays(state.round_log),
        "history": history_to_arrays(state.history),
    }


def engine_state_from_tree(tree: Dict[str, Any]) -> EngineState:
    """Inverse of ``engine_state_to_tree``."""
    ev = np.asarray(tree["events"], np.float64).reshape(-1, 2)
    return EngineState(
        version=int(tree["meta"]["version"]),
        now=float(tree["meta"]["now"]),
        num_events=int(tree["meta"]["num_events"]),
        base_version=np.asarray(tree["base_version"], np.int64),
        events=tuple((float(t), int(c)) for t, c in ev),
        params=tree["params"],
        ring=(dict(tree["ring"]) if isinstance(tree["ring"], dict)
              else np.asarray(tree["ring"], np.float32)),
        behavior=dict(tree["behavior"]),
        dataset_rng=np.asarray(tree["dataset_rng"], np.uint64),
        history=history_from_arrays(tree["history"]),
        round_log=round_log_from_arrays(tree["round_log"]),
    )


@functools.lru_cache(maxsize=64)
def _make_chunk_step(loss_fn: Callable, fl: FLConfig,
                     mesh: Optional[Any] = None) -> Callable:
    """Compile S whole server rounds (K local trainings + eq. 3/4/5 each)
    into one ``lax.scan`` program; the version ring advances on-device.
    The round maths is the shared ``core/round_body.py`` implementation —
    the same body the compiled cohort step runs — wrapped in the ring
    gather/write; ``mesh`` shards it over (data, model) (DESIGN.md §5).
    Memoized on (loss_fn, fl, mesh) so repeated runs — benchmark sweeps,
    protocol comparisons — reuse the compiled program."""
    ring_round = make_ring_round(loss_fn, fl, mesh=mesh)

    @jax.jit
    def chunk_step(params, ring, base_slots, batches, probes, sizes, taus,
                   new_slots):
        def round_body(carry, xs):
            params, ring = carry
            slots, batch, probe, size, tau, new_slot = xs
            params, ring, info = ring_round(params, ring, slots, batch,
                                            probe, size, tau, new_slot)
            return (params, ring), info

        (params, ring), infos = jax.lax.scan(
            round_body, (params, ring),
            (base_slots, batches, probes, sizes, taus, new_slots))
        return params, ring, infos

    return chunk_step


def run_vectorized(loss_fn: Callable, init_params: Any, clients: Sequence,
                   fl: FLConfig, total_rounds: int,
                   eval_fn: Optional[Callable[[Any], Dict]] = None,
                   eval_every: int = 5,
                   latency: Optional[LatencyModel] = None,
                   seed: int = 0,
                   behavior: Optional[ClientBehavior] = None,
                   scenario: Optional[Scenario] = None,
                   trace: Optional[EventTrace] = None,
                   record_trace: bool = False,
                   rounds_per_launch: int = 8,
                   mesh: Optional[Any] = None,
                   shard_ring: bool = True,
                   init_state: Optional[EngineState] = None,
                   capture_state: bool = False,
                   registry: Optional[MetricsRegistry] = None,
                   tracer: Optional[Tracer] = None) -> SimResult:
    """Simulate buffered-async FL, many server rounds per XLA launch.

    Same contract as the legacy ``run_async`` plus scenario/trace hooks;
    behavior precedence: ``trace`` (replay) > ``behavior`` > ``scenario``
    > ``latency`` (plain lognormal population). ``rounds_per_launch``
    bounds how far ahead of the device the host event loop runs (launch
    chunks are additionally clipped to eval boundaries). ``mesh`` runs
    every round through the sharded substrate (DESIGN.md §5): the
    K-client vmap over the ``data`` axis, the flat-vector server pass
    over ``model``, with the params device-resident on the mesh and the
    version ring stored as flat-sharded rows (``init_version_ring``:
    R * n_padded / model_shards floats per device; ``shard_ring=False``
    replicates the rows instead — same program, parity-test reference);
    no mesh is the single-device path, bit-for-bit unchanged.

    A mesh spanning PROCESSES (``launch/multihost.py``, DESIGN.md §7)
    runs the same program multi-controller: every process executes this
    host loop on identical seeds (so per-round metadata agrees without
    communication), chunk inputs are placed replicated across processes,
    and the round log is read back from process-local addressable shards
    — ``jax.device_get`` is never issued on a non-addressable array.

    ``capture_state=True`` attaches an ``EngineState`` snapshot to
    ``SimResult.final_state``; passing it back as ``init_state`` (same
    loss/clients/config/seed) resumes the run BIT-identically to the
    uninterrupted one. ``total_rounds`` always counts from round 0, so a
    resume runs ``total_rounds - init_state.version`` more rounds.
    """
    n = len(clients)
    k = fl.buffer_size
    beh = resolve_behavior(n, seed, behavior, scenario, latency, trace)
    ring_depth = fl.max_staleness + 1
    spans = mesh_spans_processes(mesh)

    # ---- observability plane (DESIGN.md §9) ----------------------------
    # EVERY XLA dispatch of the round program goes through this one
    # wrapper, so the registry counter — the number the nightly
    # launch-count gate reads via SimResult.num_launches — cannot miss a
    # dispatch site the way a hand-maintained `num_launches += 1` could
    # (e.g. a future final-eval or warmup path calling chunk_step
    # directly). The histogram records host-side dispatch time only (no
    # block_until_ready: the engine deliberately runs ahead of the
    # device), so it measures the launch overhead the O(T/S) contract
    # bounds, not device compute.
    reg = registry if registry is not None else default_registry()
    tr = tracer if tracer is not None else NULL_TRACER
    _dispatches = reg.counter("engine_dispatches_total")
    _launch_hist = reg.histogram("engine_launch_seconds")
    _syncs = reg.counter("engine_host_syncs_total")
    _dispatches_start = _dispatches.value
    _raw_chunk_step = _make_chunk_step(loss_fn, fl, mesh)

    def chunk_step(*args):
        t0 = time.perf_counter()
        _dispatches.inc()
        out = _raw_chunk_step(*args)
        _launch_hist.observe(time.perf_counter() - t0)
        return out

    if init_state is None:
        params = init_params
        _, ring = init_version_ring(init_params, fl, mesh=mesh,
                                    shard_ring=shard_ring)
        version = 0
        base_version = np.zeros(n, np.int64)
        now = 0.0
        history: List[Dict] = []
        round_log_prefix: List[Dict] = []
        num_events = 0
        # every client starts training at t=0 (availability-gated) from v0
        events = []
        for cid in range(n):
            start = beh.next_start(cid, 0.0)
            events.append((start + beh.duration(cid, start), cid))
        heapq.heapify(events)
    else:
        if record_trace:
            raise ValueError(
                "record_trace cannot resume from a checkpoint: the duration "
                "draws before the snapshot are not in the restored state")
        if len(init_state.base_version) != n:
            raise ValueError(
                f"checkpoint has {len(init_state.base_version)} clients, "
                f"this run has {n}")
        beh.set_state(init_state.behavior)
        for c, row in zip(clients, init_state.dataset_rng):
            c.set_rng_state(row)
        params = init_state.params
        _, ring = init_version_ring(init_params, fl, mesh=mesh,
                                    shard_ring=shard_ring,
                                    rows=init_state.ring)
        version = init_state.version
        base_version = np.asarray(init_state.base_version, np.int64).copy()
        now = init_state.now
        history = [dict(h) for h in init_state.history]
        if eval_fn and history and history[-1]["round"] == version \
                and version % eval_every:
            # the snapshot run's trailing FORCED eval: off the cadence,
            # the uninterrupted run never evaluates here — drop it so
            # the resumed history matches bit-for-bit
            history.pop()
        round_log_prefix = [dict(r) for r in init_state.round_log]
        num_events = init_state.num_events
        events = [(float(t), int(c)) for t, c in init_state.events]
        heapq.heapify(events)
    if mesh is not None:
        # params live replicated on the mesh (the flat vector and the
        # K-client axis are re-partitioned inside the round's shard_maps)
        params = (put_replicated(params, mesh) if spans
                  else jax.device_put(params, jax.sharding.NamedSharding(
                      mesh, jax.sharding.PartitionSpec())))
    pending: List[Dict] = []  # per-round host metadata + device info handles
    event_log: List = []

    def maybe_eval(force=False):
        record_eval(history, eval_fn, version, now, params, eval_every,
                    force)

    def reschedule(cid, t):
        start = beh.next_start(cid, t)
        heapq.heappush(events, (start + beh.duration(cid, start), cid))

    def collect_window():
        """Pop exactly K accepted uploads; the host event loop runs ahead
        of the device, which is legal because upload times never depend
        on server state. Returns the stacked per-window arrays."""
        nonlocal num_events, now
        window: List = []  # (t, cid, base_version, tau)
        while len(window) < k:
            t, cid = heapq.heappop(events)
            num_events += 1
            # one atomic consume: the attempt's index AND its drop verdict
            upload_idx, lost = beh.next_upload(cid)
            if lost:
                # upload lost: client re-pulls the current model, retrains
                base_version[cid] = version
                reschedule(cid, t)
                continue
            bv = int(base_version[cid])
            if bv < version - fl.max_staleness:  # fell out of the ring
                bv = version  # resync: train from the current model, tau 0
                base_version[cid] = version
            window.append((t, cid, bv, version - bv))
            event_log.append((t, cid, upload_idx, version))
            if len(window) < k:
                # no aggregation yet: re-pull the CURRENT version and go
                base_version[cid] = version
                reschedule(cid, t)
        now = window[-1][0]  # the K-th upload triggers the aggregation
        # one vectorized gather per client for the M local-step batches
        # (ClientDataset.batches draws the same index stream as M
        # sequential .batch() calls); probes draw AFTER all train draws —
        # the aggregation-time order AsyncServer uses — so legacy parity
        # holds. The per-step Python loops this replaces were the host
        # bottleneck at large N.
        train = [clients[cid].batches(fl.batch_size, fl.local_steps)
                 for _, cid, _, _ in window]
        probes = [clients[cid].batch(fl.batch_size)
                  for _, cid, _, _ in window]  # eq.-4 probes, FIFO order
        return {
            "clients": [cid for _, cid, _, _ in window],
            "tau": [tau for _, _, _, tau in window],
            "t_trigger": window[-1][0],
            "cid_trigger": window[-1][1],
            "batches": tuple(np.stack([b[i] for b in train])
                             for i in range(2)),
            "probes": tuple(np.stack([p[i] for p in probes])
                            for i in range(2)),
            "base_slots": np.asarray([bv % ring_depth
                                      for _, _, bv, _ in window], np.int32),
            "sizes": np.asarray([clients[cid].size
                                 for _, cid, _, _ in window], np.float32),
        }

    if init_state is None:
        # a resumed run's round-0 (and any snapshot-round) eval is
        # already in the restored history
        maybe_eval(force=True)
    while version < total_rounds:
        # ---- clip the launch chunk to the next eval boundary ------------
        horizon = total_rounds - version
        if eval_fn:
            horizon = min(horizon, eval_every - version % eval_every)
        s = min(rounds_per_launch, horizon)

        # ---- host: pre-compute S windows of events ----------------------
        windows = []
        with tr.span(SPAN_COLLECT, rounds=s, version=version):
            for _ in range(s):
                w = collect_window()
                version += 1
                # window clients re-pull: the K-th gets the NEW version
                base_version[w["cid_trigger"]] = version
                reschedule(w["cid_trigger"], w["t_trigger"])
                windows.append(w)

        # ---- device: all S rounds in one scanned launch -----------------
        chunk_args = (
            np.stack([w["base_slots"] for w in windows]),
            tuple(np.stack([w["batches"][i] for w in windows])
                  for i in range(2)),
            tuple(np.stack([w["probes"][i] for w in windows])
                  for i in range(2)),
            np.stack([w["sizes"] for w in windows]),
            np.asarray([w["tau"] for w in windows], np.float32),
            np.asarray([(version - s + j + 1) % ring_depth
                        for j in range(s)], np.int32))
        if spans:
            # multi-controller: every process computed the SAME host
            # arrays (same seeds drive the event loop), so placing them
            # replicated across the process-spanning mesh needs no
            # communication — each process fills its shards locally
            chunk_args = put_replicated(chunk_args, mesh)
        with tr.span(SPAN_APPLY, rounds=s, version=version):
            params, ring, infos = chunk_step(params, ring, *chunk_args)
        # keep only the round-log metadata; the batch arrays would
        # otherwise pin O(total_rounds * K * batch) host memory
        pending.append({"windows": [{"clients": w["clients"], "tau": w["tau"]}
                                    for w in windows],
                        "v_end": version, "infos": infos})
        maybe_eval()
    maybe_eval(force=True)

    # ---- single device->host sync for the whole run's round log --------
    # On one host this is the classic ``jax.device_get``. On a
    # process-spanning mesh the info arrays are pinned fully replicated
    # (sharding/specs.info_pspec), so every process assembles the full
    # log from its own ADDRESSABLE shards — no ``device_get`` of a
    # non-addressable array, no cross-process collective (DESIGN.md §7).
    infos_list = [p.pop("infos") for p in pending]
    with tr.span(SPAN_HOST_SYNC, what="round_log", launches=len(infos_list)):
        _syncs.inc()
        if any(isinstance(leaf, jax.Array) and not leaf.is_fully_addressable
               for info in infos_list for leaf in jax.tree.leaves(info)):
            fetched = fetch_replicated(infos_list)
        else:
            fetched = jax.device_get(infos_list)
    round_log = list(round_log_prefix)
    for meta, logs in zip(pending, fetched):
        windows = meta["windows"]
        round_log.extend(round_log_rows(
            meta["v_end"] - len(windows), k,
            [w["clients"] for w in windows],
            [w["tau"] for w in windows], logs))
    trace_out = (EventTrace.from_behavior(beh, event_log)
                 if record_trace else None)
    final_state = None
    if capture_state:
        with tr.span(SPAN_CHECKPOINT, version=version):
            _syncs.inc()
            final_state = EngineState(
                version=version, now=now, num_events=num_events,
                base_version=base_version.copy(),
                events=tuple(sorted(events)),
                params=fetch_replicated(params),
                ring=ring_state_to_host(fl, fetch_replicated(ring)),
                behavior=beh.get_state(),
                dataset_rng=np.stack([c.rng_state() for c in clients]),
                history=[dict(h) for h in history],
                round_log=[dict(r) for r in round_log])
    return SimResult(history=history, server_rounds=version, sim_time=now,
                     round_log=round_log, num_events=num_events,
                     num_launches=int(_dispatches.value - _dispatches_start),
                     trace=trace_out, final_state=final_state)

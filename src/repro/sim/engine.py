"""Vectorized, device-resident asynchronous-FL simulation engine.

The legacy simulator (now ``sim/legacy.py``) walks the event heap one
upload at a time and dispatches one jitted ``local_update`` per client
event — O(K) XLA launches plus O(K) host round-trips per server round.
This engine exploits the FedBuff structure instead: the buffer drains
completely at every aggregation, so **every server round is exactly one
window of K uploads**, and within a window no aggregation happens until
the K-th upload. All K clients' local training therefore depends only on
state known at the window start, and the whole round compiles to ONE
program (``_make_chunk_step``, scanning the shared
``core/round_body.py`` implementation — the same body the compiled
cohort step runs, optionally mesh-sharded over (data, model) per
DESIGN.md §5):

    ring   (R, Np)   device-resident version ring (R = max_staleness + 1)
                     of padded FLAT parameter rows — sharded P(None,
                     "model") on a mesh, R * Np / model_shards per device
    bases  = ring[base_slots]                      # flat gather
    deltas = vmap(local_update)(unflatten(bases))  # K clients, one launch
    losses = vmap(loss(params, probe_k))           # eq. 4 probes
    x', info = apply_server_round(...)             # eq. 3 + 4 + 5
    ring'  = ring.at[slot(t+1)].set(x')            # flat write, no round-trip

Because a client's upload timeline never depends on server state (it
trains, uploads after a sampled duration, immediately re-pulls), the
host can walk the event heap **ahead of the device**: it pre-computes up
to ``rounds_per_launch`` windows of (batches, base slots, staleness,
probes) as stacked ``(S, K, ...)`` arrays and drives all S rounds
through one ``jax.lax.scan`` launch, the version ring advancing
on-device between rounds. The round log is fetched with a single
``jax.device_get`` at the end of the run, so a T-round simulation costs
O(T / rounds_per_launch) launches and O(1) log syncs instead of the
legacy O(T*K) launches and O(T) syncs. Launch chunks are clipped to
eval boundaries, so the eval cadence is identical to the legacy loop.

Event semantics match the legacy loop event-for-event on the scenarios
both can express (tested in tests/test_sim_engine.py): uploads are
processed in (time, client) heap order; a client that uploads without
triggering aggregation immediately re-pulls the *current* version; the
K-th client pulls the new version; bases older than the ring resync to
the current model with staleness 0. On top of that the engine supports
the behaviors the legacy loop cannot: availability gating, dropped
uploads (the client re-pulls and retrains; no delta is computed for the
lost upload), and trace replay (see sim/traces.py).
"""
from __future__ import annotations

import functools
import heapq
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.round_body import make_ring_round
from repro.core.server_pass import flatten_tree, make_flat_spec
from repro.sharding.specs import ring_pspec
from repro.sim.base import (  # noqa: F401  (re-exported for callers)
    SimResult,
    make_batches,
    record_eval,
    resolve_behavior,
)
from repro.sim.scenarios import ClientBehavior, LatencyModel, Scenario
from repro.sim.traces import EventTrace


def init_version_ring(init_params: Any, fl: FLConfig, *,
                      mesh: Optional[Any] = None, shard_ring: bool = True):
    """Build the device-resident version ring: (R, n_padded) f32 rows.

    Each of the R = max_staleness + 1 retained versions is one padded
    flat parameter vector on the ``make_flat_spec`` layout (DESIGN.md
    §6). With a mesh whose ``model`` axis has size m > 1 the ring is
    placed ``P(None, "model")`` — per device it costs
    ``R * n_padded / m`` floats instead of R full replicas.
    ``shard_ring=False`` keeps the same flat layout but replicates the
    rows (the bit-parity reference the multi-device tests pin against).
    Returns ``(spec, ring)``.
    """
    spec = make_flat_spec(init_params, fl.server_pass_block_n, mesh=mesh)
    ring_depth = fl.max_staleness + 1
    flat = flatten_tree(spec, init_params)
    ring = jnp.broadcast_to(flat[None], (ring_depth, spec.n_padded)) * 1
    if mesh is not None:
        pspec = (ring_pspec() if shard_ring and getattr(
            spec, "model_shards", 1) > 1 else jax.sharding.PartitionSpec())
        ring = jax.device_put(ring, jax.sharding.NamedSharding(mesh, pspec))
    return spec, ring


@functools.lru_cache(maxsize=64)
def _make_chunk_step(loss_fn: Callable, fl: FLConfig,
                     mesh: Optional[Any] = None) -> Callable:
    """Compile S whole server rounds (K local trainings + eq. 3/4/5 each)
    into one ``lax.scan`` program; the version ring advances on-device.
    The round maths is the shared ``core/round_body.py`` implementation —
    the same body the compiled cohort step runs — wrapped in the ring
    gather/write; ``mesh`` shards it over (data, model) (DESIGN.md §5).
    Memoized on (loss_fn, fl, mesh) so repeated runs — benchmark sweeps,
    protocol comparisons — reuse the compiled program."""
    ring_round = make_ring_round(loss_fn, fl, mesh=mesh)

    @jax.jit
    def chunk_step(params, ring, base_slots, batches, probes, sizes, taus,
                   new_slots):
        def round_body(carry, xs):
            params, ring = carry
            slots, batch, probe, size, tau, new_slot = xs
            params, ring, info = ring_round(params, ring, slots, batch,
                                            probe, size, tau, new_slot)
            return (params, ring), info

        (params, ring), infos = jax.lax.scan(
            round_body, (params, ring),
            (base_slots, batches, probes, sizes, taus, new_slots))
        return params, ring, infos

    return chunk_step


def run_vectorized(loss_fn: Callable, init_params: Any, clients: Sequence,
                   fl: FLConfig, total_rounds: int,
                   eval_fn: Optional[Callable[[Any], Dict]] = None,
                   eval_every: int = 5,
                   latency: Optional[LatencyModel] = None,
                   seed: int = 0,
                   behavior: Optional[ClientBehavior] = None,
                   scenario: Optional[Scenario] = None,
                   trace: Optional[EventTrace] = None,
                   record_trace: bool = False,
                   rounds_per_launch: int = 8,
                   mesh: Optional[Any] = None,
                   shard_ring: bool = True) -> SimResult:
    """Simulate buffered-async FL, many server rounds per XLA launch.

    Same contract as the legacy ``run_async`` plus scenario/trace hooks;
    behavior precedence: ``trace`` (replay) > ``behavior`` > ``scenario``
    > ``latency`` (plain lognormal population). ``rounds_per_launch``
    bounds how far ahead of the device the host event loop runs (launch
    chunks are additionally clipped to eval boundaries). ``mesh`` runs
    every round through the sharded substrate (DESIGN.md §5): the
    K-client vmap over the ``data`` axis, the flat-vector server pass
    over ``model``, with the params device-resident on the mesh and the
    version ring stored as flat-sharded rows (``init_version_ring``:
    R * n_padded / model_shards floats per device; ``shard_ring=False``
    replicates the rows instead — same program, parity-test reference);
    no mesh is the single-device path, bit-for-bit unchanged.
    """
    n = len(clients)
    k = fl.buffer_size
    beh = resolve_behavior(n, seed, behavior, scenario, latency, trace)
    ring_depth = fl.max_staleness + 1
    chunk_step = _make_chunk_step(loss_fn, fl, mesh)

    params = init_params
    _, ring = init_version_ring(init_params, fl, mesh=mesh,
                                shard_ring=shard_ring)
    if mesh is not None:
        # params live replicated on the mesh (the flat vector and the
        # K-client axis are re-partitioned inside the round's shard_maps)
        params = jax.device_put(params, jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()))
    version = 0
    base_version = np.zeros(n, np.int64)
    now = 0.0
    history: List[Dict] = []
    pending: List[Dict] = []  # per-round host metadata + device info handles
    event_log: List = []
    num_events = 0
    num_launches = 0

    # every client starts training at t=0 (availability-gated) from version 0
    events = []
    for cid in range(n):
        start = beh.next_start(cid, 0.0)
        events.append((start + beh.duration(cid, start), cid))
    heapq.heapify(events)

    def maybe_eval(force=False):
        record_eval(history, eval_fn, version, now, params, eval_every,
                    force)

    def reschedule(cid, t):
        start = beh.next_start(cid, t)
        heapq.heappush(events, (start + beh.duration(cid, start), cid))

    def collect_window():
        """Pop exactly K accepted uploads; the host event loop runs ahead
        of the device, which is legal because upload times never depend
        on server state. Returns the stacked per-window arrays."""
        nonlocal num_events, now
        window: List = []  # (t, cid, base_version, tau)
        while len(window) < k:
            t, cid = heapq.heappop(events)
            num_events += 1
            # one atomic consume: the attempt's index AND its drop verdict
            upload_idx, lost = beh.next_upload(cid)
            if lost:
                # upload lost: client re-pulls the current model, retrains
                base_version[cid] = version
                reschedule(cid, t)
                continue
            bv = int(base_version[cid])
            if bv < version - fl.max_staleness:  # fell out of the ring
                bv = version  # resync: train from the current model, tau 0
                base_version[cid] = version
            window.append((t, cid, bv, version - bv))
            event_log.append((t, cid, upload_idx, version))
            if len(window) < k:
                # no aggregation yet: re-pull the CURRENT version and go
                base_version[cid] = version
                reschedule(cid, t)
        now = window[-1][0]  # the K-th upload triggers the aggregation
        # one vectorized gather per client for the M local-step batches
        # (ClientDataset.batches draws the same index stream as M
        # sequential .batch() calls); probes draw AFTER all train draws —
        # the aggregation-time order AsyncServer uses — so legacy parity
        # holds. The per-step Python loops this replaces were the host
        # bottleneck at large N.
        train = [clients[cid].batches(fl.batch_size, fl.local_steps)
                 for _, cid, _, _ in window]
        probes = [clients[cid].batch(fl.batch_size)
                  for _, cid, _, _ in window]  # eq.-4 probes, FIFO order
        return {
            "clients": [cid for _, cid, _, _ in window],
            "tau": [tau for _, _, _, tau in window],
            "t_trigger": window[-1][0],
            "cid_trigger": window[-1][1],
            "batches": tuple(np.stack([b[i] for b in train])
                             for i in range(2)),
            "probes": tuple(np.stack([p[i] for p in probes])
                            for i in range(2)),
            "base_slots": np.asarray([bv % ring_depth
                                      for _, _, bv, _ in window], np.int32),
            "sizes": np.asarray([clients[cid].size
                                 for _, cid, _, _ in window], np.float32),
        }

    maybe_eval(force=True)
    while version < total_rounds:
        # ---- clip the launch chunk to the next eval boundary ------------
        horizon = total_rounds - version
        if eval_fn:
            horizon = min(horizon, eval_every - version % eval_every)
        s = min(rounds_per_launch, horizon)

        # ---- host: pre-compute S windows of events ----------------------
        windows = []
        for _ in range(s):
            w = collect_window()
            version += 1
            # window clients re-pull: the K-th gets the NEW version
            base_version[w["cid_trigger"]] = version
            reschedule(w["cid_trigger"], w["t_trigger"])
            windows.append(w)

        # ---- device: all S rounds in one scanned launch -----------------
        params, ring, infos = chunk_step(
            params, ring,
            np.stack([w["base_slots"] for w in windows]),
            tuple(np.stack([w["batches"][i] for w in windows])
                  for i in range(2)),
            tuple(np.stack([w["probes"][i] for w in windows])
                  for i in range(2)),
            np.stack([w["sizes"] for w in windows]),
            np.asarray([w["tau"] for w in windows], np.float32),
            np.asarray([(version - s + j + 1) % ring_depth
                        for j in range(s)], np.int32))
        num_launches += 1
        # keep only the round-log metadata; the batch arrays would
        # otherwise pin O(total_rounds * K * batch) host memory
        pending.append({"windows": [{"clients": w["clients"], "tau": w["tau"]}
                                    for w in windows],
                        "v_end": version, "infos": infos})
        maybe_eval()
    maybe_eval(force=True)

    # ---- single device->host sync for the whole run's round log --------
    fetched = jax.device_get([p.pop("infos") for p in pending])
    round_log = []
    for meta, logs in zip(pending, fetched):
        windows = meta["windows"]
        v0 = meta["v_end"] - len(windows)
        for j, w in enumerate(windows):
            round_log.append({
                "version": v0 + j + 1,
                "weights": logs["weights"][j].tolist(),
                "staleness_deg": logs["staleness"][j].tolist(),
                "stat_effect": logs["stat_effect"][j].tolist(),
                "sq_dists": logs["sq_dists"][j].tolist(),
                "tau": w["tau"],
                "clients": w["clients"],
                "k": k,
            })
    trace_out = (EventTrace.from_behavior(beh, event_log)
                 if record_trace else None)
    return SimResult(history=history, server_rounds=version, sim_time=now,
                     round_log=round_log, num_events=num_events,
                     num_launches=num_launches, trace=trace_out)

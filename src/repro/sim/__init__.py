"""Vectorized scenario-simulation engine (DESIGN.md §4).

``engine``     — device-resident windowed round engine (one XLA launch per
                 server round); the default behind ``repro.core.run_async``.
``population`` — fully device-resident client STATE machine (counter-based
                 RNG, vmapped behavior kernel, device top-k windows) for
                 million-client scenarios (DESIGN.md §10).
``scenarios``  — registry of named, composable client-behavior models.
``traces``     — record/replay of client timelines for exact reproducibility.
``metrics``    — staleness / participation / weight-entropy telemetry.
``legacy``     — the original per-event heapq loop (parity reference).
"""
from repro.sim import metrics  # noqa: F401
from repro.sim.arrivals import TrafficGenerator  # noqa: F401
from repro.sim.base import (  # noqa: F401
    SimResult,
    make_batches,
    resolve_behavior,
)
from repro.sim.engine import run_vectorized  # noqa: F401
from repro.sim.legacy import run_async_legacy, run_sync  # noqa: F401
from repro.sim.population import (  # noqa: F401
    CounterBehavior,
    CounterDataset,
    DevicePool,
    PopulationEngineState,
    collect_windows,
    make_counter_clients,
    population_state_from_tree,
    population_state_to_tree,
    run_population,
)
from repro.sim.scenarios import (  # noqa: F401
    ClientBehavior,
    LatencyModel,
    Scenario,
    get_scenario,
    register,
    registry,
)
from repro.sim.traces import EventTrace  # noqa: F401

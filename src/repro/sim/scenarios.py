"""Client-behavior scenarios for the simulation engine (DESIGN.md §4).

A ``Scenario`` is a declarative, composable description of how a federated
client population behaves — data heterogeneity (Dirichlet α wired to
``data/partition.py``), compute speed (lognormal tiers), availability
(diurnal phone-style duty cycles), upload loss (Bernoulli or trace-driven
dropouts), network (bandwidth-tiered upload latency), and adversarial
timing (straggler bursts). ``registry()`` exposes the named presets; any
field can be overridden with ``dataclasses.replace`` to compose new ones.

``ClientBehavior`` is the runtime object the engines consume. It holds one
seeded RNG stream **per client** so draw ``k`` for client ``i`` depends
only on ``(seed, i, k)`` — never on which protocol, engine, or buffer
size consumed it. That is what makes sync-vs-async (and paper-vs-FedBuff)
wall-clock comparisons fair: every run sees identical per-client
durations. Recorded draws round-trip through ``sim.traces`` so any
timeline can be replayed exactly.

The PCG64 streams here are host objects — O(N) Python state. For very
large populations the same scenario semantics run device-resident with
counter-based draws in ``sim/population.py`` (DESIGN.md §10);
``CounterBehavior`` subclasses ``ClientBehavior`` to consume those
counter streams through this module's interface for parity testing.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.utils.rngstate import pack_pcg64, unpack_pcg64


# ---------------------------------------------------------------------------
# latency model (moved from core/simulator.py; core re-exports for compat)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LatencyModel:
    """Per-client round duration = speed_factor * lognormal + comm."""

    speed_factors: np.ndarray  # (N,) multiplicative slowness per client
    base_mean: float = 1.0
    sigma: float = 0.25
    comm: float = 0.1

    @staticmethod
    def heterogeneous(num_clients: int, max_slowdown: float = 10.0,
                      seed: int = 0, **kw) -> "LatencyModel":
        rng = np.random.default_rng(seed)
        # log-uniform speed factors in [1, max_slowdown]
        f = np.exp(rng.uniform(0.0, np.log(max_slowdown), num_clients))
        return LatencyModel(speed_factors=np.sort(f), **kw)

    def sample(self, rng: np.random.Generator, client: int) -> float:
        """Legacy shared-stream draw (kept for launch/train.py schedules)."""
        dur = self.speed_factors[client] * rng.lognormal(
            mean=np.log(self.base_mean), sigma=self.sigma)
        return float(dur + self.comm)


# ---------------------------------------------------------------------------
# scenario description
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Declarative client-population behavior; compose via ``replace``."""

    name: str
    description: str = ""
    # --- data heterogeneity (wired to data/partition.dirichlet_partition) --
    alpha: Optional[float] = 0.2  # Dirichlet label-skew; None => IID
    # --- compute: per-client speed = tier_speed * logU[1, max_slowdown] ----
    compute_tiers: Tuple[float, ...] = (1.0,)  # multiplicative tier slowness
    max_slowdown: float = 10.0  # log-uniform spread within a tier
    base_mean: float = 1.0  # lognormal location of one local round
    sigma: float = 0.25  # lognormal shape
    # --- network: upload latency, one tier per client ----------------------
    comm_tiers: Tuple[float, ...] = (0.1,)  # seconds added per upload
    # --- availability: phone-style diurnal duty cycle ----------------------
    diurnal: bool = False
    diurnal_period: float = 24.0  # sim-time length of one "day"
    diurnal_duty: float = 0.5  # fraction of the day a client is online
    # --- dropouts ----------------------------------------------------------
    dropout_p: float = 0.0  # Bernoulli(p) chance an upload is lost
    dropout_trace: Tuple[Tuple[int, int], ...] = ()  # exact (client, k) drops
    # --- adversarial timing ------------------------------------------------
    burst_every: float = 0.0  # 0 = off; else a burst starts each period
    burst_len: float = 2.0  # sim-time length of one burst
    burst_factor: float = 10.0  # duration multiplier inside a burst
    burst_frac: float = 0.25  # fraction of clients hit per burst

    # ------------------------------------------------------------------
    def behavior(self, num_clients: int, seed: int = 0) -> "ClientBehavior":
        return ClientBehavior(self, num_clients, seed)

    def make_dataset(self, num_clients: int, samples_per_client: int = 300,
                     seed: int = 0, noise: float = 1.0):
        """Federated image dataset with this scenario's label skew.

        ``alpha=None`` (IID) uses a huge Dirichlet α, which the partition
        test shows converges to uniform label histograms.
        """
        from repro.data import make_federated_image_dataset
        alpha = 1e5 if self.alpha is None else self.alpha
        return make_federated_image_dataset(
            num_clients=num_clients, samples_per_client=samples_per_client,
            alpha=alpha, noise=noise, seed=seed)


# ---------------------------------------------------------------------------
# runtime behavior: per-client seeded streams (the fair-comparison RNG fix)
# ---------------------------------------------------------------------------


class ClientBehavior:
    """Samples one client population's timeline, one stream per client.

    The engines call, per upload attempt of client ``cid``:
      * ``next_start(cid, t)``   — availability gating (deterministic);
      * ``duration(cid, t)``     — compute + upload time (consumes draw k);
      * ``next_upload(cid)``     — atomically consume the next upload:
                                   its index k AND whether it is lost
                                   (separate drop stream, so dropout
                                   never shifts the duration draws).
    ``upload_index(cid)`` peeks the next index without consuming. All
    draws are recorded; ``drain_log()`` hands them to ``sim.traces``.
    """

    def __init__(self, scenario: Scenario, num_clients: int, seed: int = 0,
                 latency: Optional[LatencyModel] = None):
        self.scenario = scenario
        self.num_clients = int(num_clients)
        self.seed = int(seed)
        sc = scenario
        init = np.random.default_rng(seed)
        n = self.num_clients
        if latency is not None:  # honor an explicit legacy LatencyModel
            self.speed = np.asarray(latency.speed_factors, np.float64)
            self.base_mean = float(latency.base_mean)
            self.sigma = float(latency.sigma)
            self.comm = np.full(n, float(latency.comm))
        else:
            tiers = np.asarray(sc.compute_tiers, np.float64)
            tier_of = init.integers(0, len(tiers), size=n)
            spread = np.exp(init.uniform(0.0, np.log(max(sc.max_slowdown, 1.0 + 1e-9)), n))
            self.speed = np.sort(tiers[tier_of] * spread)
            self.base_mean = float(sc.base_mean)
            self.sigma = float(sc.sigma)
            comm_tiers = np.asarray(sc.comm_tiers, np.float64)
            self.comm = comm_tiers[init.integers(0, len(comm_tiers), size=n)]
        # diurnal phase offsets: where in the "day" each client wakes up
        self.phase = init.uniform(0.0, sc.diurnal_period, size=n)
        # one independent stream pair per client: durations / dropouts
        self._dur_rng = [np.random.default_rng(
            np.random.SeedSequence((self.seed, 101, cid))) for cid in range(n)]
        self._drop_rng = [np.random.default_rng(
            np.random.SeedSequence((self.seed, 202, cid))) for cid in range(n)]
        self._drop_trace = frozenset(tuple(e) for e in sc.dropout_trace)
        self._upload_idx = np.zeros(n, np.int64)  # k: next upload index
        self._durations: List[List[float]] = [[] for _ in range(n)]
        self._drops: List[Tuple[int, int]] = []
        # replay state (sim.traces.TraceBehavior wiring)
        self._replay_dur: Optional[List[List[float]]] = None
        self._replay_drops: Optional[frozenset] = None

    # -- construction helpers ------------------------------------------
    @staticmethod
    def from_latency(latency: LatencyModel, num_clients: int,
                     seed: int = 0) -> "ClientBehavior":
        """Plain lognormal population matching a legacy ``LatencyModel``."""
        sc = Scenario(name="latency-model", description="legacy LatencyModel")
        return ClientBehavior(sc, num_clients, seed, latency=latency)

    # -- availability ---------------------------------------------------
    def next_start(self, cid: int, t: float) -> float:
        """Earliest time >= t the client can start training (diurnal gate)."""
        sc = self.scenario
        if not sc.diurnal:
            return t
        period, on = sc.diurnal_period, sc.diurnal_duty * sc.diurnal_period
        local = (t - self.phase[cid]) % period
        if local < on:
            return t
        return t + (period - local)  # sleep until the next window opens

    # -- durations ------------------------------------------------------
    def duration(self, cid: int, t: float = 0.0) -> float:
        """One train+upload duration draw for client ``cid`` at time ``t``."""
        if self._replay_dur is not None:
            k = len(self._durations[cid])
            recorded = self._replay_dur[cid]
            if k >= len(recorded):
                raise RuntimeError(
                    f"trace exhausted: client {cid} has only {len(recorded)} "
                    f"recorded duration draws but draw {k} was requested — "
                    "record a longer run or lower total_rounds")
            dur = recorded[k]
        else:
            draw = self._dur_rng[cid].lognormal(
                mean=math.log(self.base_mean), sigma=self.sigma)
            dur = float(self.speed[cid] * draw * self._burst_mult(cid, t)
                        + self.comm[cid])
        self._durations[cid].append(dur)
        return dur

    def _burst_mult(self, cid: int, t: float) -> float:
        sc = self.scenario
        if sc.burst_every <= 0.0:
            return 1.0
        j = int(t // sc.burst_every)  # burst index
        if (t % sc.burst_every) >= sc.burst_len:
            return 1.0
        stride = max(1, int(round(1.0 / max(sc.burst_frac, 1e-9))))
        return sc.burst_factor if (cid + j) % stride == 0 else 1.0

    # -- uploads / dropouts ---------------------------------------------
    def upload_index(self, cid: int) -> int:
        """The index k of client ``cid``'s NEXT upload (peek, no advance).

        Dropped uploads consume an index too, so the stream k = 0, 1, ...
        identifies every upload attempt — the key ``sim.traces`` records
        drops and events under.
        """
        return int(self._upload_idx[cid])

    def next_upload(self, cid: int) -> Tuple[int, bool]:
        """Consume client ``cid``'s next upload: returns ``(k, dropped)``.

        The ONE public way the engines advance the upload stream — index
        sampling and the drop decision are atomic, so a caller can never
        read the index of one attempt and the drop verdict of another.
        """
        k = int(self._upload_idx[cid])
        self._upload_idx[cid] += 1
        if self._replay_drops is not None:
            hit = (cid, k) in self._replay_drops
        else:
            sc = self.scenario
            hit = (cid, k) in self._drop_trace
            if not hit and sc.dropout_p > 0.0:
                hit = bool(self._drop_rng[cid].random() < sc.dropout_p)
        if hit:
            self._drops.append((cid, k))
        return k, hit

    # -- trace wiring ---------------------------------------------------
    def drain_log(self) -> Dict:
        """Recorded draws, in per-client order (see sim.traces)."""
        return {"durations": [list(d) for d in self._durations],
                "drops": sorted(self._drops)}

    # -- checkpointing (engine resume; DESIGN.md §7) --------------------
    def get_state(self) -> Dict[str, np.ndarray]:
        """Snapshot the mutable stream state as plain arrays.

        Captures exactly what a resumed engine needs to continue the
        per-client streams where they left off: the upload indices, the
        per-client draw COUNTS (replay-mode behaviors index recorded
        durations by count), and the raw PCG64 generator states of the
        duration and dropout streams. The recorded-draw log itself is
        NOT captured — ``drain_log`` after a resume only covers the
        post-resume draws, which is why ``run_vectorized`` refuses
        ``record_trace`` on a resumed run.
        """
        return {
            "upload_idx": self._upload_idx.copy(),
            "draw_counts": np.asarray([len(d) for d in self._durations],
                                      np.int64),
            "dur_rng": pack_pcg64(self._dur_rng),
            "drop_rng": pack_pcg64(self._drop_rng),
        }

    def set_state(self, state: Dict[str, np.ndarray]) -> None:
        """Restore ``get_state``; the next draw of every stream matches
        what the snapshotted behavior would have drawn next."""
        n = self.num_clients
        upload_idx = np.asarray(state["upload_idx"], np.int64)
        if len(upload_idx) != n:
            raise ValueError(f"state has {len(upload_idx)} clients, "
                             f"behavior has {n}")
        self._upload_idx = upload_idx.copy()
        self._dur_rng = unpack_pcg64(state["dur_rng"])
        self._drop_rng = unpack_pcg64(state["drop_rng"])
        # placeholder entries so replay indexing (len of the draw log)
        # continues from the recorded count
        counts = np.asarray(state["draw_counts"], np.int64)
        self._durations = [[float("nan")] * int(c) for c in counts]
        self._drops = []


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Scenario] = {}


def register(sc: Scenario) -> Scenario:
    if sc.name in _REGISTRY:
        raise ValueError(f"scenario {sc.name!r} already registered")
    _REGISTRY[sc.name] = sc
    return sc


def registry() -> Dict[str, Scenario]:
    """Name -> Scenario for every registered preset (copy; mutate freely)."""
    return dict(_REGISTRY)


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {name!r}; known: {known}") from None


def _deterministic_drop_trace(num_clients: int = 64,
                              every: int = 5) -> Tuple[Tuple[int, int], ...]:
    """Fixed replayable dropout schedule: every ``every``-th upload of every
    third client is lost (a stand-in for a real-device trace file)."""
    return tuple((cid, k) for cid in range(0, num_clients, 3)
                 for k in range(every - 1, 50, every))


register(Scenario(
    name="iid-uniform",
    description="IID data, homogeneous devices, reliable network — the "
                "no-heterogeneity control where all weightings coincide.",
    alpha=None, max_slowdown=1.0, sigma=0.1))
register(Scenario(
    name="paper-fig1",
    description="The paper's §5 setting: Dirichlet α=0.2 label skew, "
                "10x log-uniform device speeds, all clients participating.",
    alpha=0.2, max_slowdown=10.0))
register(Scenario(
    name="dirichlet-mild",
    description="Mild label skew (α=1.0) with the paper's 10x speed spread.",
    alpha=1.0, max_slowdown=10.0))
register(Scenario(
    name="dirichlet-extreme",
    description="Extreme label skew (α=0.1): each client sees ~1-2 classes.",
    alpha=0.1, max_slowdown=10.0))
register(Scenario(
    name="compute-tiers",
    description="Three device tiers (flagship 1x / mid 4x / low-end 16x) "
                "with modest in-tier spread — FLGo-style system skew.",
    alpha=0.3, compute_tiers=(1.0, 4.0, 16.0), max_slowdown=2.0))
register(Scenario(
    name="diurnal-phones",
    description="Phones on a day/night duty cycle: each client trains only "
                "during its ~half of the day (staggered phases).",
    alpha=0.3, max_slowdown=4.0, diurnal=True,
    diurnal_period=24.0, diurnal_duty=0.5))
register(Scenario(
    name="dropout-bernoulli",
    description="Every upload lost independently with p=0.15 (flaky radio).",
    alpha=0.3, max_slowdown=4.0, dropout_p=0.15))
register(Scenario(
    name="dropout-trace",
    description="Trace-driven dropouts: a fixed replayable (client, upload) "
                "loss schedule, identical on every run.",
    alpha=0.3, max_slowdown=4.0,
    dropout_trace=_deterministic_drop_trace()))
register(Scenario(
    name="bandwidth-tiers",
    description="Upload latency tiers (fiber 0.05s / LTE 0.5s / 2G 2.5s): "
                "comm-bound stragglers instead of compute-bound ones.",
    alpha=0.3, max_slowdown=2.0, comm_tiers=(0.05, 0.5, 2.5)))
register(Scenario(
    name="straggler-burst",
    description="Adversarial timing: every 8 sim-seconds a 2s burst slows "
                "a rotating quarter of the fleet by 10x.",
    alpha=0.3, max_slowdown=2.0,
    burst_every=8.0, burst_len=2.0, burst_factor=10.0, burst_frac=0.25))

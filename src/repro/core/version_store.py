"""Codec-pluggable compressed version store (DESIGN.md §11).

The paper's eq. 3 staleness weighting needs the server to retain
R = max_staleness + 1 historical model versions. The engine stored them
as R full f32 flat rows — linear in model size, so R=16 of a 7B-param
model is ~450 GB even sharded. But eq. 3 only consumes *distances* to
ring entries (and clients pull bases that are immediately perturbed by M
local SGD steps), so the rows can be stored compressed. This module owns
that storage behind one interface; ``core/round_body.py::make_ring_round``
is codec-agnostic, ``sim/engine.py`` / ``sim/population.py`` carry the
codec state through scan/checkpoint, and every layer selects the codec
from ``FLConfig.ring_codec``.

Codecs (all on the ``make_flat_spec`` padded flat layout, DESIGN.md §6):

``f32``   identity — the pre-refactor (R, Np) f32 matrix, BIT-compatible:
          gather is ``ring[slots]``, write is ``ring.at[slot].set(row)``,
          and ``distance_sq`` defers to the server pass (returns None),
          so the engine compiles to the identical XLA program and every
          existing sharded/multihost/population parity pin holds.
``int8``  per-block affine quantization: int8 codewords + per-block f32
          (scale, zero) pairs, ``~(1 + 8/qblock) / 4`` of the f32 bytes
          (3.8x smaller at qblock=256). eq. 3 distances run through the
          fused dequantize-distance kernel (``kernels/ring_codec``) so
          the K decoded rows are never materialized.
``delta`` sparse residual against a periodically-refreshed f32 base
          snapshot: per row the top-m |residual| entries (m = density *
          Np) as (int32 idx, f32 val) pairs. Distances are EXACT via the
          expansion ||x - (base + s)||^2 = ||x - base||^2
          - 2<x - base, s> + ||s||^2 — one dense base pass plus O(m)
          gathers per row. Every ``ring_base_refresh`` writes the base
          snaps to the incoming row and retained rows re-encode against
          it (scanned row-at-a-time so no (R, Np) dense intermediate
          ever exists).

Checkpointing: a codec's device state round-trips through
``state_to_host`` / ``state_from_host`` as plain numpy (f32: the bare
(R, Np) matrix, unchanged on disk; compressed codecs: a dict of arrays
with a ``codec`` name stamp). Restore is codec-aware — a layout or
codec mismatch raises with the codec NAME and the expected layout, not
a bare shape pair.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.ring_codec import ops as _cops
from repro.kernels.ring_codec import ref as _cref
from repro.sharding.specs import (
    MODEL_AXIS,
    flat_param_pspec,
    ring_codes_pspec,
    ring_pspec,
    ring_scales_pspec,
)

CODECS = ("f32", "int8", "delta")


def resolve_qblock(spec, requested: int) -> int:
    """Largest power-of-two-ish divisor of the kernel tile <= requested.

    The quantization block must divide ``spec.block_n`` (so the fused
    kernel's scale columns align with its N-tiles) and therefore also
    ``n_padded`` and the per-shard slice. ``block_n`` is always a
    multiple of LANE=128, so halving from the requested size always
    terminates at a valid block.
    """
    qb = max(1, int(requested))
    while spec.block_n % qb:
        qb //= 2
    return max(qb, 1)


# ---------------------------------------------------------------------------
# codec states (NamedTuples: scan-carry and checkpoint friendly)
# ---------------------------------------------------------------------------


class Int8RingState(NamedTuple):
    """int8 codec device state: codewords + per-block affine params."""

    codes: jnp.ndarray  # (R, Np) int8
    scale: jnp.ndarray  # (R, Np // qblock) f32
    zero: jnp.ndarray  # (R, Np // qblock) f32


class DeltaRingState(NamedTuple):
    """delta codec device state: base snapshot + per-row sparse residual."""

    base: jnp.ndarray  # (Np,) f32 snapshot the residuals are against
    idx: jnp.ndarray  # (R, m) int32 residual positions
    val: jnp.ndarray  # (R, m) f32 residual values
    writes: jnp.ndarray  # () int32 ring-write counter (refresh schedule)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class F32Codec:
    """Identity codec — the pre-refactor ring, bit-for-bit."""

    name = "f32"
    precomputes_distance = False  # eq. 3 stays in the server pass

    def init_state(self, spec, flat0: jnp.ndarray, depth: int):
        # broadcast_to + * 1 materializes a writable copy (as before)
        return jnp.broadcast_to(flat0[None], (depth, spec.n_padded)) * 1

    def decode(self, spec, state, slots: jnp.ndarray) -> jnp.ndarray:
        return state[slots]

    def encode(self, spec, state, slot, row: jnp.ndarray):
        return state.at[slot].set(row)

    def distance_sq(self, spec, state, slots, x, *, mesh=None,
                    use_kernel=False, interpret=False):
        """None: the server pass computes eq. 3 from the decoded rows —
        the exact program that ran before the refactor (bit parity)."""
        return None

    def pspecs(self, spec) -> List[P]:
        return [ring_pspec()]

    def expected_layout(self, spec, depth: int) -> Dict[str, Tuple]:
        return {"ring": ((depth, spec.n_padded), "float32")}

    def device_bytes(self, spec, depth: int, model_shards: int = 1) -> int:
        per_shard_np = -(-spec.n_padded // model_shards)
        return depth * per_shard_np * 4

    def state_to_host(self, state) -> np.ndarray:
        return np.asarray(state, np.float32)

    def state_from_host(self, spec, depth: int, host):
        if isinstance(host, dict):
            raise ValueError(_codec_mismatch_msg(self, spec, depth, host))
        rows = np.asarray(host)
        if tuple(rows.shape) != (depth, spec.n_padded):
            raise ValueError(
                f"checkpointed f32 ring shape {tuple(rows.shape)} does not "
                f"match this run's layout "
                f"{_layout_str(self.expected_layout(spec, depth))} — same "
                "model/fl config (incl. ring_codec) required to resume")
        return jnp.asarray(rows, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Int8Codec:
    """Per-block affine int8 quantization (codewords + scale/zero)."""

    qblock: int = 256

    name = "int8"
    precomputes_distance = True

    def _qb(self, spec) -> int:
        return resolve_qblock(spec, self.qblock)

    def _nblocks(self, spec) -> int:
        return spec.n_padded // self._qb(spec)

    def _quant_row(self, spec, row: jnp.ndarray):
        """(Np,) f32 -> (codes (Np,) int8, scale (Nb,), zero (Nb,))."""
        qb = self._qb(spec)
        v = row.reshape(-1, qb)
        hi = jnp.max(v, axis=1)
        lo = jnp.min(v, axis=1)
        zero = 0.5 * (hi + lo)
        scale = (hi - lo) / 254.0  # symmetric range [-127, 127]
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.round((v - zero[:, None]) / safe[:, None])
        codes = jnp.clip(q, -127, 127).astype(jnp.int8)
        return codes.reshape(-1), scale, zero

    def init_state(self, spec, flat0: jnp.ndarray, depth: int):
        codes, scale, zero = self._quant_row(spec, flat0)
        rep = lambda a: jnp.broadcast_to(a[None], (depth,) + a.shape) * 1
        return Int8RingState(codes=rep(codes), scale=rep(scale),
                             zero=rep(zero))

    def decode(self, spec, state: Int8RingState, slots) -> jnp.ndarray:
        qb = self._qb(spec)
        return _cref.dequant_ref(state.codes[slots], state.scale[slots],
                                 state.zero[slots], qb)

    def encode(self, spec, state: Int8RingState, slot, row):
        codes, scale, zero = self._quant_row(spec, row)
        return Int8RingState(codes=state.codes.at[slot].set(codes),
                             scale=state.scale.at[slot].set(scale),
                             zero=state.zero.at[slot].set(zero))

    def distance_sq(self, spec, state: Int8RingState, slots, x, *,
                    mesh=None, use_kernel=False, interpret=False):
        """Fused dequantize-distance: the K decoded f32 rows are never
        materialized on the kernel path; under a model mesh each shard
        computes its partial and they meet in ONE psum (the same
        communication shape as the f32 server pass)."""
        qb = self._qb(spec)
        codes = state.codes[slots]
        scale = state.scale[slots]
        zero = state.zero[slots]
        shards = getattr(spec, "model_shards", 1)
        if mesh is None or shards <= 1:
            return _cops.int8_sq_dists(
                x, codes, scale, zero, qblock=qb, block_n=spec.block_n,
                use_kernel=use_kernel, interpret=interpret)

        def shard_body(x_s, c_s, s_s, z_s):
            part = _cops.int8_sq_dists(
                x_s, c_s, s_s, z_s, qblock=qb, block_n=spec.block_n,
                use_kernel=use_kernel, interpret=interpret)
            return jax.lax.psum(part, MODEL_AXIS)

        return shard_map(
            shard_body, mesh,
            in_specs=(flat_param_pspec(), P(None, MODEL_AXIS),
                      P(None, MODEL_AXIS), P(None, MODEL_AXIS)),
            out_specs=P(), check_rep=False)(x, codes, scale, zero)

    def pspecs(self, spec) -> List[P]:
        return [ring_codes_pspec(), ring_scales_pspec(),
                ring_scales_pspec()]

    def expected_layout(self, spec, depth: int) -> Dict[str, Tuple]:
        nb = self._nblocks(spec)
        return {"codes": ((depth, spec.n_padded), "int8"),
                "scale": ((depth, nb), "float32"),
                "zero": ((depth, nb), "float32")}

    def device_bytes(self, spec, depth: int, model_shards: int = 1) -> int:
        per_shard_np = -(-spec.n_padded // model_shards)
        per_shard_nb = -(-self._nblocks(spec) // model_shards)
        return depth * (per_shard_np + 2 * per_shard_nb * 4)

    def state_to_host(self, state: Int8RingState) -> Dict[str, np.ndarray]:
        return {"codec": np.asarray(self.name),
                "codes": np.asarray(state.codes, np.int8),
                "scale": np.asarray(state.scale, np.float32),
                "zero": np.asarray(state.zero, np.float32)}

    def state_from_host(self, spec, depth: int, host):
        arrays = _checked_host_dict(self, spec, depth, host)
        return Int8RingState(codes=jnp.asarray(arrays["codes"], jnp.int8),
                             scale=jnp.asarray(arrays["scale"], jnp.float32),
                             zero=jnp.asarray(arrays["zero"], jnp.float32))


@dataclasses.dataclass(frozen=True)
class DeltaCodec:
    """Sparse residual vs a periodically-refreshed f32 base snapshot."""

    density: float = 0.05
    base_refresh: int = 0  # 0 -> ring depth (one full lap)

    name = "delta"
    precomputes_distance = True

    def _m(self, spec) -> int:
        return max(1, min(spec.n_padded,
                          int(round(self.density * spec.n_padded))))

    def _refresh_every(self, depth: int) -> int:
        return self.base_refresh if self.base_refresh > 0 else depth

    def init_state(self, spec, flat0: jnp.ndarray, depth: int):
        m = self._m(spec)
        return DeltaRingState(
            base=flat0 * 1,
            idx=jnp.zeros((depth, m), jnp.int32),
            val=jnp.zeros((depth, m), jnp.float32),
            writes=jnp.zeros((), jnp.int32))

    def _scatter(self, n: int, idx: jnp.ndarray, val: jnp.ndarray):
        # duplicate indices only occur for the all-zero init rows, where
        # add keeps the scatter exact
        return jnp.zeros((n,), jnp.float32).at[idx].add(val)

    def decode(self, spec, state: DeltaRingState, slots) -> jnp.ndarray:
        idx = state.idx[slots]
        val = state.val[slots]
        sp = jax.vmap(lambda i, v: self._scatter(spec.n_padded, i, v))(idx,
                                                                       val)
        return state.base[None] + sp

    def encode(self, spec, state: DeltaRingState, slot, row):
        m = self._m(spec)

        def top_m(dense):
            mag, idx = jax.lax.top_k(jnp.abs(dense), m)
            idx = idx.astype(jnp.int32)
            return idx, dense[idx]

        def normal(st):
            idx, val = top_m(row - st.base)
            return DeltaRingState(base=st.base,
                                  idx=st.idx.at[slot].set(idx),
                                  val=st.val.at[slot].set(val),
                                  writes=st.writes)

        def refresh(st):
            # new base := the incoming row; every retained row re-encodes
            # against it. Row r's dense residual vs the new base is
            # (base_old - base_new) + scatter(idx_r, val_r) — rebuilt one
            # row at a time under lax.scan so the (R, Np) dense ring is
            # never materialized (the whole point of this codec).
            base_diff = st.base - row

            def per_row(carry, iv):
                idx_r, val_r = iv
                dense = base_diff.at[idx_r].add(val_r)
                return carry, top_m(dense)

            _, (idx, val) = jax.lax.scan(per_row, 0, (st.idx, st.val))
            # the slot being written IS the new base: residual exactly 0
            idx = idx.at[slot].set(jnp.zeros((m,), jnp.int32))
            val = val.at[slot].set(jnp.zeros((m,), jnp.float32))
            return DeltaRingState(base=row, idx=idx, val=val,
                                  writes=st.writes)

        every = self._refresh_every(state.idx.shape[0])
        do_refresh = jnp.mod(state.writes + 1, every) == 0
        new = jax.lax.cond(do_refresh, refresh, normal, state)
        return new._replace(writes=state.writes + 1)

    def distance_sq(self, spec, state: DeltaRingState, slots, x, *,
                    mesh=None, use_kernel=False, interpret=False):
        """EXACT eq. 3 distances without densifying the rows:
        ||x - (base + s_r)||^2 = ||x - base||^2 - 2<x - base, s_r>
        + ||s_r||^2 — one dense pass over the base plus O(m) gathers per
        row (the sparse rows never become (K, Np))."""
        xb = x - state.base
        idx = state.idx[slots]
        val = state.val[slots]
        d = (jnp.sum(xb * xb)
             - 2.0 * jnp.sum(xb[idx] * val, axis=1)
             + jnp.sum(val * val, axis=1))
        return jnp.maximum(d, 0.0)

    def pspecs(self, spec) -> List[P]:
        # base rides the flat-param layout; the sparse (idx, val) pairs
        # index the GLOBAL flat vector so they stay replicated (m is tiny
        # — density * Np entries vs Np per dense row), as do the scalars
        return [flat_param_pspec(), P(), P(), P()]

    def expected_layout(self, spec, depth: int) -> Dict[str, Tuple]:
        m = self._m(spec)
        return {"base": ((spec.n_padded,), "float32"),
                "idx": ((depth, m), "int32"),
                "val": ((depth, m), "float32"),
                "writes": ((), "int32")}

    def device_bytes(self, spec, depth: int, model_shards: int = 1) -> int:
        per_shard_np = -(-spec.n_padded // model_shards)
        return per_shard_np * 4 + depth * self._m(spec) * 8 + 4

    def state_to_host(self, state: DeltaRingState) -> Dict[str, np.ndarray]:
        return {"codec": np.asarray(self.name),
                "base": np.asarray(state.base, np.float32),
                "idx": np.asarray(state.idx, np.int32),
                "val": np.asarray(state.val, np.float32),
                "writes": np.asarray(state.writes, np.int32)}

    def state_from_host(self, spec, depth: int, host):
        arrays = _checked_host_dict(self, spec, depth, host)
        return DeltaRingState(
            base=jnp.asarray(arrays["base"], jnp.float32),
            idx=jnp.asarray(arrays["idx"], jnp.int32),
            val=jnp.asarray(arrays["val"], jnp.float32),
            writes=jnp.asarray(arrays["writes"], jnp.int32))


def resolve_codec(fl) -> Any:
    """The codec instance ``FLConfig.ring_codec`` selects."""
    if fl.ring_codec == "f32":
        return F32Codec()
    if fl.ring_codec == "int8":
        return Int8Codec(qblock=fl.ring_qblock)
    if fl.ring_codec == "delta":
        return DeltaCodec(density=fl.ring_delta_density,
                          base_refresh=fl.ring_base_refresh)
    raise ValueError(
        f"unknown ring_codec {fl.ring_codec!r}; valid: {CODECS}")


# ---------------------------------------------------------------------------
# codec-aware restore errors (the f32-only shape message predates codecs)
# ---------------------------------------------------------------------------


def _layout_str(layout: Dict[str, Tuple]) -> str:
    return "{" + ", ".join(f"{k}: {shape} {dtype}"
                           for k, (shape, dtype) in layout.items()) + "}"


def _codec_mismatch_msg(codec, spec, depth: int, host) -> str:
    if isinstance(host, dict):
        found = str(host.get("codec", "<unstamped dict>"))
    else:
        found = f"f32 matrix of shape {tuple(np.shape(host))}"
    return (f"checkpointed ring was written by codec {found!r} but this "
            f"run uses ring_codec={codec.name!r} expecting layout "
            f"{_layout_str(codec.expected_layout(spec, depth))} — resume "
            "with the SAME ring_codec (and model/fl config) it was "
            "checkpointed with")


def _checked_host_dict(codec, spec, depth: int, host) -> Dict[str, Any]:
    """Validate a compressed codec's host dict: codec stamp + exact layout."""
    if not isinstance(host, dict):
        raise ValueError(_codec_mismatch_msg(codec, spec, depth, host))
    stamp = host.get("codec")
    if stamp is not None and str(np.asarray(stamp)) != codec.name:
        raise ValueError(_codec_mismatch_msg(codec, spec, depth, host))
    layout = codec.expected_layout(spec, depth)
    for key, (shape, _) in layout.items():
        if key not in host:
            raise ValueError(
                f"checkpointed {codec.name!r} ring is missing field "
                f"{key!r}; expected layout {_layout_str(layout)}")
        got = tuple(np.shape(host[key]))
        if got != shape:
            raise ValueError(
                f"checkpointed {codec.name!r} ring field {key!r} has shape "
                f"{got}, expected {shape} (full layout "
                f"{_layout_str(layout)}) — same model/fl config (incl. "
                "ring codec parameters) required to resume")
    return host


# ---------------------------------------------------------------------------
# store construction + host round-trip (the engine/population entry points)
# ---------------------------------------------------------------------------

# provenance of the most recently built store, stamped into BENCH_*.json
# by benchmarks/common.run_metadata() (single-process benchmarking only —
# this is telemetry, not program state)
_LAST_BUILT: Dict[str, Any] = {"ring_codec": None,
                               "ring_bytes_per_device": None}


def ring_provenance() -> Dict[str, Any]:
    """{ring_codec, ring_bytes_per_device} of the last store built."""
    return dict(_LAST_BUILT)


def build_ring(init_params: Any, fl, *, mesh: Optional[Any] = None,
               shard_ring: bool = True, rows: Optional[Any] = None):
    """Build (or restore) the version store. Returns ``(spec, state)``.

    The codec-generalised ``sim/engine.py::init_version_ring`` (which now
    delegates here): ``state`` is the raw (R, Np) f32 matrix for the
    ``f32`` codec — bit-compatible with every pre-codec caller — and a
    codec NamedTuple otherwise. ``rows`` restores from the host
    representation ``ring_state_to_host`` produced; mismatches raise
    codec-aware errors naming the codec and its expected layout.
    """
    from repro.core.server_pass import flatten_tree, make_flat_spec
    from repro.launch.multihost import put_with_sharding

    spec = make_flat_spec(init_params, fl.server_pass_block_n, mesh=mesh)
    depth = fl.max_staleness + 1
    codec = resolve_codec(fl)
    if rows is None:
        state = codec.init_state(spec, flatten_tree(spec, init_params),
                                 depth)
    else:
        state = codec.state_from_host(spec, depth, rows)
    shards = getattr(spec, "model_shards", 1)
    if mesh is not None:
        pspecs = (codec.pspecs(spec) if shard_ring and shards > 1
                  else [P()] * len(jax.tree.leaves(state)))
        leaves, treedef = jax.tree.flatten(state)
        placed = [put_with_sharding(leaf, mesh, ps)
                  for leaf, ps in zip(leaves, pspecs)]
        state = jax.tree.unflatten(treedef, placed)
    _LAST_BUILT.update(
        ring_codec=codec.name,
        ring_bytes_per_device=codec.device_bytes(
            spec, depth, shards if (shard_ring and mesh is not None) else 1))
    return spec, state


def ring_state_to_host(fl, state) -> Any:
    """Device (already-fetched) ring state -> checkpointable host arrays.

    f32 keeps the bare (R, Np) f32 matrix (existing checkpoints and the
    ``EngineState.ring`` pins stay byte-compatible); compressed codecs
    produce a dict of arrays stamped with the codec name.
    """
    return resolve_codec(fl).state_to_host(state)


def ring_device_bytes(fl, spec, model_shards: int = 1) -> int:
    """Per-device bytes the ring costs under ``fl`` on ``spec``'s layout."""
    return resolve_codec(fl).device_bytes(spec, fl.max_staleness + 1,
                                          model_shards)

"""Secure-aggregation compatibility layer (additive pairwise masking).

The paper motivates buffered (K-client) asynchronous FL specifically
because it "is suitable to combine with the secured aggregation methods"
(§3) — unlike fully-async servers that see every update in the clear.
This module provides the Bonawitz-style additive-masking primitive over
parameter pytrees and shows how the contribution-aware weights compose
with it:

* every pair (i, j) of the K buffered clients derives a shared PRG seed;
  client i adds +PRG(seed_ij) for j > i and −PRG(seed_ij) for j < i to its
  (weighted) update — the masks cancel exactly in the server's sum;
* weights: S_i (eq. 3) is computed server-side from model versions (no
  client data needed) and P_i (eq. 4) is a single scalar upload, so the
  server can return w_i to each buffered client BEFORE upload; clients
  submit `w_i * Delta_i + mask_i` and the server only ever sees the
  weighted SUM — the individual update stays private. This two-phase
  exchange is the protocol variant recorded in DESIGN.md §10.

Dropout recovery (mask reconstruction for clients that fail mid-round) is
out of scope; the buffer simply re-queues their upload next round.
"""
from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_add


def _pair_seed(round_key, i: int, j: int):
    lo, hi = (i, j) if i < j else (j, i)
    return jax.random.fold_in(jax.random.fold_in(round_key, lo), hi)


def _mask_like(key, params: Any, scale: float):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    masked = [
        (jax.random.normal(k, l.shape, jnp.float32) * scale).astype(l.dtype)
        for k, l in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, masked)


def mask_update(round_key, update: Any, client_id: int,
                cohort_ids: Sequence[int], scale: float = 1.0) -> Any:
    """Add the pairwise-cancelling mask for ``client_id`` to ``update``."""
    masked = update
    for other in cohort_ids:
        if other == client_id:
            continue
        m = _mask_like(_pair_seed(round_key, client_id, other), update, scale)
        sign = 1.0 if client_id < other else -1.0
        masked = jax.tree.map(lambda u, mm: u + sign * mm, masked, m)
    return masked


def secure_sum(masked_updates: List[Any]) -> Any:
    """Server-side sum of masked updates == sum of raw updates.

    An empty cohort is a protocol error (the pairwise masks only cancel
    inside one complete K-buffer), so it raises instead of IndexError.
    """
    if not masked_updates:
        raise ValueError("secure_sum needs at least one masked update "
                         "(the buffer drained an empty cohort)")
    out = masked_updates[0]
    for u in masked_updates[1:]:
        out = tree_add(out, u)
    return out

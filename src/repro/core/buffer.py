"""Server-side K-buffer with model-version history (FedBuff structure)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class BufferEntry:
    client_id: int
    delta: Any  # pytree: cumulative local update Delta_i
    base_version: int  # global version the client trained from
    data_size: int  # N_i


class UpdateBuffer:
    """Accumulates client uploads; ready when K updates are buffered."""

    def __init__(self, k: int):
        self.k = int(k)
        self._entries: List[BufferEntry] = []

    def add(self, entry: BufferEntry) -> None:
        self._entries.append(entry)

    def ready(self) -> bool:
        return len(self._entries) >= self.k

    def drain(self) -> List[BufferEntry]:
        """Pop the first K entries (FIFO), keep any overflow buffered."""
        out, self._entries = self._entries[: self.k], self._entries[self.k:]
        return out

    def __len__(self) -> int:
        return len(self._entries)


class VersionHistory:
    """Ring of recent global-model snapshots for exact eq.-3 distances.

    Holds AT MOST ``max_versions`` snapshots: after ``put(version)`` the
    ring spans ``[version - max_versions + 1, version]``. Callers that
    need bases up to ``max_staleness`` rounds old must size the ring
    ``max_staleness + 1`` (current + that many predecessors).
    """

    def __init__(self, max_versions: int):
        if max_versions < 1:
            raise ValueError("max_versions must be >= 1")
        self.max_versions = int(max_versions)
        self._snaps: Dict[int, Any] = {}

    def put(self, version: int, params: Any) -> None:
        self._snaps[version] = params
        # keep the newest max_versions entries: floor at
        # version - max_versions + 1 (the old "- max_versions" floor
        # silently retained max_versions + 1 snapshots)
        floor = version - self.max_versions + 1
        for v in [v for v in self._snaps if v < floor]:
            del self._snaps[v]

    def get(self, version: int) -> Optional[Any]:
        return self._snaps.get(version)

    def oldest(self) -> int:
        """Oldest version still in the ring (fallback base for updates
        whose true snapshot was pruned: treated as max-stale)."""
        return min(self._snaps)

    def __contains__(self, version: int) -> bool:
        return version in self._snaps

from repro.core.aggregation import aggregate, aggregate_fused  # noqa: F401
from repro.core.buffer import BufferEntry, UpdateBuffer, VersionHistory  # noqa: F401
from repro.core.client import make_fresh_loss_fn, make_local_update_fn  # noqa: F401
from repro.core.cohort import (  # noqa: F401
    CohortState,
    DistFLState,
    init_cohort_state,
    init_dist_state,
    make_cohort_step,
    make_dist_step,
)
from repro.core.round_body import (  # noqa: F401
    make_ring_round,
    make_round_body,
    make_streaming_round_body,
)
from repro.core.server import AsyncServer, SyncServer  # noqa: F401
from repro.core.serving import (  # noqa: F401
    Admission,
    ServeConfig,
    ServingController,
    Upload,
    serve_stream,
)
from repro.core.server_pass import (  # noqa: F401
    FlatSpec,
    ShardedFlatSpec,
    apply_server_round,
    flatten_stacked,
    flatten_tree,
    make_flat_spec,
    make_server_pass,
    resolve_mode,
    unflatten_like,
    unflatten_stacked,
)
from repro.core.simulator import (  # noqa: F401
    LatencyModel,
    SimResult,
    run_async,
    run_async_legacy,
    run_sync,
    run_vectorized,
)
from repro.core.weighting import (  # noqa: F401
    FEDASYNC_POLICIES,
    POLICIES,
    contribution_weights,
    fedasync_discount,
    staleness_degree,
    statistical_effect,
)

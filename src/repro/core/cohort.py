"""Compiled production FL round — the paper's protocol as one SPMD program.

Two deployment mappings (DESIGN.md §2.1):

* **replicated-client** (archs whose replica fits one model-axis group):
  the ``data`` mesh axis carries C concurrent cohort slots (clients). State
  holds, per slot, the client's *current* local params and the *base*
  snapshot it last pulled — so eq. (3) staleness is computed EXACTLY
  (per-slot ``||x^t - base_i||^2`` full-model reductions), the fresh-loss
  probe (eq. 4) evaluates x^t on each client's probe batch, and the
  weighted delta reduction (eq. 5) is one masked psum over ``data``.
  Stragglers (arrival_mask=0) carry their local progress into the next
  round instead of contributing — identical semantics to the event-driven
  simulator, but fully compiled. The round maths itself lives in
  ``core/round_body.py`` — the SAME implementation the vectorized engine
  scans (DESIGN.md §5) — so engine==cohort agreement holds by
  construction; this module only adds the slot state machine (resync of
  arrivals, straggler carry-over, version bookkeeping).

* **distributed-client** (arctic-480b, qwen1.5-110b): one client spans the
  whole mesh (FSDP x TP). The K-buffer fills across sequential step calls
  with a *running weighted accumulator*: under mean-normalisation the
  eq.-3 min cancels (w_i / sum w_j is min-free), so only scalar buffers +
  one params-shaped accumulator are carried — the O(1)-memory streaming
  form of eq. (5). Staleness distances use the scalar update-norm ring
  (cross terms dropped; exact variant = simulator; agreement tested on
  small models).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.client import make_local_update_fn
from repro.core.round_body import make_round_body
from repro.utils.pytree import tree_sq_dist


# ---------------------------------------------------------------------------
# replicated-client cohort
# ---------------------------------------------------------------------------


class CohortState(NamedTuple):
    global_params: Any  # x^t (replicated over data, TP over model)
    client_params: Any  # (C, ...) current local state per slot
    client_base: Any  # (C, ...) base snapshot each slot pulled (eq. 3)
    client_version: jnp.ndarray  # (C,) int32 — version of that base
    version: jnp.ndarray  # scalar int32, t


def init_cohort_state(params: Any, cohort: int) -> CohortState:
    def stack():
        # distinct buffers per field: donation must never see aliased args
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cohort,) + x.shape) * 1,
            params)

    return CohortState(
        global_params=params,
        client_params=stack(),
        client_base=stack(),
        client_version=jnp.zeros((cohort,), jnp.int32),
        version=jnp.zeros((), jnp.int32),
    )


def make_cohort_step(loss_fn: Callable, fl: FLConfig, *,
                     mesh: Any = None) -> Callable:
    """Build the compiled replicated-client FL round.

    loss_fn(params, batch_dict) -> (scalar, metrics).
    Batch layout (C = cohort slots on the data axis):
      batch["local"] : leaves (C, M, b, ...) — M local steps per slot
      batch["probe"] : leaves (C, bp, ...)   — fresh-loss probe (eq. 4)
      batch["arrival"]: (C,) f32 {0,1}       — slots buffered this round
      batch["data_sizes"]: (C,) f32          — N_i

    ``mesh`` shards the C-slot vmap over ``data`` and the flat-vector
    server pass over ``model`` (core/round_body.py, DESIGN.md §5); with
    no mesh the step is the single-device program it always was.
    """
    round_body = make_round_body(loss_fn, fl, mesh=mesh)

    def step(state: CohortState, batch: Dict[str, Any]):
        arrival = batch["arrival"].astype(jnp.float32)
        tau = (state.version - state.client_version).astype(jnp.float32)

        # --- the paper's round: local training + eq. 3/4/5 (shared body) -
        new_global, end_params, info = round_body(
            state.global_params, state.client_base, batch["local"],
            batch["probe"], batch["data_sizes"], tau,
            client_params=state.client_params, arrival_mask=arrival)
        fresh, s, w = info["fresh_loss"], info["staleness"], info["weights"]

        # --- arrivals re-sync; stragglers keep their local progress ------
        def resync(stacked_new_src, stacked_old):
            def leaf(g, old):
                m = arrival.reshape((-1,) + (1,) * (old.ndim - 1))
                return jnp.where(m > 0, g[None].astype(old.dtype), old)
            return jax.tree.map(leaf, stacked_new_src, stacked_old)

        new_client_params = resync(new_global, end_params)
        new_client_base = resync(new_global, state.client_base)
        new_version = state.version + 1
        new_client_version = jnp.where(arrival > 0, new_version,
                                       state.client_version).astype(jnp.int32)

        metrics = {
            "fresh_loss_mean": jnp.mean(fresh),
            "staleness_min": jnp.min(s),
            "weights_max": jnp.max(w),
            "update_sq_norm": tree_sq_dist(state.global_params, new_global),
        }
        return CohortState(new_global, new_client_params, new_client_base,
                           new_client_version, new_version), metrics

    return step


# ---------------------------------------------------------------------------
# distributed-client (sequential buffer, streaming weighted accumulator)
# ---------------------------------------------------------------------------


class DistFLState(NamedTuple):
    global_params: Any  # x^t, FSDP x TP sharded
    accum: Any  # running sum v_i * Delta_i (params-shaped, f32)
    vsum: jnp.ndarray  # running sum v_i (scalar f32)
    count: jnp.ndarray  # updates buffered so far (int32)
    version: jnp.ndarray  # t (int32)
    update_norm_ring: jnp.ndarray  # (max_staleness,) ||u_s||^2 scalars


def init_dist_state(params: Any, fl: FLConfig) -> DistFLState:
    acc_dtype = jnp.dtype(fl.accum_dtype)
    return DistFLState(
        global_params=params,
        accum=jax.tree.map(lambda x: jnp.zeros(x.shape, acc_dtype), params),
        vsum=jnp.zeros((), jnp.float32),
        count=jnp.zeros((), jnp.int32),
        version=jnp.zeros((), jnp.int32),
        update_norm_ring=jnp.zeros((fl.max_staleness,), jnp.float32),
    )


def make_dist_step(loss_fn: Callable, fl: FLConfig) -> Callable:
    """One sequential buffer contribution + conditional server apply.

    Batch layout (single distributed client):
      batch["local"] : leaves (M, b, ...)
      batch["probe"] : leaves (bp, ...)
      batch["tau"]   : scalar int32 — simulated staleness in rounds
      batch["data_size"]: scalar f32
    """
    local_update = make_local_update_fn(loss_fn, fl.local_steps, fl.local_lr,
                                        fl.local_momentum)

    def step(state: DistFLState, batch: Dict[str, Any]):
        delta, _ = local_update(state.global_params, batch["local"])

        # eq. 4 probe
        fresh = loss_fn(state.global_params, batch["probe"])[0]
        p = batch["data_size"].astype(jnp.float32) * fresh.astype(jnp.float32)

        # eq. 3 distance via scalar update-norm ring (cross terms dropped)
        tau = jnp.minimum(batch["tau"], fl.max_staleness - 1)
        idx = jnp.arange(fl.max_staleness)
        recent = idx < tau  # ring[0] = newest
        d = jnp.sum(state.update_norm_ring * recent) + 1e-12

        # streaming weight v_i (mean-normalised at apply; min_j cancels)
        if fl.weighting == "paper":
            v = p * d
        elif fl.weighting == "multiplicative":
            v = p / d
        elif fl.weighting == "fedbuff":
            v = jnp.ones((), jnp.float32)
        else:  # polynomial / fedasync
            v = (1.0 + tau.astype(jnp.float32)) ** (-fl.poly_a)

        accum = jax.tree.map(
            lambda a, dl: a + (v * dl.astype(jnp.float32)).astype(a.dtype),
            state.accum, delta)
        vsum = state.vsum + v
        count = state.count + 1

        def apply_fn(st):
            accum_, vsum_, _ = st
            upd = jax.tree.map(lambda a: (fl.global_lr / jnp.maximum(vsum_, 1e-12)) * a,
                               accum_)
            new_params = jax.tree.map(lambda x, u: (x - u.astype(x.dtype)),
                                      state.global_params, upd)
            unorm = jnp.sum(jnp.stack([jnp.sum(jnp.square(u)) for u in
                                       jax.tree.leaves(upd)]))
            ring = jnp.concatenate([unorm[None], state.update_norm_ring[:-1]])
            zero_accum = jax.tree.map(jnp.zeros_like, accum_)
            return (new_params, zero_accum, jnp.zeros((), jnp.float32),
                    jnp.zeros((), jnp.int32), state.version + 1, ring)

        def hold_fn(st):
            accum_, vsum_, count_ = st
            return (state.global_params, accum_, vsum_, count_, state.version,
                    state.update_norm_ring)

        new_params, accum, vsum, count, version, ring = jax.lax.cond(
            count >= fl.buffer_size, apply_fn, hold_fn, (accum, vsum, count))

        metrics = {"fresh_loss": fresh, "v_weight": v, "buffered": count}
        return DistFLState(new_params, accum, vsum, count, version, ring), metrics

    return step

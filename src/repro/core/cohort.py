"""Compiled production FL round — the paper's protocol as one SPMD program.

Two deployment mappings (DESIGN.md §2.1):

* **replicated-client** (archs whose replica fits one model-axis group):
  the ``data`` mesh axis carries C concurrent cohort slots (clients). State
  holds, per slot, the client's *current* local params and the *base*
  snapshot it last pulled — so eq. (3) staleness is computed EXACTLY
  (per-slot ``||x^t - base_i||^2`` full-model reductions), the fresh-loss
  probe (eq. 4) evaluates x^t on each client's probe batch, and the
  weighted delta reduction (eq. 5) is one masked psum over ``data``.
  Stragglers (arrival_mask=0) carry their local progress into the next
  round instead of contributing — identical semantics to the event-driven
  simulator, but fully compiled. The round maths itself lives in
  ``core/round_body.py`` — the SAME implementation the vectorized engine
  scans (DESIGN.md §5) — so engine==cohort agreement holds by
  construction; this module only adds the slot state machine (resync of
  arrivals, straggler carry-over, version bookkeeping).

* **distributed-client** (arctic-480b, qwen1.5-110b): one client spans the
  whole mesh (FSDP x TP). The K-buffer fills across sequential step calls
  with a *running weighted accumulator* — the O(1)-memory streaming form
  of eq. (5), now implemented by ``core/round_body.py::
  make_streaming_round_body`` so all three deployment mappings share one
  round implementation. Per-upload weights run the SAME ``weighting.py``
  policy code as the exact paths (``s_min`` cap included) with the eq. 3
  reference pinned to the current model; staleness distances use the
  scalar update-norm ring (cross terms dropped; exact variant =
  simulator; parity tested on small models in tests/test_round_body.py).
  This module only keeps the buffer state machine.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.round_body import make_round_body, make_streaming_round_body
from repro.utils.pytree import tree_sq_dist


# ---------------------------------------------------------------------------
# replicated-client cohort
# ---------------------------------------------------------------------------


class CohortState(NamedTuple):
    global_params: Any  # x^t (replicated over data, TP over model)
    client_params: Any  # (C, ...) current local state per slot
    client_base: Any  # (C, ...) base snapshot each slot pulled (eq. 3)
    client_version: jnp.ndarray  # (C,) int32 — version of that base
    version: jnp.ndarray  # scalar int32, t


def init_cohort_state(params: Any, cohort: int) -> CohortState:
    def stack():
        # distinct buffers per field: donation must never see aliased args
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cohort,) + x.shape) * 1,
            params)

    return CohortState(
        global_params=params,
        client_params=stack(),
        client_base=stack(),
        client_version=jnp.zeros((cohort,), jnp.int32),
        version=jnp.zeros((), jnp.int32),
    )


def make_cohort_step(loss_fn: Callable, fl: FLConfig, *,
                     mesh: Any = None) -> Callable:
    """Build the compiled replicated-client FL round.

    loss_fn(params, batch_dict) -> (scalar, metrics).
    Batch layout (C = cohort slots on the data axis):
      batch["local"] : leaves (C, M, b, ...) — M local steps per slot
      batch["probe"] : leaves (C, bp, ...)   — fresh-loss probe (eq. 4)
      batch["arrival"]: (C,) f32 {0,1}       — slots buffered this round
      batch["data_sizes"]: (C,) f32          — N_i

    ``mesh`` shards the C-slot vmap over ``data`` and the flat-vector
    server pass over ``model`` (core/round_body.py, DESIGN.md §5); with
    no mesh the step is the single-device program it always was.
    """
    round_body = make_round_body(loss_fn, fl, mesh=mesh)

    def step(state: CohortState, batch: Dict[str, Any]):
        arrival = batch["arrival"].astype(jnp.float32)
        tau = (state.version - state.client_version).astype(jnp.float32)

        # --- the paper's round: local training + eq. 3/4/5 (shared body) -
        new_global, end_params, info = round_body(
            state.global_params, state.client_base, batch["local"],
            batch["probe"], batch["data_sizes"], tau,
            client_params=state.client_params, arrival_mask=arrival)
        fresh, s, w = info["fresh_loss"], info["staleness"], info["weights"]

        # --- arrivals re-sync; stragglers keep their local progress ------
        def resync(stacked_new_src, stacked_old):
            def leaf(g, old):
                m = arrival.reshape((-1,) + (1,) * (old.ndim - 1))
                return jnp.where(m > 0, g[None].astype(old.dtype), old)
            return jax.tree.map(leaf, stacked_new_src, stacked_old)

        new_client_params = resync(new_global, end_params)
        new_client_base = resync(new_global, state.client_base)
        new_version = state.version + 1
        new_client_version = jnp.where(arrival > 0, new_version,
                                       state.client_version).astype(jnp.int32)

        # round telemetry over ARRIVED slots only: zero-weight non-arrival
        # slots (stragglers) must not pollute the mins/means (jit-safe
        # where-reductions; an empty round reports neutral 0.0s)
        n_arr = jnp.sum(arrival)
        any_arr = n_arr > 0
        metrics = {
            "fresh_loss_mean": jnp.where(
                any_arr, jnp.sum(fresh * arrival) / jnp.maximum(n_arr, 1.0),
                0.0),
            "staleness_min": jnp.where(
                any_arr, jnp.min(jnp.where(arrival > 0, s, jnp.inf)), 0.0),
            "weights_max": jnp.where(
                any_arr, jnp.max(jnp.where(arrival > 0, w, -jnp.inf)), 0.0),
            "update_sq_norm": tree_sq_dist(state.global_params, new_global),
        }
        return CohortState(new_global, new_client_params, new_client_base,
                           new_client_version, new_version), metrics

    return step


# ---------------------------------------------------------------------------
# distributed-client (sequential buffer, streaming weighted accumulator)
# ---------------------------------------------------------------------------


class DistFLState(NamedTuple):
    global_params: Any  # x^t, FSDP x TP sharded
    accum: Any  # running sum v_i * Delta_i (params-shaped, f32)
    v_buf: jnp.ndarray  # (buffer_size,) per-slot scalar weights v_i
    count: jnp.ndarray  # updates buffered so far (int32)
    version: jnp.ndarray  # t (int32)
    update_norm_ring: jnp.ndarray  # (max_staleness,) ||u_s||^2 scalars


def init_dist_state(params: Any, fl: FLConfig) -> DistFLState:
    acc_dtype = jnp.dtype(fl.accum_dtype)
    return DistFLState(
        global_params=params,
        accum=jax.tree.map(lambda x: jnp.zeros(x.shape, acc_dtype), params),
        v_buf=jnp.zeros((fl.buffer_size,), jnp.float32),
        count=jnp.zeros((), jnp.int32),
        version=jnp.zeros((), jnp.int32),
        update_norm_ring=jnp.zeros((fl.max_staleness,), jnp.float32),
    )


def make_dist_step(loss_fn: Callable, fl: FLConfig) -> Callable:
    """One sequential buffer contribution + conditional server apply.

    A thin state machine over the shared streaming round body
    (``core/round_body.py::make_streaming_round_body``) — ALL weighting
    and eq. 5 arithmetic lives there; this wrapper only manages the
    buffer fill (v-slot write, count), the ``lax.cond`` apply/hold, and
    version bookkeeping.

    Batch layout (single distributed client):
      batch["local"] : leaves (M, b, ...)
      batch["probe"] : leaves (bp, ...)
      batch["tau"]   : scalar int32 — simulated staleness in rounds
      batch["data_size"]: scalar f32

    Metrics: ``buffered`` is the PRE-apply fill count (so the round that
    triggers the apply reports K, not 0) and ``applied`` is a {0,1} flag
    for whether this step flushed the buffer.
    """
    streaming = make_streaming_round_body(loss_fn, fl)

    def step(state: DistFLState, batch: Dict[str, Any]):
        accum, v, fresh = streaming.contribute(
            state.global_params, state.accum, state.update_norm_ring,
            batch["local"], batch["probe"],
            batch["data_size"].astype(jnp.float32), batch["tau"])
        v_buf = state.v_buf.at[state.count].set(v)
        count = state.count + 1

        def apply_fn(st):
            accum_, v_buf_, count_ = st
            new_params, ring = streaming.apply(
                state.global_params, accum_, v_buf_, count_,
                state.update_norm_ring)
            return (new_params, jax.tree.map(jnp.zeros_like, accum_),
                    jnp.zeros_like(v_buf_), jnp.zeros((), jnp.int32),
                    state.version + 1, ring)

        def hold_fn(st):
            accum_, v_buf_, count_ = st
            return (state.global_params, accum_, v_buf_, count_,
                    state.version, state.update_norm_ring)

        applied = count >= fl.buffer_size
        new_params, accum, v_buf, count, version, ring = jax.lax.cond(
            applied, apply_fn, hold_fn, (accum, v_buf, count))

        metrics = {"fresh_loss": fresh, "v_weight": v,
                   "buffered": state.count + 1,
                   "applied": applied.astype(jnp.int32)}
        return DistFLState(new_params, accum, v_buf, count, version,
                           ring), metrics

    return step

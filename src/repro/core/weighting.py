"""Contribution-aware update weighting — the paper's central equations.

eq. (3)  S_i^t = min_j ||x^t - x^{t-tau_j}||^2 / ||x^t - x^{t-tau_i}||^2
eq. (4)  P_i^t = N_i * (1/|zeta_i|) F_i(x^t, zeta_i)
eq. (5)  x_{t+1} = x_t - eta_g * (1/K) * sum_i (P_i^t / S_i^t) * Delta_i

Policies (see DESIGN.md §1.1 for the faithfulness discussion):
  paper          : w_i = P_i / max(S_i, s_min)          (eq. 5, literal)
  multiplicative : w_i = P_i * S_i                      (typo-corrected read)
  fedbuff        : w_i = 1                              (uniform — baseline [26])
  polynomial     : w_i = (1 + tau_i)^-a                 (staleness discount the
                                                         paper quotes, a=0.5)
  fedasync       : alias of polynomial (per-update mixing weight)

FedAsync staleness-discount family (Xie et al., arXiv:1903.03934; the
``s(tau)`` flags FLGo ships) — pure functions of the round staleness, so
they are exact under every deployment mapping including the streaming
serving path (DESIGN.md §8):
  fedasync_constant : w_i = 1
  fedasync_hinge    : w_i = 1 if tau <= b else 1 / (a * (tau - b))
  fedasync_poly     : w_i = (1 + tau_i)^-a  (== polynomial)

``normalize="mean"`` rescales weights to mean 1 so eq. 5's (1/K)*sum keeps
the global-update magnitude decoupled from raw loss scale; ``"none"`` is the
strictly literal form. All functions are jit-safe.
"""
from __future__ import annotations

import jax.numpy as jnp

FEDASYNC_POLICIES = ("fedasync_constant", "fedasync_hinge", "fedasync_poly")
POLICIES = ("paper", "multiplicative", "fedbuff", "polynomial",
            "fedasync") + FEDASYNC_POLICIES


def fedasync_discount(flag: str, tau_rounds: jnp.ndarray, *,
                      hinge_a: float = 10.0, hinge_b: float = 6.0,
                      poly_a: float = 0.5) -> jnp.ndarray:
    """FedAsync's ``s(tau)`` staleness discount, flags per FLGo.

    ``flag`` is one of ``constant`` / ``hinge`` / ``poly``; ``tau_rounds``
    is (K,) round staleness. The hinge denominator is floored so the
    boundary tau == b (where the discontinuous branch would divide by
    zero before ``where`` selects the constant side) stays finite.
    """
    tau = tau_rounds.astype(jnp.float32)
    if flag == "constant":
        return jnp.ones_like(tau)
    if flag == "hinge":
        return jnp.where(
            tau <= hinge_b, 1.0,
            1.0 / jnp.maximum(hinge_a * (tau - hinge_b), 1e-12))
    if flag == "poly":
        return (1.0 + tau) ** (-poly_a)
    raise ValueError(f"unknown fedasync flag {flag!r}; "
                     "valid: constant, hinge, poly")


def staleness_degree(sq_dists: jnp.ndarray, eps: float = 1e-12, *,
                     ref_sq_dist=None,
                     arrival_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """eq. (3). sq_dists: (K,) ||x^t - x^{base_i}||^2 >= 0. Returns (K,) in (0,1].

    A client whose base model equals the freshest base gets exactly 1.
    Degenerate all-zero distances (round 0: nobody is stale) => all ones.

    ``arrival_mask`` restricts the ``min_j`` reference to arrived (mask>0)
    slots: eq. 3's min is over BUFFERED clients, so an absent cohort slot
    that happens to hold the freshest base must not distort the arrived
    slots' staleness ratios. With no arrivals the reference falls back to
    ``max(d)`` (the weights are all masked to zero downstream anyway).

    ``ref_sq_dist`` replaces the ``min_j`` numerator with a fixed reference
    squared distance instead. The streaming entry shape
    (core/round_body.py, DESIGN.md §6) pins it to 0.0 — the current model
    itself — because the buffer-wide min is not known when an update is
    folded into the running accumulator; whenever the buffer holds a fresh
    (distance-0) update the two references coincide exactly.
    """
    d = jnp.maximum(sq_dists.astype(jnp.float32), 0.0)
    if ref_sq_dist is not None:
        m = jnp.asarray(ref_sq_dist, jnp.float32)
    elif arrival_mask is not None:
        # min over arrived slots; absent slots park on max(d) (>= any
        # arrived distance, so it never wins while any slot arrived)
        m = jnp.min(jnp.where(arrival_mask > 0, d, jnp.max(d)))
    else:
        m = jnp.min(d)
    s = (m + eps) / (d + eps)
    return jnp.clip(s, 0.0, 1.0)


def statistical_effect(batch_losses: jnp.ndarray, data_sizes: jnp.ndarray) -> jnp.ndarray:
    """eq. (4). batch_losses: (K,) mean per-sample loss of x^t on a fresh
    local mini-batch; data_sizes: (K,) N_i. Returns (K,)."""
    return data_sizes.astype(jnp.float32) * batch_losses.astype(jnp.float32)


def contribution_weights(policy: str,
                         p_stat: jnp.ndarray,
                         s_stale: jnp.ndarray,
                         tau_rounds: jnp.ndarray,
                         *,
                         s_min: float = 1e-3,
                         poly_a: float = 0.5,
                         hinge_a: float = 10.0,
                         hinge_b: float = 6.0,
                         normalize: str = "mean",
                         arrival_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Per-update aggregation weights w_i (before the 1/K of eq. 5).

    arrival_mask: optional (K,) {0,1} — cohort slots actually present in the
    buffer this round; masked-out slots get weight 0 and are excluded from
    the normalisation.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; valid: {POLICIES}")
    if policy == "paper":
        w = p_stat / jnp.maximum(s_stale, s_min)
    elif policy == "multiplicative":
        w = p_stat * s_stale
    elif policy == "fedbuff":
        w = jnp.ones_like(p_stat)
    elif policy in FEDASYNC_POLICIES:
        w = fedasync_discount(policy.split("_", 1)[1], tau_rounds,
                              hinge_a=hinge_a, hinge_b=hinge_b,
                              poly_a=poly_a)
    else:  # polynomial / fedasync
        w = (1.0 + tau_rounds.astype(jnp.float32)) ** (-poly_a)
    w = w.astype(jnp.float32)
    if arrival_mask is not None:
        mask = arrival_mask.astype(jnp.float32)
        w = w * mask
        denom_n = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom_n = jnp.asarray(w.shape[0], jnp.float32)
    if normalize == "mean":
        w = w * denom_n / jnp.maximum(jnp.sum(w), 1e-12)
    elif normalize != "none":
        raise ValueError(f"unknown normalize {normalize!r}")
    return w

"""Always-on FL serving controller (DESIGN.md §8).

Everything else in the repo is run-to-completion simulation; this module
is the first consumer of the round substrate as a *service*. It wraps
``core/round_body.py::make_streaming_round_body`` — the O(1)-state online
form of eq. 5 — in the three pieces a long-running aggregation endpoint
actually needs:

* **Admission control.** Uploads land in a bounded ingress queue. A full
  queue rejects with a ``retry_after`` backoff hint (the client re-offers
  the SAME update later, staler); queued updates whose staleness outgrows
  ``FLConfig.max_staleness`` are evicted oldest-first (their eq. 3 base
  fell out of the version ring, so folding them would be unweightable).
  Every rejection reason has its own counter — nothing is dropped
  silently.

* **Adaptive buffer size K.** The time to gather a K-buffer is ~K/λ for
  arrival rate λ, so a fixed K couples round cadence to traffic. The
  controller EWMA-estimates λ from admitted inter-arrival gaps and steers
  K toward ``K* = λ · target_round_latency`` with a damped proportional
  step every ``adapt_every`` rounds. The streaming accumulator makes K a
  pure control decision: the apply is triggered by a host-side count, no
  device state is shaped by K (the v-buffer is padded to ``k_max`` so the
  jitted apply compiles once).

* **Telemetry.** Sustained uploads folded/sec, round-latency quantiles
  (p50/p99), queue-depth high-water mark, per-reason rejection counts,
  and the K trajectory — the numbers ``benchmarks/bench_serve.py`` gates
  on. The counters live on an ``obs.metrics`` registry (DESIGN.md §9) —
  ``controller.counters`` and ``metrics()`` are stable views of it, so
  the historical dict shape is unchanged while the registry snapshot
  gives the JSONL sink / nightly diffing the same numbers with labeled
  series. An optional ``obs.trace.Tracer`` times the round lifecycle
  (``collect_window`` open -> K-th fold, ``contribute`` per fold,
  ``apply`` per round) as Chrome-trace spans.

Time is injected by the caller (``now``): the driver below runs on the
sim/ scenario clock so tests and CI are deterministic, while a real
deployment would pass wall-clock. Service cost is modeled by
``service_time`` (sim-time to fold one upload); with arrival rate above
``1/service_time`` the queue fills and backpressure engages — exactly
the regime the burst tests pin.

The weighting inherits the FULL policy zoo of ``core/weighting.py``,
including the FedAsync staleness-discount family
(``fedasync_constant`` / ``fedasync_hinge`` / ``fedasync_poly``), because
the streaming round body runs ``contribution_weights`` verbatim. Parity
of the served aggregate against the exact ``apply_server_round`` path is
pinned in tests/test_serving.py for every policy.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import (Any, Callable, Deque, Dict, List, Optional, Protocol,
                    Tuple, runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.round_body import make_streaming_round_body
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import (
    NULL_TRACER,
    SPAN_APPLY,
    SPAN_COLLECT,
    SPAN_CONTRIBUTE,
    Tracer,
)

# admission outcomes (Admission.reason values)
ADMITTED = "admitted"
REJECT_QUEUE_FULL = "queue_full"
DROP_MAX_STALENESS = "max_staleness"


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the serving loop (separate from the FL maths in FLConfig)."""

    queue_capacity: int = 64  # bounded ingress queue (admission control)
    service_time: float = 0.0  # sim-time to fold ONE upload into the accum
    target_round_latency: float = 2.0  # cadence the adaptive K steers toward
    k_min: int = 2  # adaptive-K clamp (floor keeps secure-agg viable)
    k_max: int = 64  # also the padded v-buffer length (one compile)
    adapt_every: int = 4  # rounds between K adjustments; 0 = fixed K
    adapt_gain: float = 0.5  # damping toward K* = lambda_hat * target
    arrival_ewma: float = 0.2  # EWMA factor of the inter-arrival estimate
    retry_after_min: float = 0.1  # floor on the advertised backoff
    # ceiling on the advertised backoff: the drain-time hint is linear in
    # queue depth, so without a clamp a deep queue at a slow modeled
    # service rate would tell a WALL-CLOCK client to sleep unboundedly
    retry_after_max: float = 30.0


# -- wire-able pytrees --------------------------------------------------
# Upload/Admission travel over the transport (DESIGN.md §12) as a
# JSON-able meta dict plus a flat {name: ndarray} tensor map; the byte
# encoding (framing, payload codec) lives in transport/wire.py so this
# module never learns about sockets. A pytree of arrays becomes a
# JSON-able skeleton whose leaves are {"__tensor__": name} references.

def tree_to_wire(prefix: str, tree: Any,
                 tensors: Dict[str, np.ndarray]) -> Any:
    """JSON-able skeleton of ``tree``; array leaves land in ``tensors``."""
    if isinstance(tree, dict):
        return {"__dict__": {k: tree_to_wire(f"{prefix}.{k}", v, tensors)
                             for k, v in sorted(tree.items())}}
    if isinstance(tree, (list, tuple)):
        return {"__tuple__": [tree_to_wire(f"{prefix}.{i}", v, tensors)
                              for i, v in enumerate(tree)]}
    arr = np.asarray(tree)
    tensors[prefix] = arr
    return {"__tensor__": prefix}


def tree_from_wire(skel: Any, tensors: Dict[str, np.ndarray]) -> Any:
    """Inverse of ``tree_to_wire`` (tuples come back as tuples)."""
    if "__tensor__" in skel:
        return tensors[skel["__tensor__"]]
    if "__dict__" in skel:
        return {k: tree_from_wire(v, tensors)
                for k, v in skel["__dict__"].items()}
    return tuple(tree_from_wire(v, tensors) for v in skel["__tuple__"])


@dataclasses.dataclass(frozen=True)
class Upload:
    """One client upload as the ingress queue holds it.

    The streaming mapping folds the local training server-side (the
    distributed-client entry shape), so the message carries the client's
    batches rather than a precomputed delta; ``base_version`` is the
    global version the client pulled, from which the controller derives
    staleness at FOLD time (it grows while the upload queues).

    Field-by-field (the wire schema mirrors these, DESIGN.md §12):

    * ``client_id`` — stable integer identity of the uploading client;
    * ``base_version`` — the global model version the client pulled and
      trained from (staleness = controller version - base_version);
    * ``data_size`` — |D_i|, the client's sample count (eq. 5 weight);
    * ``batch`` — (M, b, ...) stacked local-step batches, any pytree of
      arrays;
    * ``probe`` — (bp, ...) eq.-4 fresh-loss probe batch, any pytree;
    * ``sent_at`` — seconds on the SERVICE clock when the upload reached
      the endpoint (sim-seconds on the scenario clock, wall-clock
      seconds behind a real transport);
    * ``seq`` — client-local draw index of this upload (0, 1, 2, ...;
      a queue-full retry re-offers the SAME seq). Lets the loopback
      parity replay reconstruct a concurrent run's fold stream from
      seeded client datasets; -1 when the producer doesn't track it.
    """

    client_id: int
    base_version: int
    data_size: float
    batch: Any
    probe: Any
    sent_at: float
    seq: int = -1

    def to_wire(self) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
        """(JSON-able meta, flat tensor map) — transport/wire.py encodes
        these into one length-prefixed frame."""
        tensors: Dict[str, np.ndarray] = {}
        meta = {
            "client_id": int(self.client_id),
            "base_version": int(self.base_version),
            "data_size": float(self.data_size),
            "sent_at": float(self.sent_at),
            "seq": int(self.seq),
            "batch": tree_to_wire("batch", self.batch, tensors),
            "probe": tree_to_wire("probe", self.probe, tensors),
        }
        return meta, tensors

    @classmethod
    def from_wire(cls, meta: Dict[str, Any],
                  tensors: Dict[str, np.ndarray]) -> "Upload":
        return cls(client_id=int(meta["client_id"]),
                   base_version=int(meta["base_version"]),
                   data_size=float(meta["data_size"]),
                   batch=tree_from_wire(meta["batch"], tensors),
                   probe=tree_from_wire(meta["probe"], tensors),
                   sent_at=float(meta["sent_at"]),
                   seq=int(meta.get("seq", -1)))


@dataclasses.dataclass(frozen=True)
class Admission:
    """The admission-control verdict for one ``offer``.

    * ``accepted`` — True iff the upload entered the ingress queue;
    * ``reason`` — ADMITTED / REJECT_QUEUE_FULL / DROP_MAX_STALENESS;
    * ``retry_after`` — backoff hint in seconds on the SAME clock the
      caller passed as ``now`` (sim-seconds on the scenario clock,
      wall-clock seconds over a real transport); > 0 only for
      REJECT_QUEUE_FULL, and clamped to ``ServeConfig.retry_after_max``
      so a wall-clock client never sleeps unboundedly on a deep queue.
    """

    accepted: bool
    reason: str
    retry_after: float

    def to_wire(self) -> Dict[str, Any]:
        return {"accepted": bool(self.accepted), "reason": self.reason,
                "retry_after": float(self.retry_after)}

    @classmethod
    def from_wire(cls, meta: Dict[str, Any]) -> "Admission":
        return cls(accepted=bool(meta["accepted"]),
                   reason=str(meta["reason"]),
                   retry_after=float(meta["retry_after"]))


@runtime_checkable
class AggregatorService(Protocol):
    """The aggregation endpoint, as its CLIENTS see it (DESIGN.md §12).

    Three methods, deliberately transport-shaped: they are exactly the
    RPCs of the wire schema, so the in-process twin (``sim/arrivals.py``
    driving a ``ServingController`` directly — the deterministic CI
    path) and the socket path (``transport/client.py::RemoteAggregator``
    speaking to ``transport/server.py``) are interchangeable behind one
    type. ``core/serving.py`` never learns about sockets; the transport
    never learns about folding.

    * ``offer(upload, now)`` — submit one upload for admission; ``now``
      is the caller's clock reading (sim or wall seconds — whatever
      clock the service runs on);
    * ``pull()`` — ``(version, params)`` of the CURRENT served model
      (the client trains from this and stamps ``base_version``);
    * ``snapshot()`` — the service's metrics dict (telemetry only; no
      aggregation state).
    """

    def offer(self, upload: Upload, now: float) -> Admission: ...

    def pull(self) -> Tuple[int, Any]: ...

    def snapshot(self) -> Dict[str, Any]: ...


class ServingController:
    """Admission control + adaptive-K state machine over the streaming round.

    Host-side object: the queue, counters, and the K decision live on the
    host; the two jitted programs (``contribute`` folding one upload,
    ``apply`` completing eq. 5) each compile exactly once because every
    device-side shape — params, accumulator, the (k_max,) v-buffer, the
    (max_staleness,) update-norm ring — is independent of the current K.

    This is the in-process implementation of ``AggregatorService``
    (``offer`` / ``pull`` / ``snapshot``); the socket path wraps it
    without subclassing (transport/server.py).

    **Thread-safety contract (DESIGN.md §12).** One internal lock
    (``self._lock``) guards every piece of state shared between admission
    and folding: the ingress queue, ``version``, ``params``, counters,
    and the arrival estimator. Under it:

    * ``offer`` / ``pull`` / ``snapshot`` are safe to call from ANY
      thread (the transport's per-connection workers call them
      concurrently);
    * ``pump`` must only ever run on ONE thread — the aggregator thread.
      The fold state it owns (``accum``, ``v_buf``, ``count``,
      ``busy_until``, the tracer round bookkeeping) is single-owner by
      design: folding stays on one thread so the jit-once ``contribute``
      / ``apply`` programs are never raced and eq. 5's accumulation
      order is the arrival order, deterministically. ``pump`` takes the
      lock per fold iteration (not for its whole run), so admission
      stays live while a long round folds.

    The sim path (serve_stream) is single-threaded and pays only the
    uncontended-lock cost.
    """

    def __init__(self, loss_fn: Callable, init_params: Any, fl: FLConfig,
                 cfg: ServeConfig = ServeConfig(),
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        if cfg.k_min < 1 or cfg.k_max < cfg.k_min:
            raise ValueError(f"need 1 <= k_min <= k_max, got "
                             f"[{cfg.k_min}, {cfg.k_max}]")
        if cfg.queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")
        self.fl = fl
        self.cfg = cfg
        body = make_streaming_round_body(loss_fn, fl)

        def contribute_step(params, accum, ring, v_buf, count, batch, probe,
                            size, tau):
            accum, v, fresh = body.contribute(params, accum, ring, batch,
                                              probe, size, tau)
            return accum, v_buf.at[count].set(v), v, fresh

        self._contribute = jax.jit(contribute_step)
        self._apply = jax.jit(body.apply)

        acc_dtype = jnp.dtype(fl.accum_dtype)
        self.params = init_params
        self.accum = jax.tree.map(
            lambda x: jnp.zeros(x.shape, acc_dtype), init_params)
        self.v_buf = jnp.zeros((cfg.k_max,), jnp.float32)
        self.update_norm_ring = jnp.zeros((fl.max_staleness,), jnp.float32)
        self.count = 0  # uploads folded into the open round
        self.version = 0  # global rounds applied
        self.k = int(np.clip(fl.buffer_size, cfg.k_min, cfg.k_max))

        self.queue: Deque[Upload] = collections.deque()
        self.busy_until = 0.0  # service-model clock (sim-time)
        # the single lock of the thread-safety contract (class docstring)
        self._lock = threading.RLock()
        # transport hook: called as fold_hook(upload, tau) right after an
        # upload folds — the fold JOURNAL the loopback parity replay
        # consumes (launch/serve_fl.py --journal-out). None = disabled.
        self.fold_hook: Optional[Callable[[Upload, int], None]] = None
        # private registry by default: two controllers in one process must
        # not alias series (pass a shared registry to aggregate instead)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._counters = {
            "admitted": self.registry.counter("serve_admitted_total"),
            "rejected_queue_full": self.registry.counter(
                "serve_rejected_total", reason="queue_full"),
            "dropped_stale_ingress": self.registry.counter(
                "serve_dropped_total", reason="stale_ingress"),
            "dropped_stale_queue": self.registry.counter(
                "serve_dropped_total", reason="stale_queue"),
            "folded": self.registry.counter("serve_folded_total"),
            "rounds": self.registry.counter("serve_rounds_total"),
        }
        self._queue_depth = self.registry.gauge("serve_queue_depth")
        self._k_gauge = self.registry.gauge("serve_k")
        self._k_gauge.set(self.k)
        # compressed-ring config (DESIGN.md §11): the streaming path's
        # eq. 3 state is the (max_staleness,) SCALAR update-norm ring —
        # O(R) bytes independent of model size, so the codec changes
        # nothing here; the active codec + ring bytes are exported as
        # registry series so serving telemetry stays comparable with
        # engine runs of the same FLConfig
        self.ring_codec = fl.ring_codec
        self.registry.gauge("serve_update_norm_ring_bytes",
                            codec=fl.ring_codec).set(
            float(self.update_norm_ring.nbytes))
        self._latency_hist = self.registry.histogram(
            "serve_round_latency_seconds")
        self._round_wall_open: Optional[float] = None  # tracer clock
        self.round_latencies: List[float] = []
        self.round_times: List[float] = []  # apply completion times
        self.k_history: List[Tuple[int, int]] = [(0, self.k)]
        self.queue_depth_max = 0
        self._round_open_at: Optional[float] = None
        self._interarrival: Optional[float] = None
        self._last_arrival: Optional[float] = None

    @property
    def counters(self) -> Dict[str, int]:
        """The historical counter dict, now a VIEW of the obs registry —
        same keys, same values, pinned by tests/test_obs.py parity."""
        return {k: int(c.value) for k, c in self._counters.items()}

    # -- admission control ---------------------------------------------
    def staleness(self, upload: Upload) -> int:
        return self.version - upload.base_version

    def _evict_stale(self) -> None:
        """Drop-oldest: head entries whose base outgrew the version ring."""
        while self.queue and self.staleness(self.queue[0]) > \
                self.fl.max_staleness:
            self.queue.popleft()
            self._counters["dropped_stale_queue"].inc()

    def _retry_after(self) -> float:
        """Backoff hint: the time to drain the current queue at the modeled
        service rate — floored so zero-cost services still spread retries,
        and CLAMPED to ``retry_after_max`` so a deep queue never advertises
        an unbounded sleep to a wall-clock client (Admission docstring)."""
        return min(self.cfg.retry_after_max,
                   max(self.cfg.retry_after_min,
                       len(self.queue) * self.cfg.service_time))

    def offer(self, upload: Upload, now: float) -> Admission:
        """Admit one upload into the bounded ingress queue.

        Safe from any thread (AggregatorService contract)."""
        with self._lock:
            self._evict_stale()
            if self.staleness(upload) > self.fl.max_staleness:
                self._counters["dropped_stale_ingress"].inc()
                return Admission(False, DROP_MAX_STALENESS, 0.0)
            if len(self.queue) >= self.cfg.queue_capacity:
                self._counters["rejected_queue_full"].inc()
                return Admission(False, REJECT_QUEUE_FULL,
                                 self._retry_after())
            self.queue.append(upload)
            self._counters["admitted"].inc()
            self._queue_depth.set(len(self.queue))
            self.queue_depth_max = max(self.queue_depth_max, len(self.queue))
            self._observe_arrival(now)
            return Admission(True, ADMITTED, 0.0)

    def pull(self) -> Tuple[int, Any]:
        """``(version, params)`` of the CURRENT served model — the model-
        pull RPC of AggregatorService. Safe from any thread; the pair is
        read atomically under the lock so a client never sees version N
        with version N-1's params."""
        with self._lock:
            return self.version, self.params

    def snapshot(self) -> Dict[str, Any]:
        """AggregatorService telemetry: ``metrics()`` read under the lock."""
        with self._lock:
            return self.metrics()

    def _observe_arrival(self, now: float) -> None:
        if self._last_arrival is not None:
            gap = max(now - self._last_arrival, 1e-9)
            a = self.cfg.arrival_ewma
            self._interarrival = (gap if self._interarrival is None
                                  else (1.0 - a) * self._interarrival + a * gap)
        self._last_arrival = now

    def arrival_rate(self) -> float:
        """EWMA admitted uploads per sim-second (0 before two arrivals)."""
        return 0.0 if not self._interarrival else 1.0 / self._interarrival

    # -- service + aggregation -----------------------------------------
    def pump(self, now: float) -> int:
        """Fold queued uploads whose service completes by ``now``; run the
        eq. 5 apply whenever the open round reaches K. Returns the number
        of rounds applied.

        Single-owner: only the aggregator thread may call this (class
        docstring). The lock is taken per fold so concurrent ``offer``
        calls interleave between folds rather than stalling for a round.
        """
        rounds = 0
        while True:
            with self._lock:
                if self.count >= self.k:  # also catches K adapted downward
                    self._apply_round(max(self.busy_until, now))
                    rounds += 1
                    continue
                if not self.queue:
                    break
                done = max(self.busy_until,
                           now if self.cfg.service_time == 0.0
                           else self.queue[0].sent_at) + self.cfg.service_time
                if self.cfg.service_time > 0.0 and done > now:
                    break  # the server is still busy; leave the rest queued
                upload = self.queue.popleft()
                tau = self.staleness(upload)
                if tau > self.fl.max_staleness:  # out-aged while queued
                    self._counters["dropped_stale_queue"].inc()
                    continue
                with self.tracer.span(SPAN_CONTRIBUTE,
                                      client=upload.client_id, tau=tau):
                    self.accum, self.v_buf, _, _ = self._contribute(
                        self.params, self.accum, self.update_norm_ring,
                        self.v_buf, jnp.int32(self.count), upload.batch,
                        upload.probe, jnp.float32(upload.data_size),
                        jnp.int32(tau))
                self.busy_until = done
                if self.count == 0:
                    self._round_open_at = upload.sent_at
                    if self._round_wall_open is None:  # first-ever round
                        self._round_wall_open = self.tracer.now()
                self.count += 1
                self._counters["folded"].inc()
                if self.fold_hook is not None:
                    self.fold_hook(upload, tau)
        with self._lock:
            self._queue_depth.set(len(self.queue))
        return rounds

    def _apply_round(self, t_done: float) -> None:
        # the whole collect window is one retroactive span. It opens when
        # the PREVIOUS apply finished (the server is collecting from that
        # instant, even before the first fold lands), so collect_window +
        # apply spans tile the full round wall-time — the property the
        # trace-coverage acceptance gate (>= 95%) checks.
        apply_start = self.tracer.now()
        if self._round_wall_open is not None:
            self.tracer.complete(SPAN_COLLECT, self._round_wall_open,
                                 apply_start - self._round_wall_open,
                                 version=self.version, k=self.count)
        with self.tracer.span(SPAN_APPLY, version=self.version,
                              k=self.count):
            self.params, self.update_norm_ring = self._apply(
                self.params, self.accum, self.v_buf, jnp.int32(self.count),
                self.update_norm_ring)
            # the accumulator reset is part of completing the round: keep
            # it inside the apply span so spans tile the round wall-time
            self.accum = jax.tree.map(jnp.zeros_like, self.accum)
            self.v_buf = jnp.zeros_like(self.v_buf)
        self.count = 0
        self.version += 1
        self._counters["rounds"].inc()
        open_at = self._round_open_at if self._round_open_at is not None \
            else t_done
        # clamped: over a live transport an upload can land DURING the
        # pump loop with sent_at later than the loop's ``now`` — the true
        # latency is sub-poll-interval, not negative
        lat = max(0.0, t_done - open_at)
        self.round_latencies.append(lat)
        self._latency_hist.observe(lat)
        self.round_times.append(t_done)
        self._round_open_at = None
        self._round_wall_open = self.tracer.now()  # next window opens now
        if self.cfg.adapt_every and \
                self._counters["rounds"].value % self.cfg.adapt_every == 0:
            self._adapt_k()

    def _adapt_k(self) -> None:
        """Damped proportional step toward K* = lambda_hat * target."""
        lam = self.arrival_rate()
        if lam <= 0.0:
            return
        k_star = lam * self.cfg.target_round_latency
        g = self.cfg.adapt_gain
        new_k = int(np.clip(round((1.0 - g) * self.k + g * k_star),
                            self.cfg.k_min, self.cfg.k_max))
        if new_k != self.k:
            self.k = new_k
            self._k_gauge.set(new_k)
            self.k_history.append((self.version, self.k))

    # -- telemetry -------------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        lat = sorted(self.round_latencies)

        def pct(p: float) -> float:
            if not lat:
                return float("nan")
            return lat[min(len(lat) - 1, int(p * len(lat)))]

        cadence = (np.diff(self.round_times).tolist()
                   if len(self.round_times) > 1 else [])
        return {
            **self.counters,
            "k": self.k,
            "k_history": list(self.k_history),
            "version": self.version,
            "arrival_rate": self.arrival_rate(),
            "round_latency_p50": pct(0.50),
            "round_latency_p99": pct(0.99),
            "round_cadence_mean": (float(np.mean(cadence)) if cadence
                                   else float("nan")),
            "queue_depth_now": len(self.queue),
            "queue_depth_max": self.queue_depth_max,
        }


def serve_stream(controller: ServingController, gen,
                 *, max_rounds: Optional[int] = None,
                 max_events: Optional[int] = None,
                 max_time: Optional[float] = None,
                 round_hook: Optional[Callable[[int], None]] = None
                 ) -> Dict[str, Any]:
    """Drive the controller from a continuous arrival stream.

    ``gen`` is a ``sim.arrivals.TrafficGenerator`` (or anything with its
    ``pop`` / ``realize`` / ``settle`` protocol). Events are consumed in
    global (time, client) order until one of the bounds trips; the final
    partial buffer is left unapplied (a service has no "end of run").
    ``round_hook(version)`` fires once per applied round — the periodic
    metrics flush / windowed-profiler hook serve_fl installs. Returns
    ``controller.metrics()`` plus the event/time bookkeeping.
    """
    if max_rounds is None and max_events is None and max_time is None:
        raise ValueError("need at least one of max_rounds / max_events / "
                         "max_time")
    events = 0
    now = 0.0
    while not gen.empty():
        if max_rounds is not None and controller.version >= max_rounds:
            break
        if max_events is not None and events >= max_events:
            break
        t, cid = gen.pop()
        if max_time is not None and t > max_time:
            break
        now = t
        events += 1
        upload = gen.realize(cid, t, controller.version)
        if upload is None:  # lost in transit (scenario dropout)
            continue
        adm = controller.offer(upload, t)
        before = controller.version
        controller.pump(t)
        if round_hook is not None:
            for v in range(before + 1, controller.version + 1):
                round_hook(v)
        gen.settle(cid, t, adm, controller.version, upload)
    out = controller.metrics()
    out["events"] = events
    out["sim_time"] = now
    out["lost_in_transit"] = gen.lost
    out["retries_scheduled"] = gen.retries
    return out

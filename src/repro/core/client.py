"""FL client: M local SGD steps from a (possibly stale) base model.

``make_local_update_fn`` builds a jit-able function:

    (base_params, batches, key) -> (delta, metrics)

where ``delta = base - end`` is the *cumulative update* Delta_i of the paper
(sum over local steps of lr * grad, for plain SGD), and ``batches`` is a
pytree whose leaves carry a leading (M, ...) local-step axis.

``make_fresh_loss_fn`` evaluates the CURRENT global model on a fresh local
mini-batch — the P_i^t probe of eq. (4). In the real protocol the server
broadcasts x^t to the buffered clients, which reply with one scalar; the
simulator performs that exchange directly.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim.optimizers import apply_updates, sgd
from repro.utils.pytree import tree_sub


def make_local_update_fn(loss_fn: Callable, local_steps: int, local_lr: float,
                         momentum: float = 0.0,
                         prox_mu: float = 0.0) -> Callable:
    """loss_fn(params, batch) -> (scalar, metrics_dict).

    ``prox_mu > 0`` adds the FedProx proximal term mu/2 * ||w - w_base||^2
    to each local step — the standard heterogeneity mitigation the paper's
    related-work line cites; composes with any aggregation policy.
    """
    opt = sgd(local_lr, momentum=momentum)
    grad_fn = jax.grad(lambda p, b: loss_fn(p, b)[0])

    def local_update(base_params, batches, _key=None):
        opt_state = opt.init(base_params)

        def step(carry, batch):
            params, ostate = carry
            g = grad_fn(params, batch)
            if prox_mu:
                g = jax.tree.map(
                    lambda gi, p, b: gi + prox_mu * (p - b).astype(gi.dtype),
                    g, params, base_params)
            upd, ostate = opt.update(g, ostate, params)
            return (apply_updates(params, upd), ostate), None

        (end_params, _), _ = jax.lax.scan(step, (base_params, opt_state),
                                          batches, length=local_steps)
        delta = tree_sub(base_params, end_params)  # Delta_i (gradient-like)
        return delta, {}

    return local_update


@functools.lru_cache(maxsize=64)
def make_fresh_loss_fn(loss_fn: Callable) -> Callable:
    """(global_params, fresh_batch) -> scalar mean per-sample loss.

    Memoized on ``loss_fn`` so repeated server constructions share one
    probe function (and downstream, one compiled server pass)."""

    def fresh_loss(global_params, fresh_batch):
        loss, _ = loss_fn(global_params, fresh_batch)
        return loss.astype(jnp.float32)

    return fresh_loss

"""Compatibility shim over the vectorized simulation engine (repro.sim).

The event-driven simulator that used to live here is now two modules:

* ``repro.sim.engine``  — the vectorized, device-resident engine (one XLA
  launch per ``rounds_per_launch`` server rounds); the default for
  ``run_async``;
* ``repro.sim.legacy``  — the original per-event heapq loop, kept as the
  parity reference (``engine="legacy"``) and benchmark baseline.

``LatencyModel`` / ``SimResult`` moved to ``repro.sim`` and are re-exported
here unchanged. Scenario-driven runs (availability churn, dropouts,
bandwidth tiers, ... — see ``repro.sim.scenarios.registry()``) pass
``scenario=``/``behavior=``/``trace=`` through either runner.

The engine/legacy modules are imported lazily inside the runners:
``repro.sim.engine`` depends on ``repro.core.client``, so a module-level
import here would cycle when ``repro.sim`` is imported first.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

from repro.configs.base import FLConfig
from repro.sim.base import SimResult  # noqa: F401  (compat re-export)
from repro.sim.scenarios import LatencyModel  # noqa: F401  (compat re-export)


def run_async(loss_fn: Callable, init_params: Any, clients: Sequence,
              fl: FLConfig, total_rounds: int,
              eval_fn: Optional[Callable[[Any], Dict]] = None,
              eval_every: int = 5,
              latency: Optional[LatencyModel] = None,
              seed: int = 0,
              engine: str = "vectorized",
              **kw) -> SimResult:
    """Simulate buffered-async FL for ``total_rounds`` server rounds.

    ``engine="vectorized"`` (default) runs each K-upload window as one
    compiled cohort step; ``engine="legacy"`` replays the original
    per-event loop. Both accept ``scenario=``, ``behavior=``, ``trace=``
    and ``record_trace=`` (see repro.sim). ``engine="population"`` keeps
    the whole client state machine device-resident (counter-based RNG +
    top-k window selection, ``repro.sim.population``) — scenario-driven
    only, built for very large N.
    """
    if engine == "vectorized":
        from repro.sim.engine import run_vectorized as runner
    elif engine == "legacy":
        from repro.sim.legacy import run_async_legacy as runner
    elif engine == "population":
        from repro.sim.population import run_population as runner
    else:
        raise ValueError(f"unknown engine {engine!r}; "
                         "valid: 'vectorized', 'legacy', 'population'")
    return runner(loss_fn, init_params, clients, fl, total_rounds,
                  eval_fn=eval_fn, eval_every=eval_every, latency=latency,
                  seed=seed, **kw)


def run_vectorized(*args, **kw) -> SimResult:
    """See ``repro.sim.engine.run_vectorized`` (lazy compat wrapper)."""
    from repro.sim.engine import run_vectorized as f
    return f(*args, **kw)


def run_async_legacy(*args, **kw) -> SimResult:
    """See ``repro.sim.legacy.run_async_legacy`` (lazy compat wrapper)."""
    from repro.sim.legacy import run_async_legacy as f
    return f(*args, **kw)


def run_sync(*args, **kw) -> SimResult:
    """See ``repro.sim.legacy.run_sync`` (lazy compat wrapper)."""
    from repro.sim.legacy import run_sync as f
    return f(*args, **kw)

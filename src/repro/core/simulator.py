"""Event-driven simulator of asynchronous federated training.

Models the realistic FL timeline the paper targets: heterogeneous clients
(lognormal compute times with per-client speed factors, plus communication
latency) continuously train and upload; the server aggregates whenever the
K-buffer fills; finished clients immediately pull the newest global model
and keep going, while stragglers continue on stale versions.

Supports protocols:
  * buffered-async (FedBuff structure) with any weighting policy — this is
    the paper's method when ``weighting="paper"``;
  * fully-async (``buffer_size=1``) — FedAsync-style;
  * synchronous FedAvg (``run_sync``) for wall-clock comparisons.

Returns a history of (server_round, sim_time, eval metrics) so benchmarks
can plot accuracy-vs-rounds AND accuracy-vs-time (the paper's Fig. 1).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.core.client import make_fresh_loss_fn, make_local_update_fn
from repro.core.server import AsyncServer, SyncServer


@dataclasses.dataclass
class LatencyModel:
    """Per-client round duration = speed_factor * lognormal + comm."""

    speed_factors: np.ndarray  # (N,) multiplicative slowness per client
    base_mean: float = 1.0
    sigma: float = 0.25
    comm: float = 0.1

    @staticmethod
    def heterogeneous(num_clients: int, max_slowdown: float = 10.0,
                      seed: int = 0, **kw) -> "LatencyModel":
        rng = np.random.default_rng(seed)
        # log-uniform speed factors in [1, max_slowdown]
        f = np.exp(rng.uniform(0.0, np.log(max_slowdown), num_clients))
        return LatencyModel(speed_factors=np.sort(f), **kw)

    def sample(self, rng: np.random.Generator, client: int) -> float:
        dur = self.speed_factors[client] * rng.lognormal(
            mean=np.log(self.base_mean), sigma=self.sigma)
        return float(dur + self.comm)


@dataclasses.dataclass
class SimResult:
    history: List[Dict]  # per-eval: {round, time, **metrics}
    server_rounds: int
    sim_time: float
    round_log: List[Dict]

    def rounds_to_target(self, metric: str, target: float) -> Optional[int]:
        for h in self.history:
            if h.get(metric, -np.inf) >= target:
                return h["round"]
        return None

    def time_to_target(self, metric: str, target: float) -> Optional[float]:
        for h in self.history:
            if h.get(metric, -np.inf) >= target:
                return h["time"]
        return None


def _make_batches(ds, batch_size: int, steps: int):
    xs, ys = zip(*[ds.batch(batch_size) for _ in range(steps)])
    return np.stack(xs), np.stack(ys)


def run_async(loss_fn: Callable, init_params: Any, clients: Sequence,
              fl: FLConfig, total_rounds: int,
              eval_fn: Optional[Callable[[Any], Dict]] = None,
              eval_every: int = 5,
              latency: Optional[LatencyModel] = None,
              seed: int = 0) -> SimResult:
    """Simulate buffered-async FL for ``total_rounds`` server rounds.

    loss_fn(params, (x, y)) -> (scalar, metrics). clients: ClientDataset-like
    (needs .batch(b) and .size).
    """
    n = len(clients)
    rng = np.random.default_rng(seed)
    latency = latency or LatencyModel.heterogeneous(n, seed=seed)
    local_update = jax.jit(make_local_update_fn(
        loss_fn, fl.local_steps, fl.local_lr, fl.local_momentum))
    server = AsyncServer(init_params, fl, make_fresh_loss_fn(loss_fn))

    # every client starts training at t=0 from version 0
    base_version = {i: 0 for i in range(n)}
    events = [(latency.sample(rng, i), i) for i in range(n)]
    heapq.heapify(events)
    history: List[Dict] = []
    now = 0.0

    def maybe_eval(force=False):
        if eval_fn and (force or server.version % eval_every == 0):
            if not history or history[-1]["round"] != server.version or force:
                m = eval_fn(server.params)
                history.append({"round": server.version, "time": now, **m})

    maybe_eval(force=True)
    while server.version < total_rounds:
        now, cid = heapq.heappop(events)
        ds = clients[cid]
        bx, by = _make_batches(ds, fl.batch_size, fl.local_steps)
        base = server.history.get(base_version[cid])
        if base is None:  # fell out of the ring: resync (modelled as re-pull)
            base = server.params
            base_version[cid] = server.version
        delta, _ = local_update(base, (bx, by))
        fresh = (lambda d=ds: d.batch(fl.batch_size))
        advanced = server.receive(cid, delta, base_version[cid], ds.size,
                                  fresh_batch_fn=fresh)
        # client immediately pulls the newest model and restarts (async)
        base_version[cid] = server.version
        heapq.heappush(events, (now + latency.sample(rng, cid), cid))
        if advanced:
            maybe_eval()
    maybe_eval(force=True)
    return SimResult(history=history, server_rounds=server.version,
                     sim_time=now, round_log=server.round_log)


def run_sync(loss_fn: Callable, init_params: Any, clients: Sequence,
             fl: FLConfig, total_rounds: int,
             eval_fn: Optional[Callable[[Any], Dict]] = None,
             eval_every: int = 5,
             latency: Optional[LatencyModel] = None,
             seed: int = 0) -> SimResult:
    """Synchronous FedAvg: every round waits for all N clients (the
    straggler cost the paper's Problem statement describes)."""
    n = len(clients)
    rng = np.random.default_rng(seed)
    latency = latency or LatencyModel.heterogeneous(n, seed=seed)
    local_update = jax.jit(make_local_update_fn(
        loss_fn, fl.local_steps, fl.local_lr, fl.local_momentum))
    server = SyncServer(init_params, fl)
    history: List[Dict] = []
    now = 0.0
    for _ in range(total_rounds):
        durations = [latency.sample(rng, i) for i in range(n)]
        now += max(durations)  # wait for the slowest straggler
        deltas = []
        for cid in range(n):
            bx, by = _make_batches(clients[cid], fl.batch_size, fl.local_steps)
            d, _ = local_update(server.params, (bx, by))
            deltas.append(d)
        server.round(deltas, [c.size for c in clients])
        if eval_fn and server.version % eval_every == 0:
            history.append({"round": server.version, "time": now,
                            **eval_fn(server.params)})
    if eval_fn:
        history.append({"round": server.version, "time": now,
                        **eval_fn(server.params)})
    return SimResult(history=history, server_rounds=server.version,
                     sim_time=now, round_log=[])

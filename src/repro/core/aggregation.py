"""Server-side weighted aggregation (eq. 5) over stacked client deltas.

Two equivalent implementations:
  * ``aggregate``       — pure jnp (XLA), works everywhere;
  * ``aggregate_fused`` — routes the flat hot loop through the Pallas
    ``weighted_agg`` kernel (one HBM pass computes the weighted sum; see
    repro/kernels/weighted_agg). Tests assert both match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_sub, tree_weighted_sum


def aggregate(global_params, deltas_stacked, weights, eta_g: float, k: int):
    """eq. (5): x_{t+1} = x_t - eta_g * (1/K) sum_i w_i Delta_i.

    deltas_stacked: pytree with leading (K, ...) axis. weights: (K,).
    """
    scale = eta_g / float(k)
    upd = tree_weighted_sum(deltas_stacked, weights.astype(jnp.float32) * scale)
    return tree_sub(global_params, upd), upd


def aggregate_fused(global_params, deltas_stacked, weights, eta_g: float, k: int,
                    interpret: bool = True):
    """Same maths via the Pallas kernel (flattened per-leaf)."""
    from repro.kernels.weighted_agg.ops import weighted_sum as pallas_ws

    scale = eta_g / float(k)
    w = weights.astype(jnp.float32) * scale

    def leaf_update(x, d):
        dk = d.reshape(d.shape[0], -1)  # (K, n)
        u = pallas_ws(dk.astype(jnp.float32), w, interpret=interpret)
        return (x.astype(jnp.float32) - u.reshape(x.shape)).astype(x.dtype), \
            u.reshape(x.shape).astype(x.dtype)

    pairs = jax.tree.map(leaf_update, global_params, deltas_stacked)
    new = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    upd = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return new, upd

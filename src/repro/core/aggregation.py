"""Server-side weighted aggregation (eq. 5) over stacked client deltas.

Two equivalent implementations:
  * ``aggregate``       — pure jnp (XLA), works everywhere;
  * ``aggregate_fused`` — routes the flat hot loop through the Pallas
    ``weighted_agg`` kernel (one HBM pass computes the weighted sum; see
    repro/kernels/weighted_agg). Tests assert both match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_sub, tree_weighted_sum


def aggregate(global_params, deltas_stacked, weights, eta_g: float, k: int):
    """eq. (5): x_{t+1} = x_t - eta_g * (1/K) sum_i w_i Delta_i.

    deltas_stacked: pytree with leading (K, ...) axis. weights: (K,).
    """
    scale = eta_g / float(k)
    upd = tree_weighted_sum(deltas_stacked, weights.astype(jnp.float32) * scale)
    return tree_sub(global_params, upd), upd


def aggregate_fused(global_params, deltas_stacked, weights, eta_g: float, k: int,
                    interpret: bool = True):
    """Same maths via ONE Pallas launch over the whole flattened tree.

    The FlatSpec adapter (repro/core/server_pass.py) concatenates and
    zero-pads all leaves to one lane-aligned (K, Np) array, so a single
    kernel streams every parameter once instead of one launch per leaf.
    """
    from repro.core.server_pass import (
        flatten_stacked, flatten_tree, make_flat_spec, unflatten_like)
    from repro.kernels.weighted_agg import kernel as _k

    spec = make_flat_spec(global_params)
    x = flatten_tree(spec, global_params)
    d = flatten_stacked(spec, deltas_stacked)
    w = weights.astype(jnp.float32) * (eta_g / float(k))
    u = _k.weighted_sum_pallas(d, w, block_n=spec.block_n,
                               interpret=interpret)
    return (unflatten_like(spec, x - u, global_params),
            unflatten_like(spec, u, global_params))

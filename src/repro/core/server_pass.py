"""Device-resident contribution-aware server pass (DESIGN.md §3).

One compiled program computes the paper's whole per-round server maths —
eq. 3 staleness distances, the eq. 4 fresh-loss probe, the weighting
policy, and the eq. 5 reduction — over the stacked K buffered updates.
The seed implementation looped on the host with a ``float()`` sync per
buffered entry (O(K) device<->host round-trips per round); this module is
the single jitted entry point that replaces it for ``AsyncServer``, the
compiled cohort step, and ``aggregate_fused``.

Dataflow (all inside one ``jax.jit``):

    params pytree ──flatten/pad──> x (Np,)          f32
    deltas  (K, ...) ──flatten──>  d (K, Np)         f32
    bases   (K, ...) ──flatten──>  b (K, Np)         f32
    probes  (K, ...) ──vmap loss─> losses (K,)          (eq. 4)
    dists_k = ||x - b_k||^2                             (eq. 3)
    w = contribution_weights(policy, N_i * losses, S(dists), tau)
    x' = x - eta_g / k_eff * sum_k w_k d_k              (eq. 5)
    x' ──unflatten──> new params pytree (original dtypes)

The flatten/pad adapter zero-pads the concatenated parameter vector to a
lane-aligned tile multiple, which is distance- and sum-neutral, so the
Pallas kernels' ``N % block_n == 0`` contract holds for arbitrary models.

Modes (``FLConfig.server_pass_mode``):
  reference : pure-jnp body — one XLA program, runs everywhere;
  batched   : eq. 3 via ``sq_dists_pallas`` (one HBM pass for all K) and
              eq. 5 via ``weighted_sum_pallas`` — two kernel launches;
  fused     : ``fused_server_pallas`` — eq. 3 + weighting + eq. 5 in a
              single two-phase kernel launch (bases and deltas each read
              from HBM exactly once);
  auto      : fused on TPU, reference elsewhere (Mosaic kernels need a
              TPU; ``interpret=True`` is validation-only).

Host-sync contract: callers receive the new params and a dict of (K,)
info arrays, all device-resident. ``AsyncServer`` reads the info back
with ONE ``jax.device_get`` for its round log — at most 2 host syncs per
aggregation round, tested in tests/test_server_pass.py.

Mesh scale-out (DESIGN.md §5): ``make_flat_spec(..., mesh=...)`` returns a
``ShardedFlatSpec`` whose padded length is a multiple of
``block_n * model_shards``, and ``apply_server_round(..., mesh=...)`` runs
the round as a ``shard_map`` over the ``model`` axis — per-shard eq. 3
partial distances meet in ONE ``psum``, the (K,) weighting stays
replicated, and the eq. 5 reduction (over K, not N) completes per-shard
with no further collective.
"""
from __future__ import annotations

import functools
import logging

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import FLConfig
from repro.core.weighting import (
    contribution_weights,
    staleness_degree,
    statistical_effect,
)
from repro.kernels.weighted_agg import kernel as _k
from repro.kernels.weighted_agg import ops as _ops
from repro.sharding.specs import MODEL_AXIS, info_pspec, mesh_axis_size

logger = logging.getLogger(__name__)

MODES = ("auto", "reference", "batched", "fused")


def resolve_mode(mode: str, interpret: Optional[bool] = None) -> Tuple[str, bool]:
    """Map ``auto`` to a backend-appropriate concrete mode.

    Mosaic kernels compile only for TPU; everywhere else ``interpret=True``
    would run them tile-by-tile in Python (validation-only), so ``auto``
    falls back to the pure-jnp reference body — still one compiled,
    device-resident program. An explicit ``fused``/``batched`` request off
    TPU is honoured in interpret mode but warns, so the silent-slowdown
    failure mode is visible.
    """
    if mode not in MODES:
        raise ValueError(f"unknown server_pass_mode {mode!r}; valid: {MODES}")
    backend = jax.default_backend()
    on_tpu = backend == "tpu"
    if interpret is None:
        interpret = not on_tpu
    if mode == "auto":
        mode = "fused" if on_tpu else "reference"
        if not on_tpu:
            logger.info(
                "server_pass_mode='auto' resolved to 'reference' on backend "
                "%r: the fused/batched Pallas kernels are Mosaic programs "
                "and compile only for TPU", backend)
    elif mode in ("batched", "fused") and not on_tpu and interpret:
        # standardized logging (obs.configure_logging, DESIGN.md §9):
        # launchers set the level once; this used to be a warnings.warn
        logger.warning(
            "server_pass_mode=%r requested on backend %r: Mosaic/Pallas "
            "kernels compile only for TPU, so the kernel will run in "
            "interpret mode (tile-by-tile Python, validation-only — orders "
            "of magnitude slower). Use server_pass_mode='reference' or "
            "'auto' for a compiled %s path.", mode, backend, backend)
    return mode, interpret


# ---------------------------------------------------------------------------
# pytree flatten / pad / unflatten adapter
# ---------------------------------------------------------------------------


class FlatSpec(NamedTuple):
    """Static layout of a pytree flattened to one padded f32 vector."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    n: int  # true parameter count
    n_padded: int  # lane-aligned, block-divisible length
    block_n: int  # tile the kernels run with


class ShardedFlatSpec(NamedTuple):
    """FlatSpec plus the mesh layout of the flat vector (DESIGN.md §5).

    Same leading fields as ``FlatSpec`` (the flatten/unflatten helpers
    accept either), but ``n_padded`` is a multiple of
    ``block_n * model_shards`` so every ``model``-axis shard holds a whole
    number of kernel tiles. Zero padding is distance- and sum-neutral, so
    shards holding only padding contribute 0 to the eq. 3 psum.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    n: int
    n_padded: int
    block_n: int
    mesh: Any  # jax.sharding.Mesh carrying the ``model`` axis
    model_shards: int  # size of the model axis (> 1)


def make_flat_spec(template: Any, block_n: int = 0, mesh: Any = None):
    """Build the flatten layout for ``template`` (works under tracing).

    With ``mesh`` carrying a ``model`` axis of size m > 1, returns a
    ``ShardedFlatSpec`` padded to a ``block_n * m`` multiple so the padded
    vector partitions evenly into per-shard whole-tile slices.
    """
    leaves, treedef = jax.tree.flatten(template)
    shapes = tuple(tuple(x.shape) for x in leaves)
    dtypes = tuple(x.dtype for x in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    n = sum(sizes)
    block = block_n or _ops.pick_block(n)
    shards = mesh_axis_size(mesh, MODEL_AXIS) if mesh is not None else 1
    if shards > 1:
        return ShardedFlatSpec(treedef, shapes, dtypes, sizes, n,
                               _ops.pad_to(n, block * shards), block,
                               mesh, shards)
    return FlatSpec(treedef, shapes, dtypes, sizes, n,
                    _ops.pad_to(n, block), block)


def flatten_tree(spec: FlatSpec, tree: Any) -> jnp.ndarray:
    """pytree -> (n_padded,) f32, zero-padded (distance/sum neutral)."""
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([x.astype(jnp.float32).reshape(-1) for x in leaves])
    if spec.n_padded != spec.n:
        flat = jnp.pad(flat, (0, spec.n_padded - spec.n))
    return flat


def flatten_stacked(spec: FlatSpec, stacked: Any) -> jnp.ndarray:
    """pytree with (K, ...) leaves -> (K, n_padded) f32."""
    leaves = jax.tree.leaves(stacked)
    k = leaves[0].shape[0]
    flat = jnp.concatenate(
        [x.astype(jnp.float32).reshape(k, -1) for x in leaves], axis=1)
    if spec.n_padded != spec.n:
        flat = jnp.pad(flat, ((0, 0), (0, spec.n_padded - spec.n)))
    return flat


def unflatten_stacked(spec: FlatSpec, mat: jnp.ndarray, template: Any) -> Any:
    """(K, n_padded) f32 -> pytree with (K, ...) leaves (template dtypes).

    Inverse of ``flatten_stacked``; the engine's flat-sharded version ring
    (DESIGN.md §6) gathers bases as (K, Np) rows and unflattens them only
    for the K-client local-update vmap."""
    k = mat.shape[0]
    leaves = []
    off = 0
    for shape, dtype, size in zip(spec.shapes, spec.dtypes, spec.sizes):
        leaves.append(mat[:, off:off + size].reshape((k,) + shape)
                      .astype(dtype))
        off += size
    return jax.tree.unflatten(spec.treedef, leaves)


def unflatten_like(spec: FlatSpec, vec: jnp.ndarray, template: Any) -> Any:
    """(n_padded,) or (n,) f32 -> pytree with the template's dtypes."""
    leaves = []
    off = 0
    for shape, dtype, size in zip(spec.shapes, spec.dtypes, spec.sizes):
        leaves.append(vec[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree.unflatten(spec.treedef, leaves)


# ---------------------------------------------------------------------------
# the round core (shared by AsyncServer, cohort step, and the benchmarks)
# ---------------------------------------------------------------------------


def apply_server_round(x: jnp.ndarray, bases: jnp.ndarray,
                       deltas: jnp.ndarray, losses: jnp.ndarray,
                       data_sizes: jnp.ndarray, taus: jnp.ndarray,
                       fl: FLConfig, *,
                       arrival_mask: Optional[jnp.ndarray] = None,
                       mode: str = "reference", block_n: int = 0,
                       interpret: bool = False, mesh: Any = None,
                       sq_dists: Optional[jnp.ndarray] = None):
    """eq. 3 + 4 + 5 on flat arrays. Returns (new_x, info dict of (K,)).

    x: (Np,), bases/deltas: (K, Np) — already padded to a ``block_n``
    multiple (zeros), e.g. by the FlatSpec adapter. losses/data_sizes/
    taus: (K,). ``arrival_mask`` zeroes absent cohort slots (weights AND
    the k_eff divisor), matching ``contribution_weights``.

    ``sq_dists`` short-circuits eq. 3 with precomputed (K,) squared
    distances — the compressed version store's escape hatch
    (``core/version_store.py``: the int8 codec's fused
    dequantize-distance kernel and the delta codec's sparse expansion
    both produce distances WITHOUT materializing the decoded rows, so
    recomputing them here from the decoded ``bases`` would waste the
    codec's bandwidth win). With it set, the fused single-launch kernel
    is skipped in favour of the two-phase weighted-sum path (its phase 0
    IS the distance computation), and the sharded path drops its psum
    (the codec already reduced across shards).

    With ``mesh`` carrying a ``model`` axis of size m > 1, the pass runs
    as a ``shard_map`` over that axis (``Np`` must be a
    ``block_n * m`` multiple — use ``make_flat_spec(..., mesh=mesh)``):
    per-shard partial eq. 3 distances complete with one psum, the (K,)
    weighting is computed replicated, and the eq. 5 reduction (over K)
    finishes per-shard with no further collective.
    """
    if mode not in ("reference", "batched", "fused"):
        raise ValueError(f"unknown concrete mode {mode!r}")
    p = statistical_effect(losses, data_sizes)
    k = bases.shape[0]
    mask = (jnp.ones((k,), jnp.float32) if arrival_mask is None
            else arrival_mask.astype(jnp.float32))
    taus = taus.astype(jnp.float32)
    shards = mesh_axis_size(mesh, MODEL_AXIS) if mesh is not None else 1
    # default tile from the PER-SHARD slice length, so the kernels'
    # N % block_n == 0 contract holds inside the shard_map body too
    block = block_n or _ops.pick_block(x.shape[0] // shards)
    if shards > 1:
        return _apply_server_round_sharded(
            x, bases, deltas, losses, p, taus, mask, fl, mode=mode,
            block=block, interpret=interpret, mesh=mesh, sq_dists=sq_dists)

    if sq_dists is not None:
        dists = sq_dists.astype(jnp.float32)
        upd, s, w = _weight_and_reduce(
            dists, deltas, p, taus, mask, fl,
            use_kernel=(mode in ("batched", "fused")), block=block,
            interpret=interpret)
        new_x = x - upd
    elif mode == "fused":
        upd, dists, w = _ops.server_update(
            x, bases, deltas, p, taus, mask, policy=fl.weighting,
            eta_g=fl.global_lr, s_min=fl.s_min, poly_a=fl.poly_a,
            hinge_a=fl.hinge_a, hinge_b=fl.hinge_b,
            normalize=fl.normalize, block_n=block, interpret=interpret)
        s = staleness_degree(dists, arrival_mask=mask)
        new_x = x - upd
    else:
        dists = _sq_dists(x, bases, use_kernel=(mode == "batched"),
                          block=block, interpret=interpret)
        upd, s, w = _weight_and_reduce(
            dists, deltas, p, taus, mask, fl,
            use_kernel=(mode == "batched"), block=block, interpret=interpret)
        new_x = x - upd

    info = {"sq_dists": dists, "staleness": s, "stat_effect": p,
            "weights": w, "fresh_loss": losses}
    return new_x, info


def _sq_dists(x, bases, *, use_kernel, block, interpret):
    """eq. 3 squared distances over the (local slice of the) flat vector."""
    if use_kernel:
        return _k.sq_dists_pallas(x, bases, block_n=block,
                                  interpret=interpret)
    diff = bases - x[None]
    return jnp.sum(diff * diff, axis=1)


def _weight_and_reduce(dists, deltas, p, taus, mask, fl: FLConfig, *,
                       use_kernel, block, interpret):
    """Everything after eq. 3: staleness ratio -> policy weights -> the
    eq. 5 weighted-delta reduction. The ONE copy both the single-device
    pass and the per-shard shard_map body run, so sharded-vs-single
    parity cannot drift when the weighting logic evolves. The eq. 3 min
    reference is taken over ARRIVED slots only (mask>0) — an absent
    straggler's base must not distort the applied weights (and the
    cohort's arrival-masked telemetry stays consistent with them).
    """
    s = staleness_degree(dists, arrival_mask=mask)
    w = contribution_weights(fl.weighting, p, s, taus, s_min=fl.s_min,
                             poly_a=fl.poly_a, hinge_a=fl.hinge_a,
                             hinge_b=fl.hinge_b, normalize=fl.normalize,
                             arrival_mask=mask)
    k_eff = jnp.maximum(jnp.sum(mask), 1.0)
    w_scaled = w * (fl.global_lr / k_eff)
    if use_kernel:
        upd = _k.weighted_sum_pallas(deltas, w_scaled, block_n=block,
                                     interpret=interpret)
    else:
        upd = jnp.einsum("kn,k->n", deltas, w_scaled)
    return upd, s, w


def _apply_server_round_sharded(x, bases, deltas, losses, p, taus, mask,
                                fl: FLConfig, *, mode, block, interpret,
                                mesh, sq_dists=None):
    """shard_map body of the round over the ``model`` axis (DESIGN.md §5).

    Inputs are the preprocessed arrays from ``apply_server_round`` (mask
    built, taus cast, block picked per-shard). The fused single-launch
    kernel folds the weighting into the kernel, but the weighting needs
    the GLOBAL eq. 3 distances — which only exist after the cross-shard
    psum — so under sharding both kernel modes (``batched`` and
    ``fused``) run the two-phase tiles (``sq_dists_pallas`` +
    ``weighted_sum_pallas``) per shard; the shape of the communication
    (one (K,) psum) is identical either way. Precomputed ``sq_dists``
    (the compressed-ring codecs) arrive already globally reduced, so
    that path carries them in replicated and skips the psum entirely —
    the round then has NO collective beyond the final output layout.
    """
    use_kernel = mode in ("batched", "fused")

    if sq_dists is not None:
        def shard_body_pre(x_s, d_s, p_, taus_, mask_, dists):
            upd, s, w = _weight_and_reduce(
                dists, d_s, p_, taus_, mask_, fl, use_kernel=use_kernel,
                block=block, interpret=interpret)
            return x_s - upd, dists, s, w

        new_x, dists, s, w = shard_map(
            shard_body_pre, mesh,
            in_specs=(P(MODEL_AXIS), P(None, MODEL_AXIS),
                      P(), P(), P(), P()),
            out_specs=(P(MODEL_AXIS), P(), P(), P()),
            check_rep=False)(x, deltas, p, taus, mask,
                             sq_dists.astype(jnp.float32))
    else:
        def shard_body(x_s, b_s, d_s, p_, taus_, mask_):
            # eq. 3: per-shard partial squared distances -> ONE psum, then
            # the shared post-distance pipeline (weighting replicated,
            # eq. 5 reducing over K) completes per-shard with no further
            # collective
            part = _sq_dists(x_s, b_s, use_kernel=use_kernel, block=block,
                             interpret=interpret)
            dists = jax.lax.psum(part, MODEL_AXIS)
            upd, s, w = _weight_and_reduce(
                dists, d_s, p_, taus_, mask_, fl, use_kernel=use_kernel,
                block=block, interpret=interpret)
            return x_s - upd, dists, s, w

        new_x, dists, s, w = shard_map(
            shard_body, mesh,
            in_specs=(P(MODEL_AXIS), P(None, MODEL_AXIS),
                      P(None, MODEL_AXIS), P(), P(), P()),
            out_specs=(P(MODEL_AXIS), P(), P(), P()),
            check_rep=False)(x, bases, deltas, p, taus, mask)
    info = {"sq_dists": dists, "staleness": s, "stat_effect": p,
            "weights": w, "fresh_loss": losses}
    # multi-host contract (DESIGN.md §7): info stays FULLY REPLICATED so
    # every process can read the round log from its own addressable
    # shards — pin it so the partitioner can never reshard it over a
    # process-spanning axis downstream (e.g. under the engine's scan)
    rep = jax.sharding.NamedSharding(mesh, info_pspec())
    info = jax.tree.map(
        lambda a: jax.lax.with_sharding_constraint(a, rep), info)
    return new_x, info


@functools.lru_cache(maxsize=64)
def make_server_pass(fl: FLConfig,
                     fresh_loss_fn: Optional[Callable[[Any, Any], jnp.ndarray]],
                     *, mode: Optional[str] = None,
                     interpret: Optional[bool] = None,
                     mesh: Any = None) -> Callable:
    """Build the jitted server pass (memoized: one compiled program per
    (fl, fresh_loss_fn, mode) across repeated server constructions).

    Returns ``pass_fn(params, deltas_st, bases_st, probes, probe_mask,
    data_sizes, taus, losses=None) -> (new_params, info)`` where
    ``deltas_st`` / ``bases_st`` are pytrees with (K, ...) leaves,
    ``probes`` is a pytree of stacked probe batches (leading K) or None,
    and ``probe_mask`` is (K,) {0,1} marking entries that actually
    supplied a probe (the rest fall back to loss 1.0, i.e. pure size
    weighting). ``losses`` short-circuits the probe with precomputed
    (K,) fresh losses — the escape hatch for probe batches whose shapes
    don't stack (AsyncServer._gather_probes). Everything stays on
    device; the caller decides what (if anything) to read back.

    ``mesh`` shards the flat-vector round over the mesh's ``model`` axis
    (DESIGN.md §5); with no mesh the pass is the single-device program.
    """
    mode_, interpret_ = resolve_mode(fl.server_pass_mode if mode is None
                                     else mode, interpret)

    @jax.jit
    def pass_fn(params, deltas_st, bases_st, probes, probe_mask,
                data_sizes, taus, precomputed_losses=None):
        spec = make_flat_spec(params, fl.server_pass_block_n, mesh=mesh)
        x = flatten_tree(spec, params)
        d = flatten_stacked(spec, deltas_st)
        b = flatten_stacked(spec, bases_st)
        data_sizes_ = data_sizes.astype(jnp.float32)
        if precomputed_losses is not None:
            losses = precomputed_losses.astype(jnp.float32)
        elif probes is None or fresh_loss_fn is None:
            losses = jnp.ones_like(data_sizes_)
        else:
            losses = jax.vmap(lambda pb: fresh_loss_fn(params, pb))(probes)
            losses = losses.astype(jnp.float32)
            if probe_mask is not None:
                losses = jnp.where(probe_mask > 0, losses, 1.0)
        new_x, info = apply_server_round(
            x, b, d, losses, data_sizes_, taus, fl, mode=mode_,
            block_n=spec.block_n, interpret=interpret_, mesh=mesh)
        return unflatten_like(spec, new_x, params), info

    return pass_fn

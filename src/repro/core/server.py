"""FL servers (python orchestration layer; all maths is jit-compiled).

``AsyncServer`` implements the paper's contribution-aware buffered
aggregation with *exact* eq.-3 staleness (snapshot-based distances), plus
the baseline policies via ``FLConfig.weighting``. ``SyncServer`` is FedAvg.

The per-round maths runs entirely through the device-resident server pass
(repro/core/server_pass.py): one jitted program computes eq. 3 + 4 + 5
over the stacked K buffered updates, and the only device->host transfer
per aggregation round is a single ``jax.device_get`` of the (K,)-sized
round log (tested in tests/test_server_pass.py).

The O(1)-memory sharded-ring variant used by the compiled production step
lives in repro/core/cohort.py; tests check the two agree
(tests/test_fl_system.py::TestServerCohortAgreement).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.aggregation import aggregate
from repro.core.buffer import BufferEntry, UpdateBuffer, VersionHistory
from repro.core.server_pass import make_server_pass
from repro.utils.pytree import tree_stack


class AsyncServer:
    """Buffered asynchronous server (FedBuff structure + CA weighting)."""

    def __init__(self, init_params: Any, fl: FLConfig,
                 fresh_loss_fn: Callable[[Any, Any], jnp.ndarray]):
        self.fl = fl
        self.params = init_params
        self.version = 0
        self.buffer = UpdateBuffer(fl.buffer_size)
        # valid bases span version - max_staleness .. version: the current
        # snapshot plus max_staleness predecessors
        self.history = VersionHistory(fl.max_staleness + 1)
        self.history.put(0, init_params)
        self._pass = make_server_pass(fl, fresh_loss_fn)
        self._fresh_loss = (None if fresh_loss_fn is None
                            else jax.jit(fresh_loss_fn))
        self.round_log: List[Dict] = []

    # ------------------------------------------------------------------
    def receive(self, client_id: int, delta: Any, base_version: int,
                data_size: int,
                fresh_batch_fn: Optional[Callable[[], Any]] = None,
                fresh_batches: Optional[Dict[int, Any]] = None) -> bool:
        """Buffer one upload; aggregate if K reached. Returns True if a new
        global version was produced. ``fresh_batch_fn`` is stored per entry
        and called at aggregation time (the P_i probe uses x^t, not the
        model version at upload time)."""
        e = BufferEntry(client_id=client_id, delta=delta,
                        base_version=base_version, data_size=data_size)
        e.fresh_batch_fn = fresh_batch_fn  # attach probe callback
        self.buffer.add(e)
        if self.buffer.ready():
            self._do_aggregate()
            return True
        return False

    # ------------------------------------------------------------------
    def _gather_probes(self, entries):
        """Probe batches for the eq.-4 fresh-loss term.

        Returns (probes, mask, losses): uniformly-shaped batches stack
        into one (K, ...) pytree for the vmapped probe inside the pass
        (``losses=None``); heterogeneous batches fall back to K separate
        jitted loss calls whose device scalars are stacked — still zero
        device->host syncs, the pass just skips its own probe. Probe
        callbacks run on the host (they fetch client data), but batches
        only ever transfer host->device.
        """
        if self._fresh_loss is None:
            return None, None, None
        raw = [e.fresh_batch_fn() if getattr(e, "fresh_batch_fn", None)
               else None for e in entries]
        proto = next((b for b in raw if b is not None), None)
        if proto is None:
            return None, None, None
        mask = jnp.asarray([0.0 if b is None else 1.0 for b in raw],
                           jnp.float32)
        batches = [proto if b is None else b for b in raw]

        def layout(b):  # shapes only — no host->device transfer
            return jax.tree.map(lambda x: tuple(np.shape(x)), b)

        if all(layout(b) == layout(proto) for b in batches):
            probes = jax.tree.map(lambda *xs: jnp.stack(
                [jnp.asarray(x) for x in xs]), *batches)
            return probes, mask, None
        losses = jnp.stack([self._fresh_loss(self.params, b)
                            for b in batches]).astype(jnp.float32)
        return None, mask, jnp.where(mask > 0, losses, 1.0)

    def _do_aggregate(self) -> None:
        entries = self.buffer.drain()
        k = len(entries)

        bases, taus = [], []
        for e in entries:
            base = self.history.get(e.base_version)
            if base is None:  # older than the ring: treat as max-stale
                base = self.history.get(self.history.oldest())
            bases.append(base)
            taus.append(self.version - e.base_version)

        probes, probe_mask, losses = self._gather_probes(entries)
        new_params, info = self._pass(
            self.params,
            tree_stack([e.delta for e in entries]),
            tree_stack(bases),
            probes, probe_mask,
            jnp.asarray([e.data_size for e in entries], jnp.float32),
            jnp.asarray(taus, jnp.float32),
            losses)
        self.params = new_params
        self.version += 1
        self.history.put(self.version, self.params)

        log = jax.device_get(info)  # the round's single device->host sync
        self.round_log.append({
            "version": self.version,
            "weights": log["weights"].tolist(),
            "staleness_deg": log["staleness"].tolist(),
            "stat_effect": log["stat_effect"].tolist(),
            "sq_dists": log["sq_dists"].tolist(),
            "tau": taus,
            "clients": [e.client_id for e in entries],
            "k": k,
        })


class SyncServer:
    """FedAvg: waits for all selected clients, size-weighted average."""

    def __init__(self, init_params: Any, fl: FLConfig):
        self.fl = fl
        self.params = init_params
        self.version = 0
        self._aggregate = jax.jit(
            lambda p, d, w, k: aggregate(p, d, w, fl.global_lr, k),
            static_argnames=("k",))

    def round(self, deltas: List[Any], data_sizes: List[int]) -> None:
        k = len(deltas)
        w = jnp.asarray(data_sizes, jnp.float32)
        w = w * k / jnp.sum(w)  # size-weighted, mean-1 normalised
        stacked = tree_stack(deltas)
        self.params, _ = self._aggregate(self.params, stacked, w, k)
        self.version += 1

"""FL servers (python orchestration layer; all maths is jit-compiled).

``AsyncServer`` implements the paper's contribution-aware buffered
aggregation with *exact* eq.-3 staleness (snapshot-based distances), plus
the baseline policies via ``FLConfig.weighting``. ``SyncServer`` is FedAvg.

The O(1)-memory sharded-ring variant used by the compiled production step
lives in repro/core/cohort.py; tests check the two agree.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.aggregation import aggregate
from repro.core.buffer import BufferEntry, UpdateBuffer, VersionHistory
from repro.core.weighting import contribution_weights, staleness_degree, statistical_effect
from repro.utils.pytree import tree_sq_dist, tree_stack


class AsyncServer:
    """Buffered asynchronous server (FedBuff structure + CA weighting)."""

    def __init__(self, init_params: Any, fl: FLConfig,
                 fresh_loss_fn: Callable[[Any, Any], jnp.ndarray]):
        self.fl = fl
        self.params = init_params
        self.version = 0
        self.buffer = UpdateBuffer(fl.buffer_size)
        self.history = VersionHistory(fl.max_staleness)
        self.history.put(0, init_params)
        self._fresh_loss = jax.jit(fresh_loss_fn)
        self._sq_dist = jax.jit(tree_sq_dist)
        self._aggregate = jax.jit(
            lambda p, d, w: aggregate(p, d, w, fl.global_lr, fl.buffer_size))
        self.round_log: List[Dict] = []

    # ------------------------------------------------------------------
    def receive(self, client_id: int, delta: Any, base_version: int,
                data_size: int,
                fresh_batch_fn: Optional[Callable[[], Any]] = None,
                fresh_batches: Optional[Dict[int, Any]] = None) -> bool:
        """Buffer one upload; aggregate if K reached. Returns True if a new
        global version was produced. ``fresh_batch_fn`` is stored per entry
        and called at aggregation time (the P_i probe uses x^t, not the
        model version at upload time)."""
        e = BufferEntry(client_id=client_id, delta=delta,
                        base_version=base_version, data_size=data_size)
        e.fresh_batch_fn = fresh_batch_fn  # attach probe callback
        self.buffer.add(e)
        if self.buffer.ready():
            self._do_aggregate()
            return True
        return False

    # ------------------------------------------------------------------
    def _do_aggregate(self) -> None:
        fl = self.fl
        entries = self.buffer.drain()
        k = len(entries)

        # eq. 3 — exact distances from snapshots
        dists = []
        taus = []
        for e in entries:
            base = self.history.get(e.base_version)
            if base is None:  # older than the ring: treat as max-stale
                oldest = min(v for v in range(self.version + 1)
                             if v in self.history)
                base = self.history.get(oldest)
            dists.append(float(self._sq_dist(self.params, base)))
            taus.append(self.version - e.base_version)
        sq_dists = jnp.asarray(dists, jnp.float32)
        s = staleness_degree(sq_dists)

        # eq. 4 — fresh-loss probe of x^t on each buffered client's data
        losses = []
        for e in entries:
            if getattr(e, "fresh_batch_fn", None) is not None:
                losses.append(float(self._fresh_loss(self.params, e.fresh_batch_fn())))
            else:
                losses.append(1.0)
        p = statistical_effect(jnp.asarray(losses, jnp.float32),
                               jnp.asarray([e.data_size for e in entries], jnp.float32))

        w = contribution_weights(fl.weighting, p, s,
                                 jnp.asarray(taus, jnp.float32),
                                 s_min=fl.s_min, poly_a=fl.poly_a,
                                 normalize=fl.normalize)
        stacked = tree_stack([e.delta for e in entries])
        self.params, _ = self._aggregate(self.params, stacked, w)
        self.version += 1
        self.history.put(self.version, self.params)
        self.round_log.append({
            "version": self.version,
            "weights": np.asarray(w).tolist(),
            "staleness_deg": np.asarray(s).tolist(),
            "stat_effect": np.asarray(p).tolist(),
            "tau": taus,
            "clients": [e.client_id for e in entries],
            "k": k,
        })


class SyncServer:
    """FedAvg: waits for all selected clients, size-weighted average."""

    def __init__(self, init_params: Any, fl: FLConfig):
        self.fl = fl
        self.params = init_params
        self.version = 0
        self._aggregate = jax.jit(
            lambda p, d, w, k: aggregate(p, d, w, fl.global_lr, k),
            static_argnames=("k",))

    def round(self, deltas: List[Any], data_sizes: List[int]) -> None:
        k = len(deltas)
        w = jnp.asarray(data_sizes, jnp.float32)
        w = w * k / jnp.sum(w)  # size-weighted, mean-1 normalised
        stacked = tree_stack(deltas)
        self.params, _ = self._aggregate(self.params, stacked, w, k)
        self.version += 1

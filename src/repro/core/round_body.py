"""The paper's round body — ONE implementation for engine and cohort.

Before this module the eq. 3/4/5 round existed twice: once inside
``sim/engine.py::_make_chunk_step`` (gather stale bases from the version
ring, vmap K local updates, probe, server round, write the ring) and once
in ``core/cohort.py`` (the replicated-client SPMD mapping, with its own
copy of the local-update / probe / flatten plumbing). ``make_round_body``
is now the single source of both: the engine wraps it in the version-ring
gather/write (``make_ring_round``), the cohort step wraps it in its
slot-resync state machine, and agreement between the two is pinned by
construction (tests/test_round_body.py).

    bases   (K, ...) pytree   stale base snapshots the clients pulled
    batch   (K, M, b, ...)    M local-step batches per client
    probe   (K, bp, ...)      eq. 4 fresh-loss probe batches
    ------------------------------------------------------------------
    deltas = vmap(local_update)(start, batch)          K clients, 1 launch
    losses = vmap(loss(params, probe_k))               eq. 4
    x', info = apply_server_round(flat(params), ...)   eq. 3 + 5

Three entry shapes — one per deployment mapping (DESIGN.md §2.1/§6):

* ``client_params=None`` (the engine): every client trains from the base
  it pulled, so the upload delta IS the local-update delta — bitwise
  identical to the pre-refactor engine. ``flat_bases``/``return_flat``
  let the engine's flat-sharded version ring feed bases in and take the
  new params out as (n_padded,) flat vectors.
* ``client_params`` given (the cohort): slots carry local progress across
  rounds (stragglers), so training starts from ``client_params`` and the
  upload delta is measured from the pulled base,
  ``Delta_i = base_i - end_i``; ``end_params`` is returned for the
  cohort's resync.
* ``make_streaming_round_body`` (the distributed client): one client
  spans the mesh, the K-buffer fills across sequential calls, and only
  O(1) state is carried — a params-shaped running accumulator, (K,)
  scalar weight buffers, and the update-norm ring for eq. 3 distances.
  The per-upload weight runs the SAME ``weighting.py`` policy code as
  the exact paths (``s_min`` cap included), with the eq. 3 reference
  pinned to the current model.

Mesh scale-out (DESIGN.md §5): with ``mesh``, the K-client vmap is
sharded over the ``data`` axis via ``shard_map`` (local training and
probes are embarrassingly parallel over K — no collectives), and the
flat-vector server pass is sharded over ``model`` inside
``apply_server_round``. Both shardings degrade gracefully: no mesh, a
size-1 axis, or a K not divisible by the data-axis size fall back to the
single-device path, so existing callers are untouched.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from typing import NamedTuple

from repro.configs.base import FLConfig
from repro.core.client import make_local_update_fn
from repro.core.version_store import resolve_codec
from repro.core.server_pass import (
    apply_server_round,
    flatten_stacked,
    flatten_tree,
    make_flat_spec,
    resolve_mode,
    unflatten_like,
    unflatten_stacked,
)
from repro.core.weighting import (
    contribution_weights,
    staleness_degree,
    statistical_effect,
)
from repro.sharding.specs import (
    DATA_AXIS,
    MODEL_AXIS,
    flat_param_pspec,
    kclient_pspec,
    mesh_axis_size,
)
from repro.utils.pytree import tree_sub


def make_round_body(loss_fn: Callable, fl: FLConfig, *,
                    mesh: Any = None) -> Callable:
    """Build the shared round body.

    Returns ``body(params, bases, batch, probe, data_sizes, taus, *,
    client_params=None, arrival_mask=None) -> (new_params, end_params,
    info)`` — jit-safe, scan-safe. ``end_params`` is None on the engine
    path (``client_params=None``).
    """
    local_update = make_local_update_fn(loss_fn, fl.local_steps, fl.local_lr,
                                        fl.local_momentum)
    mode, interpret = resolve_mode(fl.server_pass_mode)
    data_shards = mesh_axis_size(mesh, DATA_AXIS)

    def engine_phase(params, bases, batch, probe):
        deltas, _ = jax.vmap(local_update)(bases, batch)
        losses = jax.vmap(lambda pb: loss_fn(params, pb)[0])(probe)
        return deltas, losses.astype(jnp.float32)

    def cohort_phase(params, client_params, bases, batch, probe):
        # in-flight slots advance M steps from their CURRENT local state
        deltas_cur, _ = jax.vmap(local_update)(client_params, batch)
        end_params = jax.vmap(tree_sub)(client_params, deltas_cur)
        end_params = jax.tree.map(lambda e, c: e.astype(c.dtype), end_params,
                                  client_params)
        # cumulative upload delta measured from the pulled base (Delta_i)
        up_delta = jax.vmap(tree_sub)(bases, end_params)
        losses = jax.vmap(lambda pb: loss_fn(params, pb)[0],
                          in_axes=(0,))(probe)
        return up_delta, end_params, losses.astype(jnp.float32)

    def sharded_over_clients(phase, params, *stacked):
        """Run ``phase`` with its K-stacked args/results over ``data``."""
        k = jax.tree.leaves(stacked[0])[0].shape[0]
        if data_shards > 1 and k % data_shards:
            warnings.warn(
                f"K={k} clients do not divide the data axis "
                f"({data_shards} shards): the K-client local-update vmap "
                "runs unsharded (replicated over data). Pick K a multiple "
                "of the data-axis size to shard it.",
                RuntimeWarning, stacklevel=2)
        if data_shards <= 1 or k % data_shards:
            return phase(params, *stacked)
        return shard_map(
            phase, mesh,
            in_specs=(P(),) + (kclient_pspec(),) * len(stacked),
            out_specs=kclient_pspec(),  # every result is K-leading
            check_rep=False)(params, *stacked)

    def body(params, bases, batch, probe, data_sizes, taus, *,
             client_params: Optional[Any] = None,
             arrival_mask: Optional[jnp.ndarray] = None,
             flat_bases: bool = False, return_flat: bool = False,
             sq_dists: Optional[jnp.ndarray] = None):
        """``flat_bases=True`` takes ``bases`` as the (K, n_padded) flat
        rows the sharded version ring stores (DESIGN.md §6) instead of a
        stacked pytree; ``return_flat=True`` replaces the ``end_params``
        return slot with the (n_padded,) flat new-params vector so the
        engine's ring write never leaves flat space (engine path only —
        ``client_params`` must be None). ``sq_dists`` carries precomputed
        eq. 3 distances from a compressed version store
        (``core/version_store.py``) into ``apply_server_round`` — the
        codec computed them against its compressed rows directly, so the
        server pass must not recompute them from the decoded bases."""
        spec = make_flat_spec(params, fl.server_pass_block_n, mesh=mesh)
        if flat_bases:
            bases_flat = bases
            bases = unflatten_stacked(spec, bases_flat, params)
        else:
            bases_flat = flatten_stacked(spec, bases)
        if client_params is None:
            deltas, losses = sharded_over_clients(
                engine_phase, params, bases, batch, probe)
            up_delta, end_params = deltas, None
        else:
            assert not return_flat, "return_flat is engine-path only"
            up_delta, end_params, losses = sharded_over_clients(
                cohort_phase, params, client_params, bases, batch, probe)
        new_x, info = apply_server_round(
            flatten_tree(spec, params),
            bases_flat,
            flatten_stacked(spec, up_delta),
            losses, data_sizes, taus, fl, arrival_mask=arrival_mask,
            mode=mode, block_n=spec.block_n, interpret=interpret, mesh=mesh,
            sq_dists=sq_dists)
        new_params = unflatten_like(spec, new_x, params)
        if not return_flat:
            return new_params, end_params, info
        # the flat vector the ring stores must hold the values clients
        # actually receive: for all-f32 templates new_x already does
        # (skip the round-trip); lower-precision params re-flatten the
        # dtype-cast tree so a fresh (tau=0) client's eq. 3 distance
        # stays exactly 0
        if all(jnp.dtype(dt) == jnp.float32 for dt in spec.dtypes):
            flat_new = new_x
        else:
            flat_new = flatten_tree(spec, new_params)
        if mesh is not None and mesh_axis_size(mesh, MODEL_AXIS) > 1:
            # the ring row must stay on the ring's P(None, "model") layout
            # so the engine's slot write is shard-local — on a
            # process-spanning mesh an unconstrained re-flatten would let
            # the partitioner replicate the row (a cross-process
            # broadcast per round) before the write re-shards it
            flat_new = jax.lax.with_sharding_constraint(
                flat_new, jax.sharding.NamedSharding(mesh,
                                                     flat_param_pspec()))
        return new_params, flat_new, info

    return body


def make_ring_round(loss_fn: Callable, fl: FLConfig, *,
                    mesh: Any = None) -> Callable:
    """The engine flavour: version-store gather -> round body -> store write.

    Returns ``ring_round(params, ring, slots, batch, probe, sizes, taus,
    new_slot) -> (new_params, new_ring, info)``. ``ring`` is whatever
    state the ``FLConfig.ring_codec`` codec keeps
    (``core/version_store.py``, DESIGN.md §11): for the default ``f32``
    codec the raw (R, n_padded) f32 matrix on the ``ShardedFlatSpec``
    layout (DESIGN.md §6) — gather ``ring[slots]``, write
    ``.at[new_slot].set(new_x)``, the bitwise pre-codec program — and a
    codec NamedTuple (int8 codewords + scales, or sparse deltas + base)
    otherwise. Gather/decode and the new-slot encode both happen in flat
    space (the round body hands back the flat new-params vector), and
    the state advances in place so a ``lax.scan`` over rounds never
    leaves the device. Compressed codecs also hand ``apply_server_round``
    their own eq. 3 distances (fused dequantize-distance kernel /
    sparse expansion), so the K decoded f32 rows feed ONLY the K-client
    local-update vmap — never a second full-width distance pass.
    """
    body = make_round_body(loss_fn, fl, mesh=mesh)
    codec = resolve_codec(fl)
    mode, interpret = resolve_mode(fl.server_pass_mode)
    use_kernel = mode in ("batched", "fused")

    def ring_round(params, ring, slots, batch, probe, sizes, taus, new_slot):
        spec = make_flat_spec(params, fl.server_pass_block_n, mesh=mesh)
        bases = codec.decode(spec, ring, slots)  # (K, n_padded) flat rows
        dists = None
        if codec.precomputes_distance:  # f32 leaves eq. 3 to the server
            # pass (the exact pre-codec program — nothing extra traced)
            dists = codec.distance_sq(
                spec, ring, slots, flatten_tree(spec, params), mesh=mesh,
                use_kernel=use_kernel, interpret=interpret)
        new_params, new_x, info = body(params, bases, batch, probe, sizes,
                                       taus, flat_bases=True,
                                       return_flat=True, sq_dists=dists)
        new_ring = codec.encode(spec, ring, new_slot, new_x)
        return new_params, new_ring, info

    return ring_round


# ---------------------------------------------------------------------------
# streaming entry shape (distributed-client mapping, DESIGN.md §6)
# ---------------------------------------------------------------------------


class StreamingRoundBody(NamedTuple):
    """The O(1)-memory running-accumulator form of the round (third entry
    shape). ``contribute`` folds one buffered upload into the running
    state; ``apply`` completes eq. 5 once the buffer is full. The caller
    (``core/cohort.py::make_dist_step``) owns only the state machine —
    ALL weighting arithmetic lives here and in ``core/weighting.py``.
    """

    contribute: Callable
    apply: Callable


def make_streaming_round_body(loss_fn: Callable,
                              fl: FLConfig) -> StreamingRoundBody:
    """Build the streaming (distributed-client) form of the round.

    One client spans the whole mesh (FSDP x TP), so the K-buffer fills
    across sequential calls and only O(1) state is carried: a
    params-shaped accumulator ``sum_i v_i * Delta_i``, the (K,) scalar
    weight buffer ``v_i``, and the (max_staleness,) update-norm ring that
    estimates eq. 3 squared distances (cross terms dropped; ring[0] is
    the newest update).

    The per-upload weight ``v_i`` is the SAME ``weighting.py`` policy the
    exact paths run — ``contribution_weights(..., normalize="none")`` on
    the (1,)-slot vectors, including the ``s_min`` cap — with one
    convention: the eq. 3 reference distance is pinned to 0.0 (the
    current model, ``staleness_degree(..., ref_sq_dist=0.0)``) because
    the buffer-wide ``min_j`` is unknown until the buffer is full, after
    the earlier deltas have already been folded away. Whenever the buffer
    holds a fresh (tau=0) update the pinned reference equals the true
    min and the streaming weights match the exact path EXACTLY, cap
    included; with every update stale, staleness is measured against the
    current model instead of the freshest buffered update, which engages
    the ``s_min`` cap earlier. Under ``normalize="mean"`` (the default)
    only weight RATIOS matter, so that shift is conservative — the
    relative up-weighting of staler updates can only saturate at the
    cap. Under ``normalize="none"`` the absolute magnitude matters too
    and an all-stale buffer diverges from the exact reference: ``paper``
    saturates every weight at P/s_min (step inflated by up to 1/s_min),
    ``multiplicative`` shrinks weights toward eps*P/d (step nearly
    vanishes) — prefer mean normalization for this mapping. See
    DESIGN.md §6 for the full coverage statement. ``apply`` finishes
    with ``contribution_weights``'s normalization semantics: ``mean``
    divides by ``sum v_i`` (the K/K factors cancel), ``none`` by
    ``k_eff`` alone.

    ``contribute(params, accum, update_norm_ring, batch, probe,
    data_size, tau) -> (new_accum, v, fresh)`` and
    ``apply(params, accum, v_buf, count, update_norm_ring) ->
    (new_params, new_ring)``.
    """
    if fl.normalize not in ("mean", "none"):  # match contribution_weights
        raise ValueError(f"unknown normalize {fl.normalize!r}")
    local_update = make_local_update_fn(loss_fn, fl.local_steps, fl.local_lr,
                                        fl.local_momentum)

    def contribute(params, accum, update_norm_ring, batch, probe, data_size,
                   tau):
        delta, _ = local_update(params, batch)

        # eq. 4 probe of the CURRENT model
        fresh = loss_fn(params, probe)[0].astype(jnp.float32)
        p = statistical_effect(fresh[None], data_size[None])

        # eq. 3 distance via the scalar update-norm ring (cross terms
        # dropped): ||x^t - x^{t-tau}||^2 ~= sum of the last tau ||u||^2
        tau = jnp.minimum(tau, fl.max_staleness - 1)
        recent = jnp.arange(fl.max_staleness) < tau  # ring[0] = newest
        d = jnp.sum(update_norm_ring * recent)

        # the exact policy code on this one slot (cap, poly, ...) with the
        # reference pinned to the current model; normalization is deferred
        # to apply, where the full v-buffer exists
        s = staleness_degree(d[None], ref_sq_dist=0.0)
        v = contribution_weights(fl.weighting, p, s,
                                 tau[None].astype(jnp.float32),
                                 s_min=fl.s_min, poly_a=fl.poly_a,
                                 hinge_a=fl.hinge_a, hinge_b=fl.hinge_b,
                                 normalize="none")[0]
        new_accum = jax.tree.map(
            lambda a, dl: a + (v * dl.astype(jnp.float32)).astype(a.dtype),
            accum, delta)
        return new_accum, v, fresh

    def apply(params, accum, v_buf, count, update_norm_ring):
        # eq. 5 on the running accumulator: x - eta_g/k_eff * sum w_i D_i
        # with w_i = v_i * k_eff / sum v_j ("mean") or w_i = v_i ("none")
        # — identical semantics to contribution_weights + apply_server_round
        k_eff = jnp.maximum(count.astype(jnp.float32), 1.0)
        if fl.normalize == "mean":
            scale = fl.global_lr / jnp.maximum(jnp.sum(v_buf), 1e-12)
        else:
            scale = fl.global_lr / k_eff
        upd = jax.tree.map(lambda a: scale * a.astype(jnp.float32), accum)
        new_params = jax.tree.map(lambda x, u: (x - u.astype(x.dtype)),
                                  params, upd)
        unorm = jnp.sum(jnp.stack([jnp.sum(jnp.square(u))
                                   for u in jax.tree.leaves(upd)]))
        new_ring = jnp.concatenate([unorm[None], update_norm_ring[:-1]])
        return new_params, new_ring

    return StreamingRoundBody(contribute=contribute, apply=apply)

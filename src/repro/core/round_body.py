"""The paper's round body — ONE implementation for engine and cohort.

Before this module the eq. 3/4/5 round existed twice: once inside
``sim/engine.py::_make_chunk_step`` (gather stale bases from the version
ring, vmap K local updates, probe, server round, write the ring) and once
in ``core/cohort.py`` (the replicated-client SPMD mapping, with its own
copy of the local-update / probe / flatten plumbing). ``make_round_body``
is now the single source of both: the engine wraps it in the version-ring
gather/write (``make_ring_round``), the cohort step wraps it in its
slot-resync state machine, and agreement between the two is pinned by
construction (tests/test_round_body.py).

    bases   (K, ...) pytree   stale base snapshots the clients pulled
    batch   (K, M, b, ...)    M local-step batches per client
    probe   (K, bp, ...)      eq. 4 fresh-loss probe batches
    ------------------------------------------------------------------
    deltas = vmap(local_update)(start, batch)          K clients, 1 launch
    losses = vmap(loss(params, probe_k))               eq. 4
    x', info = apply_server_round(flat(params), ...)   eq. 3 + 5

Two entry shapes, selected by ``client_params``:

* ``client_params=None`` (the engine): every client trains from the base
  it pulled, so the upload delta IS the local-update delta — bitwise
  identical to the pre-refactor engine.
* ``client_params`` given (the cohort): slots carry local progress across
  rounds (stragglers), so training starts from ``client_params`` and the
  upload delta is measured from the pulled base,
  ``Delta_i = base_i - end_i``; ``end_params`` is returned for the
  cohort's resync.

Mesh scale-out (DESIGN.md §5): with ``mesh``, the K-client vmap is
sharded over the ``data`` axis via ``shard_map`` (local training and
probes are embarrassingly parallel over K — no collectives), and the
flat-vector server pass is sharded over ``model`` inside
``apply_server_round``. Both shardings degrade gracefully: no mesh, a
size-1 axis, or a K not divisible by the data-axis size fall back to the
single-device path, so existing callers are untouched.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import FLConfig
from repro.core.client import make_local_update_fn
from repro.core.server_pass import (
    apply_server_round,
    flatten_stacked,
    flatten_tree,
    make_flat_spec,
    resolve_mode,
    unflatten_like,
)
from repro.sharding.specs import DATA_AXIS, kclient_pspec, mesh_axis_size
from repro.utils.pytree import tree_sub


def make_round_body(loss_fn: Callable, fl: FLConfig, *,
                    mesh: Any = None) -> Callable:
    """Build the shared round body.

    Returns ``body(params, bases, batch, probe, data_sizes, taus, *,
    client_params=None, arrival_mask=None) -> (new_params, end_params,
    info)`` — jit-safe, scan-safe. ``end_params`` is None on the engine
    path (``client_params=None``).
    """
    local_update = make_local_update_fn(loss_fn, fl.local_steps, fl.local_lr,
                                        fl.local_momentum)
    mode, interpret = resolve_mode(fl.server_pass_mode)
    data_shards = mesh_axis_size(mesh, DATA_AXIS)

    def engine_phase(params, bases, batch, probe):
        deltas, _ = jax.vmap(local_update)(bases, batch)
        losses = jax.vmap(lambda pb: loss_fn(params, pb)[0])(probe)
        return deltas, losses.astype(jnp.float32)

    def cohort_phase(params, client_params, bases, batch, probe):
        # in-flight slots advance M steps from their CURRENT local state
        deltas_cur, _ = jax.vmap(local_update)(client_params, batch)
        end_params = jax.vmap(tree_sub)(client_params, deltas_cur)
        end_params = jax.tree.map(lambda e, c: e.astype(c.dtype), end_params,
                                  client_params)
        # cumulative upload delta measured from the pulled base (Delta_i)
        up_delta = jax.vmap(tree_sub)(bases, end_params)
        losses = jax.vmap(lambda pb: loss_fn(params, pb)[0],
                          in_axes=(0,))(probe)
        return up_delta, end_params, losses.astype(jnp.float32)

    def sharded_over_clients(phase, params, *stacked):
        """Run ``phase`` with its K-stacked args/results over ``data``."""
        k = jax.tree.leaves(stacked[0])[0].shape[0]
        if data_shards > 1 and k % data_shards:
            warnings.warn(
                f"K={k} clients do not divide the data axis "
                f"({data_shards} shards): the K-client local-update vmap "
                "runs unsharded (replicated over data). Pick K a multiple "
                "of the data-axis size to shard it.",
                RuntimeWarning, stacklevel=2)
        if data_shards <= 1 or k % data_shards:
            return phase(params, *stacked)
        return shard_map(
            phase, mesh,
            in_specs=(P(),) + (kclient_pspec(),) * len(stacked),
            out_specs=kclient_pspec(),  # every result is K-leading
            check_rep=False)(params, *stacked)

    def body(params, bases, batch, probe, data_sizes, taus, *,
             client_params: Optional[Any] = None,
             arrival_mask: Optional[jnp.ndarray] = None):
        spec = make_flat_spec(params, fl.server_pass_block_n, mesh=mesh)
        if client_params is None:
            deltas, losses = sharded_over_clients(
                engine_phase, params, bases, batch, probe)
            up_delta, end_params = deltas, None
        else:
            up_delta, end_params, losses = sharded_over_clients(
                cohort_phase, params, client_params, bases, batch, probe)
        new_x, info = apply_server_round(
            flatten_tree(spec, params),
            flatten_stacked(spec, bases),
            flatten_stacked(spec, up_delta),
            losses, data_sizes, taus, fl, arrival_mask=arrival_mask,
            mode=mode, block_n=spec.block_n, interpret=interpret, mesh=mesh)
        return unflatten_like(spec, new_x, params), end_params, info

    return body


def make_ring_round(loss_fn: Callable, fl: FLConfig, *,
                    mesh: Any = None) -> Callable:
    """The engine flavour: version-ring gather -> round body -> ring write.

    Returns ``ring_round(params, ring, slots, batch, probe, sizes, taus,
    new_slot) -> (new_params, new_ring, info)``; the ring is a pytree
    whose leaves carry a leading (R,) version axis, device-resident and
    advanced in place (``.at[new_slot].set``) so a ``lax.scan`` over
    rounds never leaves the device.
    """
    body = make_round_body(loss_fn, fl, mesh=mesh)

    def ring_round(params, ring, slots, batch, probe, sizes, taus, new_slot):
        bases = jax.tree.map(lambda r: r[slots], ring)
        new_params, _, info = body(params, bases, batch, probe, sizes, taus)
        new_ring = jax.tree.map(
            lambda r, p: r.at[new_slot].set(p.astype(r.dtype)),
            ring, new_params)
        return new_params, new_ring, info

    return ring_round

"""Structured event sinks + the repo-wide stdlib-logging configurator.

``JsonlSink`` is the durable export surface of the observability plane:
one JSON object per line, append-only, so a nightly job can diff
snapshots across runs and the multihost merge path can concatenate
per-process files. Writes are **coordinator-gated by default** (process
0 only, the same ``launch/multihost.is_coordinator`` gate checkpoint IO
uses) — every process may emit, one writes. ``InMemorySink`` is the
test double with identical semantics minus the filesystem.

``configure_logging`` is the single place log format and level are
decided: launchers expose ``--log-level`` and call it once; library
modules just ``logging.getLogger(__name__)``. Idempotent — the second
caller adjusts the level instead of stacking handlers.
"""
from __future__ import annotations

import json
import logging
import sys
import time
from typing import Any, Callable, Dict, List, Optional

LOG_FORMAT = "%(asctime)s %(levelname)-7s %(name)s: %(message)s"
LOG_DATEFMT = "%H:%M:%S"

_configured = False


def configure_logging(level: str = "info",
                      stream=None) -> logging.Logger:
    """Install the repo's one log format on the root logger and set the
    level (``debug``/``info``/``warning``/``error`` or a numeric
    string). Returns the ``repro`` namespace logger. Safe to call
    repeatedly: later calls only move the level."""
    global _configured
    lvl = (int(level) if str(level).isdigit()
           else getattr(logging, str(level).upper(), None))
    if not isinstance(lvl, int):
        raise ValueError(f"unknown log level {level!r}")
    root = logging.getLogger()
    if not _configured:
        handler = logging.StreamHandler(stream or sys.stderr)
        handler.setFormatter(logging.Formatter(LOG_FORMAT, LOG_DATEFMT))
        root.addHandler(handler)
        _configured = True
    root.setLevel(lvl)
    logger = logging.getLogger("repro")
    logger.setLevel(lvl)
    return logger


def _default_gate() -> bool:
    """Process-0 gate; True when jax/distributed is absent (plain runs)."""
    try:
        from repro.launch.multihost import is_coordinator

        return is_coordinator()
    except Exception:
        return True


class InMemorySink:
    """Test double: events land in ``.events`` (always, no gate) so
    assertions see exactly what a JSONL file would contain."""

    def __init__(self):
        self.events: List[Dict[str, Any]] = []

    def emit(self, event: Dict[str, Any]) -> None:
        self.events.append(dict(event))

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class JsonlSink:
    """Append-only JSONL event sink, coordinator-gated.

    The file is opened lazily on the first gated-through ``emit`` so a
    non-coordinator process never creates (or truncates) the path — the
    property the forced-multihost lane pins. ``gate`` is injectable for
    tests; ``stamp=True`` (default) adds a ``t`` wall-clock field to
    every event.
    """

    def __init__(self, path: str, gate: Optional[Callable[[], bool]] = None,
                 stamp: bool = True):
        self.path = path
        self._gate = gate if gate is not None else _default_gate
        self._stamp = stamp
        self._f = None
        self._gated: Optional[bool] = None

    def emit(self, event: Dict[str, Any]) -> None:
        if self._gated is None:
            self._gated = bool(self._gate())
        if not self._gated:
            return
        if self._f is None:
            self._f = open(self.path, "a")
        if self._stamp and "t" not in event:
            event = {**event, "t": time.time()}
        self._f.write(json.dumps(event) + "\n")

    def flush(self) -> None:
        if self._f is not None:
            self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


def emit_snapshot(sink, registry, *, kind: str = "metrics_snapshot",
                  **extra) -> None:
    """One snapshot event of ``registry`` into ``sink`` — the periodic
    flush the launchers schedule (serve_fl --metrics-out)."""
    sink.emit({"event": kind, **extra, "metrics": registry.snapshot()})

"""Unified observability plane (DESIGN.md §9).

Three dependency-free pieces every runner reports into:

* ``obs.metrics`` — process-local registry of counters / gauges /
  fixed-bucket histograms with a flat ``snapshot()`` export and a
  coordinator-gated multihost merge path;
* ``obs.trace`` — round-lifecycle span tracer emitting Chrome-trace
  JSON, each span doubling as a ``jax.profiler.TraceAnnotation`` so
  windowed device profiles line up with host spans;
* ``obs.sink`` — JSONL event sink (coordinator-gated) plus the repo's
  stdlib-logging configurator.
"""
from repro.obs.metrics import (  # noqa: F401
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    export_snapshot,
    merge_snapshots,
)
from repro.obs.sink import (  # noqa: F401
    InMemorySink,
    JsonlSink,
    configure_logging,
    emit_snapshot,
)
from repro.obs.trace import (  # noqa: F401
    NULL_TRACER,
    SPAN_APPLY,
    SPAN_CHECKPOINT,
    SPAN_COLLECT,
    SPAN_CONTRIBUTE,
    SPAN_HOST_SYNC,
    SPAN_NAMES,
    Tracer,
    WindowedProfiler,
    span_coverage,
    validate_trace,
)

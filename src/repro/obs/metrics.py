"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

The measurement plane the rest of the repo reports into (DESIGN.md §9).
Three deliberate constraints shape it:

* **Dependency-free and allocation-light.** A counter increment is one
  attribute add on a long-lived object — cheap enough to sit on the
  serving fold path and the engine dispatch path, whose throughput the
  nightly gate pins (< 5% overhead budget on the default lane). No
  prometheus client, no background threads, no locks (the runners are
  single-threaded host loops; a real transport front-end would own its
  own registry per worker).

* **Series identity is (name, labels).** ``registry.counter("x", k="v")``
  get-or-creates, so call sites never hold module globals; repeated
  lookups return the same instrument. ``snapshot()`` flattens every
  series to ``name{k=v,...} -> float`` — the stable export surface the
  JSONL sink writes and ``benchmarks/check_regression.py``-style diffing
  consumes.

* **Multihost merging is a pure function over snapshots.** Under the
  multi-controller model (DESIGN.md §7) every process runs the same host
  loop, so host-side series agree by determinism; device-local series
  differ per process. ``merge_snapshots`` sums counter/histogram series
  and last-wins gauges, and ``export_snapshot`` gates emission on
  ``launch/multihost.is_coordinator`` so only process 0 writes (the same
  gate checkpoint IO uses).

Histograms are fixed-bucket (prometheus-style cumulative ``le`` edges):
``observe`` is a bisect + two adds, and ``quantile`` reconstructs
percentiles by linear interpolation inside the winning bucket — accuracy
is bounded by bucket width, pinned against numpy percentiles in
tests/test_obs.py.
"""
from __future__ import annotations

import bisect
import math
from typing import Any, Dict, Iterable, Mapping, Optional, Tuple

# Default edges suit the latencies this repo measures: sub-ms jit
# dispatches up to minute-scale round cadences (seconds).
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


def _series_key(name: str, labels: Mapping[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic float counter; ``inc`` only goes up."""

    __slots__ = ("key", "value")

    def __init__(self, key: str):
        self.key = key
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.key} cannot decrease (inc {n})")
        self.value += n


class Gauge:
    """Last-written value (queue depth, current K, arrival-rate estimate)."""

    __slots__ = ("key", "value")

    def __init__(self, key: str):
        self.key = key
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Histogram:
    """Fixed-bucket histogram with cumulative-``le`` export.

    ``counts[i]`` is the number of observations ``<= buckets[i]``
    exclusive of earlier buckets; the final slot is the +inf overflow.
    """

    __slots__ = ("key", "buckets", "counts", "sum", "count")

    def __init__(self, key: str, buckets: Iterable[float] = DEFAULT_BUCKETS):
        edges = tuple(float(b) for b in buckets)
        if not edges or any(nxt <= prev
                            for prev, nxt in zip(edges, edges[1:])):
            raise ValueError(f"histogram {key}: bucket edges must be "
                             f"strictly increasing and non-empty: {edges}")
        self.key = key
        self.buckets = edges
        self.counts = [0] * (len(edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, x: float) -> None:
        self.counts[bisect.bisect_left(self.buckets, x)] += 1
        self.sum += x
        self.count += 1

    def quantile(self, q: float) -> float:
        """Approximate q-quantile by linear interpolation in the bucket
        holding the target rank (NaN when empty; the top finite edge when
        the rank lands in the +inf overflow bucket)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return math.nan
        rank = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= rank and c:
                if i == len(self.buckets):  # +inf overflow: no upper edge
                    return self.buckets[-1]
                lo = 0.0 if i == 0 else self.buckets[i - 1]
                hi = self.buckets[i]
                return lo + (hi - lo) * ((rank - cum) / c)
            cum += c
        return self.buckets[-1]


class MetricsRegistry:
    """Get-or-create instrument store with a flat-dict export.

    One registry per measurement domain: the module-level
    ``default_registry()`` serves the engine/benchmark paths, while a
    ``ServingController`` owns a private registry by default so two
    controllers in one process never alias counters.
    """

    def __init__(self):
        self._series: Dict[str, Any] = {}

    def _get(self, cls, name: str, labels: Mapping[str, Any],
             *args) -> Any:
        key = _series_key(name, labels)
        inst = self._series.get(key)
        if inst is None:
            inst = self._series[key] = cls(key, *args)
        elif not isinstance(inst, cls):
            raise TypeError(f"series {key} already registered as "
                            f"{type(inst).__name__}, not {cls.__name__}")
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str,
                  buckets: Iterable[float] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, buckets)

    def snapshot(self) -> Dict[str, float]:
        """Flat ``series-key -> value`` export (histograms expand to
        cumulative ``_bucket{le=..}`` series plus ``_sum`` / ``_count``),
        sorted for stable diffing."""
        out: Dict[str, float] = {}
        for key, inst in self._series.items():
            if isinstance(inst, Histogram):
                base, labels = _split_key(key)
                cum = 0
                for edge, c in zip(inst.buckets + (math.inf,), inst.counts):
                    cum += c
                    le = "+Inf" if math.isinf(edge) else repr(edge)
                    out[_series_key(f"{base}_bucket",
                                    {**labels, "le": le})] = float(cum)
                out[_series_key(f"{base}_sum", labels)] = float(inst.sum)
                out[_series_key(f"{base}_count", labels)] = float(inst.count)
            else:
                out[key] = float(inst.value)
        return dict(sorted(out.items()))

    def gauge_keys(self) -> frozenset:
        """Series keys that must NOT be summed across processes (pass to
        ``merge_snapshots``): gauges are point-in-time reads."""
        return frozenset(k for k, inst in self._series.items()
                         if isinstance(inst, Gauge))

    def reset(self) -> None:
        self._series.clear()


def _split_key(key: str) -> Tuple[str, Dict[str, str]]:
    if "{" not in key:
        return key, {}
    base, inner = key[:-1].split("{", 1)
    labels = dict(kv.split("=", 1) for kv in inner.split(",") if kv)
    return base, labels


def merge_snapshots(snaps: Iterable[Dict[str, float]],
                    gauge_keys: Iterable[str] = ()) -> Dict[str, float]:
    """Combine per-process snapshots into one: counter and histogram
    series sum; series named in ``gauge_keys`` (point-in-time reads —
    ``MetricsRegistry.gauge_keys()``) keep the last value seen. The
    multihost merge path runs this over per-process JSONL snapshots on
    the coordinator."""
    gauges = frozenset(gauge_keys)
    out: Dict[str, float] = {}
    for snap in snaps:
        for key, v in snap.items():
            out[key] = v if key in gauges else out.get(key, 0.0) + v
    return dict(sorted(out.items()))


_DEFAULT: Optional[MetricsRegistry] = None


def default_registry() -> MetricsRegistry:
    """The process-global registry (engine, benchmarks, launchers)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = MetricsRegistry()
    return _DEFAULT


def export_snapshot(registry: Optional[MetricsRegistry] = None,
                    gate=None) -> Optional[Dict[str, float]]:
    """Coordinator-gated snapshot: the dict on process 0, None elsewhere.

    ``gate`` defaults to ``launch/multihost.is_coordinator`` (True when
    jax is absent or uninitialised, i.e. plain single-process runs); the
    injectable gate keeps the multihost behaviour unit-testable.
    """
    if gate is None:
        try:
            from repro.launch.multihost import is_coordinator as gate
        except Exception:  # obs stays importable without jax
            def gate() -> bool:
                return True
    if not gate():
        return None
    return (registry or default_registry()).snapshot()

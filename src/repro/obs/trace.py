"""Round-lifecycle span tracing: Chrome-trace JSON + device-profile hooks.

``Tracer.span(name)`` times a host-side phase of the round lifecycle and
records it as a Chrome trace event (``ph: "X"`` complete event, micro-
second timestamps) loadable in perfetto / ``chrome://tracing``. The span
taxonomy is fixed (DESIGN.md §9) so traces from every runner line up:

    collect_window   host event-loop window pre-compute / serving wait
    contribute       one streaming fold (serving path)
    apply            the jitted round dispatch (engine chunk / eq. 5)
    host_sync        device -> host fetches (round log, eval metrics)
    checkpoint       state capture + write
    transport_decode wire-frame decode on a transport worker (§12)
    transport_offer  admission call on a transport worker (decode->offer)

Each span also opens a ``jax.profiler.TraceAnnotation`` (when jax is
importable and the profiler is active), so a device profile collected by
``WindowedProfiler`` shows host spans on the same timeline as the XLA
ops they dispatched — the instrument the ROADMAP's real-TPU psum
measurement needs.

Overhead contract: a disabled tracer (``Tracer(enabled=False)``, or the
module ``NULL_TRACER``) returns one shared no-op context manager from
``span`` — no allocation, no clock read — so instrumented code paths
cost nothing when tracing is off (< 5% budget on the default bench lane
even when ON; the nightly ``bench_sim_engine`` gate enforces it).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional

SPAN_COLLECT = "collect_window"
SPAN_CONTRIBUTE = "contribute"
SPAN_APPLY = "apply"
SPAN_HOST_SYNC = "host_sync"
SPAN_CHECKPOINT = "checkpoint"
SPAN_TRANSPORT_DECODE = "transport_decode"
SPAN_TRANSPORT_OFFER = "transport_offer"
SPAN_NAMES = (SPAN_COLLECT, SPAN_CONTRIBUTE, SPAN_APPLY, SPAN_HOST_SYNC,
              SPAN_CHECKPOINT, SPAN_TRANSPORT_DECODE, SPAN_TRANSPORT_OFFER)


def _annotation(name: str):
    """A jax.profiler.TraceAnnotation when jax is importable, else None.

    Lazy so ``repro.obs`` stays importable (and zero-cost) in contexts
    without jax; annotations are cheap no-ops when no profiler session
    is active.
    """
    try:
        import jax

        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return None


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "cat", "args", "t0", "ann")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self.ann = _annotation(self.name) if self.tracer.annotate else None
        if self.ann is not None:
            self.ann.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        if self.ann is not None:
            self.ann.__exit__(*exc)
        self.tracer.complete(self.name, self.t0, t1 - self.t0,
                             cat=self.cat, **self.args)
        return False


class Tracer:
    """Collects Chrome trace events; host wall-clock, microsecond units.

    ``t0`` (the first construction instant) anchors the timeline so
    ``ts`` values stay small; every event carries ``pid`` (the OS pid —
    jax process index when available would alias on one host) and a
    caller-chosen ``tid`` lane (default 0 — the runners are
    single-threaded host loops, so lanes separate *subsystems*, not
    threads).
    """

    def __init__(self, enabled: bool = True, annotate: bool = True,
                 tid: int = 0):
        self.enabled = enabled
        self.annotate = annotate and enabled
        self.tid = tid
        self.events: List[Dict[str, Any]] = []
        self.pid = os.getpid()
        self._t0 = time.perf_counter()

    # -- recording ------------------------------------------------------
    def span(self, name: str, cat: str = "round", **args):
        """Context manager timing one phase; no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args)

    def now(self) -> float:
        """The tracer clock (perf_counter seconds) for retroactive events."""
        return time.perf_counter()

    def complete(self, name: str, t_start: float, duration: float,
                 cat: str = "round", **args) -> None:
        """Record a span retroactively from explicit clock readings —
        how the serving loop emits ``collect_window`` (its extent is only
        known once the K-th fold lands)."""
        if not self.enabled:
            return
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": (t_start - self._t0) * 1e6, "dur": duration * 1e6,
            "pid": self.pid, "tid": self.tid,
            **({"args": args} if args else {})})

    def instant(self, name: str, cat: str = "round", **args) -> None:
        if not self.enabled:
            return
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "p",
            "ts": (time.perf_counter() - self._t0) * 1e6,
            "pid": self.pid, "tid": self.tid,
            **({"args": args} if args else {})})

    # -- export ---------------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        doc = self.to_json()
        validate_trace(doc)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


NULL_TRACER = Tracer(enabled=False)


def validate_trace(doc: Dict[str, Any]) -> int:
    """Assert ``doc`` is loadable Chrome-trace-event JSON; returns the
    event count. The schema the CI smoke lane gates serve_fl's
    ``--trace-out`` against: the JSON-object form with a ``traceEvents``
    list where every complete event carries name/ph/ts/pid/tid and a
    non-negative ``dur``."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace must be a JSON object with 'traceEvents'")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    for i, ev in enumerate(events):
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event {i} missing {field!r}: {ev}")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"event {i}: non-numeric ts {ev['ts']!r}")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"event {i}: complete event needs a "
                                 f"non-negative dur, got {ev.get('dur')!r}")
    return len(events)


def span_coverage(doc: Dict[str, Any], names=(SPAN_COLLECT, SPAN_APPLY),
                  cat: Optional[str] = "round") -> float:
    """Fraction of the round-lifecycle wall-span covered by the union of
    the named spans — the acceptance metric for serve_fl --trace-out
    (>= 0.95). The denominator runs from the first to the last named
    event, i.e. the measured round window, not process startup."""
    ivs = sorted(
        (ev["ts"], ev["ts"] + ev["dur"]) for ev in doc["traceEvents"]
        if ev.get("ph") == "X" and ev["name"] in names
        and (cat is None or ev.get("cat") == cat))
    if not ivs:
        return 0.0
    total = max(hi for _, hi in ivs) - ivs[0][0]
    if total <= 0:
        return 1.0
    covered, cur_lo, cur_hi = 0.0, ivs[0][0], ivs[0][0]
    for lo, hi in ivs:
        if lo > cur_hi:
            covered += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        else:
            cur_hi = max(cur_hi, hi)
    covered += cur_hi - cur_lo
    return covered / total


class WindowedProfiler:
    """Windowed ``jax.profiler`` capture: a full device profile every
    ``every`` rounds, ``window`` rounds long, written under
    ``profile_dir/round_<n>``. Combined with the per-span
    ``TraceAnnotation`` this lines device timelines up with the host
    spans; windowing keeps always-on services from growing unbounded
    profiles. ``every=0`` disables (the default)."""

    def __init__(self, profile_dir: Optional[str], every: int = 0,
                 window: int = 1):
        if every and window < 1:
            raise ValueError("profiler window must be >= 1 round")
        self.profile_dir = profile_dir
        self.every = every if profile_dir else 0
        self.window = window
        self._active_until: Optional[int] = None

    def on_round(self, round_idx: int) -> None:
        """Call once per completed round with its index."""
        if not self.every:
            return
        import jax

        if self._active_until is None and round_idx % self.every == 0:
            path = os.path.join(self.profile_dir, f"round_{round_idx}")
            os.makedirs(path, exist_ok=True)
            jax.profiler.start_trace(path)
            self._active_until = round_idx + self.window
        elif self._active_until is not None \
                and round_idx >= self._active_until:
            jax.profiler.stop_trace()
            self._active_until = None

    def close(self) -> None:
        if self._active_until is not None:
            import jax

            jax.profiler.stop_trace()
            self._active_until = None

"""Pytree checkpointing to .npz (orbax is unavailable in this environment).

Flattens a pytree with jax.tree_util key-paths so arbitrary nested
dict/list/tuple/NamedTuple structures round-trip. The treedef is restored
from a caller-provided template (``like=``) which keeps loading safe and
simple; a structure-free load returns a flat {keypath: array} dict.

Multi-host (DESIGN.md §7): ``save_checkpoint`` is coordinator-gated —
every process converts its leaves to host numpy (process-spanning arrays
are read from process-local addressable shards, with one resharding
collective for non-replicated leaves, so ALL processes must call save),
but only process 0 touches the filesystem. The engine's state
(``sim/engine.py::engine_state_to_tree``) is identical on every process
by the multi-controller determinism contract, so the coordinator's file
is the global truth.

Compressed version rings (``core/version_store.py``, DESIGN.md §11)
serialize through the same keypath flattening: the f32 codec's ring is
the bare ``['ring']`` (R, Np) f32 entry — byte-compatible with every
pre-codec checkpoint — while int8/delta rings nest a dict of arrays
(``['ring']['codes']``, ``['ring']['scale']``, ...) stamped with the
codec name, restored bit-identically by ``init_version_ring(rows=...)``
which raises a codec-aware layout error on mismatch.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _key_str(path) -> str:
    return jax.tree_util.keystr(path)


def _is_coordinator() -> bool:
    # delegate so the coordinator convention lives in ONE place
    # (lazy import: jax-only module, but keep ckpt import-light)
    from repro.launch.multihost import is_coordinator
    return is_coordinator()


def _to_host(v) -> np.ndarray:
    """Leaf -> host numpy, safe for process-spanning jax.Arrays."""
    if isinstance(v, jax.Array) and not v.is_fully_addressable:
        from repro.launch.multihost import fetch_replicated
        return fetch_replicated(v)
    return np.asarray(v)


def save_checkpoint(path: str, tree: Any, step: Optional[int] = None, *,
                    coordinator_only: bool = True) -> None:
    """Write ``tree`` to ``path`` atomically (tmp file + rename).

    In a multi-process session every process MUST call this (leaf
    fetching may involve a collective for non-replicated arrays), but
    with ``coordinator_only=True`` (the default) only process 0 writes —
    N processes racing one filesystem path is never correct.
    ``coordinator_only=False`` is for process-private paths only.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    payload = {_key_str(p): _to_host(v) for p, v in flat}
    if step is not None:
        payload["__step__"] = np.asarray(step)
    if coordinator_only and not _is_coordinator():
        return
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic write: tmp file + rename
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_checkpoint(path: str, like: Any = None):
    """Load a checkpoint; if ``like`` is given, restore into its structure.

    Returns (tree_or_flat_dict, step_or_None).
    """
    with np.load(path) as z:
        data = {k: z[k] for k in z.files}
    step = int(data.pop("__step__")) if "__step__" in data else None
    if like is None:
        return data, step
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, v in flat:
        k = _key_str(p)
        if k not in data:
            raise KeyError(f"checkpoint missing key {k}")
        arr = data[k]
        if tuple(arr.shape) != tuple(np.shape(v)):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {np.shape(v)}")
        leaves.append(arr.astype(np.asarray(v).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step

"""Pytree checkpointing to .npz (orbax is unavailable in this environment).

Flattens a pytree with jax.tree_util key-paths so arbitrary nested
dict/list/tuple/NamedTuple structures round-trip. The treedef is restored
from a caller-provided template (``like=``) which keeps loading safe and
simple; a structure-free load returns a flat {keypath: array} dict.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _key_str(path) -> str:
    return jax.tree_util.keystr(path)


def save_checkpoint(path: str, tree: Any, step: Optional[int] = None) -> None:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    payload = {_key_str(p): np.asarray(v) for p, v in flat}
    if step is not None:
        payload["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic write: tmp file + rename
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_checkpoint(path: str, like: Any = None):
    """Load a checkpoint; if ``like`` is given, restore into its structure.

    Returns (tree_or_flat_dict, step_or_None).
    """
    with np.load(path) as z:
        data = {k: z[k] for k in z.files}
    step = int(data.pop("__step__")) if "__step__" in data else None
    if like is None:
        return data, step
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, v in flat:
        k = _key_str(p)
        if k not in data:
            raise KeyError(f"checkpoint missing key {k}")
        arr = data[k]
        if tuple(arr.shape) != tuple(np.shape(v)):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {np.shape(v)}")
        leaves.append(arr.astype(np.asarray(v).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step

from repro.models.lenet import apply_lenet, init_lenet, lenet_loss  # noqa: F401
from repro.models.model import Model, build_model  # noqa: F401

"""LeNet-5 (the paper's Fashion-MNIST backbone), pure JAX.

Conv(6,5x5) -> avgpool -> Conv(16,5x5) -> avgpool -> FC120 -> FC84 -> FC10.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_lenet(key, num_classes: int = 10, in_channels: int = 1):
    ks = jax.random.split(key, 5)

    def conv_w(k, kh, kw, cin, cout):
        fan_in = kh * kw * cin
        return jax.random.normal(k, (kh, kw, cin, cout), jnp.float32) * fan_in ** -0.5

    def fc(k, din, dout):
        return jax.random.normal(k, (din, dout), jnp.float32) * din ** -0.5

    return {
        "c1": {"w": conv_w(ks[0], 5, 5, in_channels, 6), "b": jnp.zeros((6,))},
        "c2": {"w": conv_w(ks[1], 5, 5, 6, 16), "b": jnp.zeros((16,))},
        "f1": {"w": fc(ks[2], 16 * 4 * 4, 120), "b": jnp.zeros((120,))},
        "f2": {"w": fc(ks[3], 120, 84), "b": jnp.zeros((84,))},
        "f3": {"w": fc(ks[4], 84, num_classes), "b": jnp.zeros((num_classes,))},
    }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _avgpool2(x):
    return jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID") / 4.0


def apply_lenet(params, x):
    """x: (B, 28, 28, 1) -> logits (B, 10)."""
    h = jnp.tanh(_conv(x, params["c1"]["w"], params["c1"]["b"]))  # (B,24,24,6)
    h = _avgpool2(h)  # (B,12,12,6)
    h = jnp.tanh(_conv(h, params["c2"]["w"], params["c2"]["b"]))  # (B,8,8,16)
    h = _avgpool2(h)  # (B,4,4,16)
    h = h.reshape(h.shape[0], -1)
    h = jnp.tanh(h @ params["f1"]["w"] + params["f1"]["b"])
    h = jnp.tanh(h @ params["f2"]["w"] + params["f2"]["b"])
    return h @ params["f3"]["w"] + params["f3"]["b"]


def lenet_loss(params, batch):
    """batch: (x (B,28,28,1), y (B,)) -> (mean CE, metrics)."""
    x, y = batch
    logits = apply_lenet(params, x)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
    ce = jnp.mean(lse - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return ce, {"ce": ce, "acc": acc}

"""Shared neural-net layers (pure JAX, params are plain dict pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim, out_dim, dtype, scale=None):
    scale = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab, dim, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms (computed in f32, cast back)
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def make_norm_params(cfg, dim, key=None):
    if cfg.norm == "rmsnorm":
        return {"w": jnp.zeros((dim,), jnp.float32)}
    return {"w": jnp.ones((dim,), jnp.float32), "b": jnp.zeros((dim,), jnp.float32)}


def apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["w"])
    return layer_norm(x, p["w"], p["b"])


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (Dh/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, dim: int):
    """Classic transformer sinusoid table, computed on the fly.

    positions: (...,) int -> (..., dim) float32.
    """
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, d_model=None, d_ff=None):
    d_model = d_model or cfg.d_model
    d_ff = d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype, scale=d_ff ** -0.5),
        }
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype, scale=d_ff ** -0.5),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def apply_mlp(cfg, p, x):
    if cfg.activation in ("swiglu", "geglu"):
        gate = x @ p["w_gate"]
        act = jax.nn.silu(gate) if cfg.activation == "swiglu" else jax.nn.gelu(gate)
        return (act * (x @ p["w_up"])) @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    return h @ p["w_down"] + p["b_down"]

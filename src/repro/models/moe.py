"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU adaptation: instead of per-token dynamic routing (GPU-style gather
kernels), tokens are dispatched into a static (E, C, d) buffer via an
argsort over expert assignments — a dense, collective-friendly layout.
Experts are sharded over the ``model`` mesh axis (expert parallelism); XLA
inserts the all-to-all when activations move from token-sharded to
expert-sharded layout. Over-capacity tokens are dropped (standard
capacity-factor semantics); the router aux loss balances load.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    d, dff, e = cfg.d_model, cfg.resolved_moe_d_ff, cfg.num_experts
    keys = jax.random.split(key, 8)

    def stack_init(k, shape_in, shape_out, n):
        ks = jax.random.split(k, n)
        return jnp.stack([dense_init(ki, shape_in, shape_out, dtype) for ki in ks])

    p = {
        "router": dense_init(keys[0], d, e, jnp.float32, scale=0.02),
        "w_gate": stack_init(keys[1], d, dff, e),  # (E, d, dff)
        "w_up": stack_init(keys[2], d, dff, e),
        "w_down": stack_init(keys[3], dff, d, e),
    }
    if cfg.num_shared_experts:
        sd = dff * cfg.num_shared_experts
        p["shared"] = {
            "w_gate": dense_init(keys[4], d, sd, dtype),
            "w_up": dense_init(keys[5], d, sd, dtype),
            "w_down": dense_init(keys[6], sd, d, dtype, scale=sd ** -0.5),
        }
    return p


def _expert_ffn(p, x):
    """x: (E, C, d) -> (E, C, d) with per-expert SwiGLU weights."""
    gate = jnp.einsum("ecd,edf->ecf", x, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", x, p["w_up"])
    h = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _dispatch_group(cfg, p, xf, cap):
    """Sort-based dispatch + expert FFN + combine for ONE token group.

    xf: (Tg, d). All gathers/scatters here index only group-local tensors,
    so under vmap-over-groups (group dim sharded on ``data``) SPMD keeps
    every intermediate sharded — no involuntary replication.
    """
    e, k = cfg.num_experts, cfg.experts_per_token
    tg, d = xf.shape
    logits = (xf.astype(jnp.float32)) @ p["router"]  # (Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (Tg, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], e), axis=0)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_coef

    flat_e = expert_ids.reshape(-1)  # (Tg*k,)
    sort_idx = jnp.argsort(flat_e)
    sorted_e = flat_e[sort_idx]
    token_idx = sort_idx // k
    counts = jax.ops.segment_sum(jnp.ones_like(sorted_e), sorted_e,
                                 num_segments=e)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(tg * k) - offsets[sorted_e]
    keep = pos_in_e < cap
    slot = sorted_e * cap + jnp.where(keep, pos_in_e, 0)

    gathered = xf[token_idx] * keep[:, None].astype(xf.dtype)
    buf = jnp.zeros((e * cap, d), xf.dtype).at[slot].add(
        jnp.where(keep[:, None], gathered, 0))
    buf = buf.reshape(e, cap, d)

    out_buf = _expert_ffn(p, buf).reshape(e * cap, d)

    y_tok = out_buf[slot] * keep[:, None].astype(out_buf.dtype)
    w = gate_vals.reshape(-1)[sort_idx].astype(y_tok.dtype)
    y = jnp.zeros((tg, d), y_tok.dtype).at[token_idx].add(y_tok * w[:, None])
    return y, aux


def moe_ffn(cfg, p, x, capacity_factor: float = None):
    """x: (B, S, d). Returns (y, aux_loss).

    Dispatch runs independently in ``cfg.moe_groups`` token groups (grouped
    a2a layout: group dim rides the data axis, experts ride the model axis),
    falling back to one global group when tokens don't split evenly.
    """
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    # decode (s == 1): guarantee dropless dispatch — serving must not lose
    # tokens to capacity; the buffer is tiny at one token per sequence.
    if s == 1:
        capacity_factor = max(capacity_factor, float(e) / max(k, 1))
    g = cfg.moe_groups if (cfg.moe_groups > 1 and t % cfg.moe_groups == 0) else 1
    tg = t // g
    cap = int(max(1, (k * tg / e) * capacity_factor))
    xg = x.reshape(g, tg, d)
    y, aux = jax.vmap(lambda xf: _dispatch_group(cfg, p, xf, cap))(xg)
    y = y.reshape(t, d)
    aux = jnp.mean(aux)

    # --- shared experts (always active) ----------------------------------
    if cfg.num_shared_experts:
        xf = x.reshape(t, d)
        sp = p["shared"]
        h = jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])
        y = y + h @ sp["w_down"]

    return y.reshape(b, s, d), aux

"""Attention: GQA/MQA, qk-norm, optional bias, RoPE, causal / bidirectional /
sliding-window, chunked (flash-style, O(S) memory) training path, and
single-token decode against full or ring KV caches.

The chunked path is pure JAX (lax.scan + online softmax) so it lowers on any
backend — it is the XLA fallback of the Pallas flash kernel in
``repro.kernels.flash_attn`` (used on real TPUs; validated against the same
reference in tests).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_attention(key, cfg, cross: bool = False):
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d, cfg.num_heads * hd, dtype),
        "wk": dense_init(kk, d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(kv, d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ko, cfg.num_heads * hd, d, dtype, scale=(cfg.num_heads * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_q(cfg, p, x):
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(x.shape[:-1] + (cfg.num_heads, hd))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
    return q


def _project_kv(cfg, p, x):
    hd = cfg.resolved_head_dim
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        k = k + p["bk"]
        v = v + p["bv"]
    k = k.reshape(x.shape[:-1] + (cfg.num_kv_heads, hd))
    v = v.reshape(x.shape[:-1] + (cfg.num_kv_heads, hd))
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"])
    return k, v


def _repeat_kv(x, n_rep: int):
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


# ---------------------------------------------------------------------------
# core attention maths
# ---------------------------------------------------------------------------


def _full_attention(q, k, v, causal: bool, q_offset: int = 0,
                    window: int = 0):
    """Materialised-scores attention. q:(B,Sq,H,D) k,v:(B,Sk,H,D)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if causal:
        qpos = jnp.arange(q.shape[1]) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def _chunked_causal_attention(q, k, v, q_chunk: int):
    """Flash-style online-softmax over q chunks; kv masked per chunk.

    O(S * q_chunk) live memory. Scans q chunks; each chunk attends to the
    full (masked) key range — the upper-triangle overcount is accepted and
    accounted for in the roofline notes.
    """
    b, s, h, d = q.shape
    nq = s // q_chunk
    qs = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 2, 3, 4)  # (nq,B,c,H,D)
    kpos = jnp.arange(k.shape[1])

    def body(carry, inp):
        qc, i = inp
        qpos = i * q_chunk + jnp.arange(q_chunk)
        scale = d ** -0.5
        scores = jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32) * scale,
                            k.astype(jnp.float32))
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
        return carry, out

    _, outs = jax.lax.scan(jax.checkpoint(body), 0, (qs, jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)


def _sliding_window_attention(q, k, v, window: int, q_chunk: int):
    """Causal SWA with exact banded compute: each q chunk slices the
    (window + chunk)-length kv band it can see — no full-S scores."""
    b, s, h, d = q.shape
    band = window + q_chunk
    # left-pad kv by `window` so band slicing is always in range
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    nq = s // q_chunk
    qs = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        qc, i = inp
        start = i * q_chunk  # band start in padded coords
        kb = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
        scale = d ** -0.5
        scores = jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32) * scale,
                            kb.astype(jnp.float32))
        qpos = start + window + jnp.arange(q_chunk)  # padded absolute pos
        kpos = start + jnp.arange(band)
        mask = (qpos[:, None] >= kpos[None, :]) & \
               (kpos[None, :] > qpos[:, None] - window) & \
               (kpos[None, :] >= window)  # drop the padding region
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(vb.dtype), vb)
        return carry, out

    _, outs = jax.lax.scan(jax.checkpoint(body), 0, (qs, jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)


FULL_ATTN_MAX_SEQ = 4096  # above this, the chunked path is used
Q_CHUNK = 512


def attention_train(cfg, p, x, kv_x=None, causal: bool = True,
                    positions: Optional[jnp.ndarray] = None,
                    window: Optional[int] = None,
                    use_pallas: bool = False):
    """Self (or cross, via kv_x) attention over a full sequence."""
    b, s, _ = x.shape
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, kv_x if kv_x is not None else x)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if cfg.rope_theta and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    n_rep = cfg.num_heads // cfg.num_kv_heads
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    window = window if window is not None else cfg.attn_window
    if use_pallas and causal:
        from repro.kernels.flash_attn.ops import flash_attention

        out = flash_attention(q, k, v, causal=True, window=window or 0,
                              use_kernel=True, interpret=True)
    elif window and causal:
        qc = min(Q_CHUNK, s)
        out = _sliding_window_attention(q, k, v, window, qc) if s > qc \
            else _full_attention(q, k, v, causal=True, window=window)
    elif causal and (s > FULL_ATTN_MAX_SEQ or
                     (getattr(cfg, "force_chunked_attn", False) and s > Q_CHUNK)):
        out = _chunked_causal_attention(q, k, v, Q_CHUNK)
    else:
        out = _full_attention(q, k, v, causal=causal)
    return out.reshape(b, s, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# decode (single new token against a KV cache)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg, batch: int, cache_len: int, dtype):
    """Full cache, or ring cache of size ``attn_window`` when SWA."""
    hd = cfg.resolved_head_dim
    length = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
    shape = (batch, length, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(cfg, p, x, cache, pos):
    """x: (B, 1, d); pos: scalar int32 current position. Returns (y, cache).

    Cache semantics: full cache writes at index ``pos``; ring (SWA) cache
    writes at ``pos % window`` and masks by recency.
    """
    b = x.shape[0]
    q = _project_q(cfg, p, x)  # (B,1,H,Dh)
    k_new, v_new = _project_kv(cfg, p, x)  # (B,1,Hkv,Dh)
    if cfg.rope_theta:
        pp = jnp.full((b, 1), pos)
        q = apply_rope(q, pp, cfg.rope_theta)
        k_new = apply_rope(k_new, pp, cfg.rope_theta)
    length = cache["k"].shape[1]
    write_idx = (pos % length) if cfg.attn_window else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), write_idx, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), write_idx, axis=1)
    n_rep = cfg.num_heads // cfg.num_kv_heads
    kk = _repeat_kv(k, n_rep)
    vv = _repeat_kv(v, n_rep)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                        kk.astype(jnp.float32))  # (B,H,1,L)
    slot = jnp.arange(length)
    if cfg.attn_window:
        valid = slot <= pos if length > 0 else slot < 0  # ring: all slots <= pos written
        # slots hold positions pos-window+1..pos (mod window) once warm
        valid = jnp.minimum(pos + 1, length) > ((write_idx - slot) % length)
    else:
        valid = slot <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(vv.dtype), vv)
    y = out.reshape(b, 1, -1) @ p["wo"]
    return y, {"k": k, "v": v}


def cross_attention_decode(cfg, p, x, enc_kv):
    """Decoder cross-attention against precomputed encoder K/V."""
    b = x.shape[0]
    q = _project_q(cfg, p, x)
    k, v = enc_kv["k"], enc_kv["v"]
    n_rep = cfg.num_heads // cfg.num_kv_heads
    kk = _repeat_kv(k, n_rep)
    vv = _repeat_kv(v, n_rep)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                        kk.astype(jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(vv.dtype), vv)
    return out.reshape(b, x.shape[1], -1) @ p["wo"]


def precompute_cross_kv(cfg, p, enc_out):
    k, v = _project_kv(cfg, p, enc_out)
    return {"k": k, "v": v}

"""Top-level model: embedding -> scanned decoder stack -> LM head.

One class covers all assigned families (dense / moe / hybrid / ssm / vlm /
audio). Params are plain dict pytrees; every method is a pure function of
(params, inputs) so the FL core and pjit treat models uniformly.

Batch dicts:
  LM     : {"tokens": (B,S) int32, "labels": (B,S) int32}
  VLM    : + {"patches": (B,P,d_model)}   (stub frontend output; loss on text)
  audio  : {"frames": (B,S_enc,d_model)}  (stub conv/mel output) + tokens/labels
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.layers import (
    apply_norm,
    dense_init,
    embed_init,
    make_norm_params,
    sinusoidal_positions,
)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(key, 8)
        p: Dict[str, Any] = {
            "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
            "final_norm": make_norm_params(cfg, cfg.d_model),
            "layers": blocks.init_stack(ks[1], cfg, cfg.num_layers,
                                        cross=cfg.is_encdec),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_size, dtype)
        if cfg.num_patches:
            p["projector"] = dense_init(ks[3], cfg.d_model, cfg.d_model, dtype)
        if cfg.is_encdec:
            p["encoder"] = {
                "layers": blocks.init_stack(ks[4], cfg, cfg.encoder_layers),
                "final_norm": make_norm_params(cfg, cfg.d_model),
            }
        return p

    # ------------------------------------------------------------- embedding
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.family == "dense" and cfg.tie_embeddings:
            # gemma-style input scaling
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if not cfg.rope_theta:
            pos = jnp.arange(tokens.shape[1])
            x = x + sinusoidal_positions(pos, cfg.d_model)[None].astype(x.dtype)
        return x.astype(jnp.dtype(cfg.compute_dtype))

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(cfg, params["final_norm"], x)
        if cfg.tie_embeddings:
            return x @ params["embed"].T
        return x @ params["lm_head"]

    def _encode(self, params, frames):
        cfg = self.cfg
        pos = jnp.arange(frames.shape[1])
        x = frames.astype(jnp.dtype(cfg.compute_dtype))
        x = x + sinusoidal_positions(pos, cfg.d_model)[None].astype(x.dtype)
        x = blocks.run_encoder_stack(cfg, params["encoder"]["layers"], x)
        return apply_norm(cfg, params["encoder"]["final_norm"], x)

    # --------------------------------------------------------- full sequence
    def hidden(self, params, batch: Dict[str, jnp.ndarray],
               window: Optional[int] = None):
        """Full-sequence forward up to (and incl.) trimming non-text
        positions; returns (hidden (B,S,d), aux_loss) — no LM head."""
        cfg = self.cfg
        x = self._embed(params, batch["tokens"])
        enc_out = None
        if cfg.num_patches:
            patches = batch["patches"].astype(x.dtype) @ params["projector"]
            x = jnp.concatenate([patches, x], axis=1)
        if cfg.is_encdec:
            enc_out = self._encode(params, batch["frames"])
        x, aux = blocks.run_stack_train(cfg, params["layers"], x,
                                        enc_out=enc_out, window=window)
        if cfg.num_patches:
            x = x[:, batch["patches"].shape[1]:]  # loss on text positions only
        return x, aux

    def apply(self, params, batch: Dict[str, jnp.ndarray],
              window: Optional[int] = None):
        """Full-sequence forward. Returns (logits, aux_loss)."""
        x, aux = self.hidden(params, batch, window=window)
        return self._logits(params, x), aux

    def prefill_logits(self, params, batch: Dict[str, jnp.ndarray],
                       window: Optional[int] = None):
        """Serving prefill: last-token logits only — the (B, S, V) logits
        tensor is never materialised (the LM head sees one position)."""
        x, _ = self.hidden(params, batch, window=window)
        return self._logits(params, x[:, -1:])

    def loss(self, params, batch, window: Optional[int] = None):
        """Mean next-token cross-entropy (+ MoE aux). Returns (loss, metrics).

        With ``cfg.ce_chunk > 0`` the LM-head matmul and the CE reduction are
        fused per token-chunk (lax.scan + remat), so the (T, V) logits tensor
        never exists in HBM — see EXPERIMENTS.md §Perf iteration 2.
        """
        labels = batch["labels"]
        if not self.cfg.ce_chunk:
            logits, aux = self.apply(params, batch, window=window)
            lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
            gold = jnp.take_along_axis(logits.astype(jnp.float32),
                                       labels[..., None], axis=-1)[..., 0]
            ce = jnp.mean(lse - gold)
            return ce + aux, {"ce": ce, "aux": aux}

        x, aux = self.hidden(params, batch, window=window)
        cfg = self.cfg
        x = apply_norm(cfg, params["final_norm"], x)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        b, s, d = x.shape
        t = b * s
        chunk = min(cfg.ce_chunk, t)
        nc = -(-t // chunk)
        pad = nc * chunk - t
        xf = x.reshape(t, d)
        lf = labels.reshape(t)
        valid = jnp.ones((t,), jnp.float32)
        if pad:
            xf = jnp.pad(xf, ((0, pad), (0, 0)))
            lf = jnp.pad(lf, (0, pad))
            valid = jnp.pad(valid, (0, pad))
        xc = xf.reshape(nc, chunk, d)
        lc = lf.reshape(nc, chunk)
        vc = valid.reshape(nc, chunk)

        def body(acc, inp):
            xi, li, vi = inp
            lg = (xi @ head).astype(jnp.float32)  # (chunk, V) — chunk-local
            lse = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, li[:, None], axis=-1)[:, 0]
            return acc + jnp.sum((lse - gold) * vi), None

        total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                                (xc, lc, vc))
        ce = total / t
        return ce + aux, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------ serve
    def init_cache(self, batch: int, cache_len: int):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.compute_dtype)
        return blocks.init_stack_cache(cfg, cfg.num_layers, batch, cache_len,
                                       dtype, cross=cfg.is_encdec)

    def prefill_cross(self, params, cache, frames):
        """Enc-dec only: run the encoder, fill per-layer cross K/V caches."""
        from repro.models.attention import precompute_cross_kv

        enc_out = self._encode(params, frames)

        def per_layer(layer_p):
            return precompute_cross_kv(self.cfg, layer_p["xattn"], enc_out)

        cross = jax.vmap(per_layer)(params["layers"])
        new = dict(cache)
        new["cross"] = cross
        return new

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B,1) int32; pos: scalar int32. Returns (logits, cache)."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.family == "dense" and cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if not cfg.rope_theta:
            x = x + sinusoidal_positions(jnp.full((1,), pos), cfg.d_model)[None].astype(x.dtype)
        x = x.astype(jnp.dtype(cfg.compute_dtype))
        x, cache = blocks.run_stack_decode(cfg, params["layers"], x, cache, pos)
        return self._logits(params, x), cache

    # ------------------------------------------------------------- utilities
    def param_count(self, params=None) -> int:
        if params is None:
            params = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return sum(int(x.size) for x in jax.tree.leaves(params))

    def active_param_count(self, params=None) -> int:
        """Params touched per token (MoE: shared + top-k routed only)."""
        cfg = self.cfg
        if params is None:
            params = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        total = self.param_count(params)
        if not cfg.is_moe:
            return total
        expert_leaves = ["w_gate", "w_up", "w_down"]
        moe = params["layers"]["moe"]
        routed = sum(int(moe[k].size) for k in expert_leaves)
        active = routed * cfg.experts_per_token // cfg.num_experts
        return total - routed + active


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)

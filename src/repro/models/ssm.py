"""Mamba-1 selective SSM block (pure JAX).

Training path uses a *chunked* scan: an outer ``lax.scan`` over sequence
chunks carrying the (B, d_inner, N) state, with an associative scan inside
each (rematerialised) chunk — O(S/chunk) saved carries instead of
O(S * d_inner * N) activations. This mirrors the VMEM-resident chunking the
Pallas kernel (repro.kernels.ssm_scan) performs on TPU.

Decode path is the O(1) recurrent update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

SSM_CHUNK = 256


def init_ssm(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    d, di, n = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    dtr = cfg.resolved_dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialisation for A
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32) *
                   (cfg.ssm_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * n, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, jnp.float32, scale=dtr ** -0.5),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype, scale=di ** -0.5),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, di), w: (K, di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b


def _ssm_inputs(cfg, p, x):
    """Shared pre-scan projections. x: (B, S, di) post-conv post-silu.

    Returns dt (B,S,di) f32, B_ (B,S,N) f32, C_ (B,S,N) f32.
    """
    n = cfg.ssm_state
    dtr = cfg.resolved_dt_rank
    dbc = (x @ p["x_proj"]).astype(jnp.float32)
    dt, b_, c_ = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])  # (B,S,di)
    return dt, b_, c_


def ssm_train(cfg, p, u):
    """u: (B, S, d_model) -> (B, S, d_model)."""
    b, s, _ = u.shape
    di, n = cfg.ssm_d_inner, cfg.ssm_state
    xz = u @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)  # (B,S,di) each
    x = jax.nn.silu(_causal_conv(x, p["conv_w"], p["conv_b"]))
    dt, b_, c_ = _ssm_inputs(cfg, p, x)
    a = -jnp.exp(p["A_log"])  # (di, N)

    chunk = min(SSM_CHUNK, s)
    nc = s // chunk
    assert s % chunk == 0, f"seq {s} not divisible by ssm chunk {chunk}"

    def reshape_c(t):  # (B,S,...) -> (nc, B, chunk, ...)
        return t.reshape(b, nc, chunk, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    xs = (reshape_c(x.astype(jnp.float32)), reshape_c(dt), reshape_c(b_), reshape_c(c_))

    def chunk_fn(h0, inp):
        xc, dtc, bc, cc = inp  # (B,chunk,di) / (B,chunk,di) / (B,chunk,N) x2
        # discretise: a_bar (B,c,di,N), b_bar*x (B,c,di,N)
        da = jnp.exp(dtc[..., None] * a[None, None])  # (B,c,di,N)
        dbx = (dtc * xc)[..., None] * bc[:, :, None, :]  # (B,c,di,N)

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_cum, b_cum = jax.lax.associative_scan(comb, (da, dbx), axis=1)
        h = a_cum * h0[:, None] + b_cum  # (B,c,di,N)
        y = jnp.einsum("bcdn,bcn->bcd", h, cc)
        return h[:, -1], y

    h0 = jnp.zeros((b, di, n), jnp.float32)
    _, ys = jax.lax.scan(jax.checkpoint(chunk_fn), h0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    y = y + x.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg, batch: int, dtype):
    di, n, k = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": jnp.zeros((batch, k - 1, di), dtype),
        "h": jnp.zeros((batch, di, n), jnp.float32),
    }


def ssm_decode(cfg, p, u, cache):
    """u: (B, 1, d_model). Returns (y, cache)."""
    b = u.shape[0]
    xz = u @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)  # (B,1,di)
    # conv over [cached K-1 inputs, current]
    conv_in = jnp.concatenate([cache["conv"], x.astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"]
    xconv = jnp.einsum("bkd,kd->bd", conv_in, w) + p["conv_b"]
    x1 = jax.nn.silu(xconv)[:, None, :]  # (B,1,di)
    dt, b_, c_ = _ssm_inputs(cfg, p, x1)
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt[:, 0, :, None] * a[None])  # (B,di,N)
    dbx = (dt[:, 0] * x1[:, 0].astype(jnp.float32))[..., None] * b_[:, 0, None, :]
    h = da * cache["h"] + dbx
    y = jnp.einsum("bdn,bn->bd", h, c_[:, 0])
    y = y + x1[:, 0].astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(u.dtype)
    out = (y @ p["out_proj"])[:, None, :]
    new_cache = {"conv": conv_in[:, 1:], "h": h}
    return out, new_cache

"""Decoder/encoder blocks and the scanned layer stack.

Each architecture family maps to one homogeneous block type so the whole
stack is a single ``lax.scan`` over layer-stacked parameters — compact HLO
at any depth (80-layer qwen1.5-110b lowers as one loop), remat-friendly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_mlp, apply_norm, init_mlp, make_norm_params


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------


def init_layer(key, cfg, cross: bool = False):
    """One decoder layer's params for the cfg's family."""
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.is_ssm_only:
        p["norm1"] = make_norm_params(cfg, cfg.d_model)
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
        return p
    p["norm1"] = make_norm_params(cfg, cfg.d_model)
    p["attn"] = attn.init_attention(ks[0], cfg)
    if cfg.is_hybrid:
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg)
        p["fuse_norm_a"] = make_norm_params(cfg, cfg.d_model)
        p["fuse_norm_s"] = make_norm_params(cfg, cfg.d_model)
    if cross:
        p["norm_x"] = make_norm_params(cfg, cfg.d_model)
        p["xattn"] = attn.init_attention(ks[2], cfg, cross=True)
    p["norm2"] = make_norm_params(cfg, cfg.d_model)
    if cfg.is_moe:
        p["moe"] = moe_mod.init_moe(ks[3], cfg)
        if cfg.dense_residual_ff:
            p["mlp"] = init_mlp(ks[4], cfg)
    else:
        p["mlp"] = init_mlp(ks[4], cfg)
    return p


def init_stack(key, cfg, num_layers: int, cross: bool = False):
    keys = jax.random.split(key, num_layers)
    return jax.vmap(lambda k: init_layer(k, cfg, cross=cross))(keys)


# ---------------------------------------------------------------------------
# full-sequence (train / prefill) block application
# ---------------------------------------------------------------------------


def _mixer_train(cfg, p, x, window):
    """Token mixer (attn / ssm / hybrid) with pre-norm + residual."""
    h = apply_norm(cfg, p["norm1"], x)
    if cfg.is_ssm_only:
        return x + ssm_mod.ssm_train(cfg, p["ssm"], h)
    if cfg.is_hybrid:
        a = attn.attention_train(cfg, p["attn"], h, window=window)
        s = ssm_mod.ssm_train(cfg, p["ssm"], h)
        fused = 0.5 * (apply_norm(cfg, p["fuse_norm_a"], a) +
                       apply_norm(cfg, p["fuse_norm_s"], s))
        return x + fused
    return x + attn.attention_train(cfg, p["attn"], h, window=window)


def _ffn_train(cfg, p, x):
    if cfg.is_ssm_only:
        return x, jnp.zeros((), jnp.float32)  # mamba block subsumes the MLP
    h = apply_norm(cfg, p["norm2"], x)
    if cfg.is_moe:
        y, aux = moe_mod.moe_ffn(cfg, p["moe"], h)
        if cfg.dense_residual_ff:
            y = y + apply_mlp(cfg, p["mlp"], h)
        return x + y, aux
    return x + apply_mlp(cfg, p["mlp"], x=h), jnp.zeros((), jnp.float32)


def decoder_layer_train(cfg, p, x, enc_out=None, causal: bool = True,
                        window: Optional[int] = None):
    """Returns (x, aux_loss). enc_out enables cross-attention (enc-dec)."""
    x = _mixer_train(cfg, p, x, window)
    if enc_out is not None and "xattn" in p:
        h = apply_norm(cfg, p["norm_x"], x)
        x = x + attn.attention_train(cfg, p["xattn"], h, kv_x=enc_out, causal=False)
    return _ffn_train(cfg, p, x)


def encoder_layer_train(cfg, p, x):
    h = apply_norm(cfg, p["norm1"], x)
    x = x + attn.attention_train(cfg, p["attn"], h, causal=False)
    x, _ = _ffn_train(cfg, p, x)
    return x


def run_stack_train(cfg, stacked, x, enc_out=None, causal: bool = True,
                    window: Optional[int] = None, remat: bool = True):
    """Scan the layer stack. Returns (x, total_aux).

    ``cfg.remat_block = G`` enables sqrt-remat: an outer (checkpointed) scan
    over L/G layer groups and an inner scan over the G layers of a group —
    only L/G boundary activations are saved for the backward pass; the G
    within-group carries are rematerialised transiently (EXPERIMENTS.md
    §Perf). G=0 checkpoints every layer (the baseline).
    """

    def body(carry, layer_p):
        h, aux = carry
        h, a = decoder_layer_train(cfg, layer_p, h, enc_out=enc_out,
                                   causal=causal, window=window)
        return (h, aux + a), None

    init = (x, jnp.zeros((), jnp.float32))
    g = getattr(cfg, "remat_block", 0)
    nl = jax.tree.leaves(stacked)[0].shape[0]
    if remat and g and g > 1 and nl % g == 0:
        grouped = jax.tree.map(
            lambda a: a.reshape(nl // g, g, *a.shape[1:]), stacked)

        def group_body(carry, gp):
            # inner body checkpointed too: during the group's backward only
            # the G carry boundaries go live, never full layer residuals
            out, _ = jax.lax.scan(jax.checkpoint(body), carry, gp)
            return out, None

        (x, aux), _ = jax.lax.scan(jax.checkpoint(group_body), init, grouped)
        return x, aux
    fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(fn, init, stacked)
    return x, aux


def run_encoder_stack(cfg, stacked, x, remat: bool = True):
    def body(h, layer_p):
        return encoder_layer_train(cfg, layer_p, h), None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, stacked)
    return x


# ---------------------------------------------------------------------------
# decode (single token) block application
# ---------------------------------------------------------------------------


def init_layer_cache(cfg, batch: int, cache_len: int, dtype, cross: bool = False):
    c = {}
    if not cfg.is_ssm_only:
        c["kv"] = attn.init_kv_cache(cfg, batch, cache_len, dtype)
    if cfg.is_ssm_only or cfg.is_hybrid:
        c["ssm"] = ssm_mod.init_ssm_cache(cfg, batch, dtype)
    if cross:
        hd = cfg.resolved_head_dim
        c["cross"] = {
            "k": jnp.zeros((batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd), dtype),
        }
    return c


def init_stack_cache(cfg, num_layers: int, batch: int, cache_len: int, dtype,
                     cross: bool = False):
    """Layer-stacked cache pytree (leading axis L) for lax.scan decode."""
    one = init_layer_cache(cfg, batch, cache_len, dtype, cross=cross)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (num_layers,) + a.shape), one)


def decoder_layer_decode(cfg, p, x, cache, pos):
    """x: (B,1,d). Returns (x, cache)."""
    h = apply_norm(cfg, p["norm1"], x)
    new_cache = dict(cache)
    if cfg.is_ssm_only:
        y, new_cache["ssm"] = ssm_mod.ssm_decode(cfg, p["ssm"], h, cache["ssm"])
        return x + y, new_cache
    if cfg.is_hybrid:
        a, new_cache["kv"] = attn.attention_decode(cfg, p["attn"], h, cache["kv"], pos)
        s, new_cache["ssm"] = ssm_mod.ssm_decode(cfg, p["ssm"], h, cache["ssm"])
        x = x + 0.5 * (apply_norm(cfg, p["fuse_norm_a"], a) +
                       apply_norm(cfg, p["fuse_norm_s"], s))
    else:
        a, new_cache["kv"] = attn.attention_decode(cfg, p["attn"], h, cache["kv"], pos)
        x = x + a
    if "xattn" in p and "cross" in cache:
        h = apply_norm(cfg, p["norm_x"], x)
        x = x + attn.cross_attention_decode(cfg, p["xattn"], h, cache["cross"])
    h = apply_norm(cfg, p["norm2"], x)
    if cfg.is_moe:
        y, _ = moe_mod.moe_ffn(cfg, p["moe"], h)
        if cfg.dense_residual_ff:
            y = y + apply_mlp(cfg, p["mlp"], h)
        x = x + y
    else:
        x = x + apply_mlp(cfg, p["mlp"], h)
    return x, new_cache


def run_stack_decode(cfg, stacked, x, caches, pos):
    """Scan layers carrying x, threading per-layer caches. Returns (x, caches)."""

    def body(h, inp):
        layer_p, layer_c = inp
        h, new_c = decoder_layer_decode(cfg, layer_p, h, layer_c, pos)
        return h, new_c

    x, new_caches = jax.lax.scan(body, x, (stacked, caches))
    return x, new_caches

"""NumPy PCG64 generator state <-> plain uint64 arrays.

The engine checkpoint (sim/engine.py::EngineState) must freeze every
host-side RNG stream — per-client behavior draws AND per-client dataset
batch sampling — into npz-storable arrays. One PCG64 generator packs to
a (6,) uint64 row: [state_hi, state_lo, inc_hi, inc_lo, has_uint32,
uinteger]; a list of generators packs to (n, 6).

Scope note (DESIGN.md §10): this pack exists for MUTABLE generator
streams only. The device-resident population engine
(``sim/population.py``) replaced them with counter-based threefry draws,
whose whole stream state is the plain integer draw counters — its
checkpoints (``PopulationEngineState``, ``CounterBehavior.get_state``,
``CounterDataset.rng_state``) never touch this module. It remains the
checkpoint format for the host-walk engine's PCG64 path
(``ClientBehavior``/``ClientDataset``).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

_U64 = (1 << 64) - 1


def pack_pcg64(rngs: Sequence[np.random.Generator]) -> np.ndarray:
    """(n, 6) uint64 rows capturing each generator's exact state."""
    rows = []
    for g in rngs:
        st = g.bit_generator.state
        if st["bit_generator"] != "PCG64":
            raise ValueError(f"unsupported generator {st['bit_generator']!r}")
        s, inc = st["state"]["state"], st["state"]["inc"]
        rows.append([s >> 64, s & _U64, inc >> 64, inc & _U64,
                     st["has_uint32"], st["uinteger"]])
    return np.asarray(rows, np.uint64).reshape(len(rows), 6)


def unpack_pcg64(rows: np.ndarray) -> List[np.random.Generator]:
    """Inverse of ``pack_pcg64``: fresh generators at the packed states."""
    out = []
    for r in np.asarray(rows, np.uint64).reshape(-1, 6):
        g = np.random.default_rng(0)
        g.bit_generator.state = {
            "bit_generator": "PCG64",
            "state": {"state": (int(r[0]) << 64) | int(r[1]),
                      "inc": (int(r[2]) << 64) | int(r[3])},
            "has_uint32": int(r[4]), "uinteger": int(r[5]),
        }
        out.append(g)
    return out

"""Pytree arithmetic helpers used across the framework.

All functions are pure and jit-safe; they operate leaf-wise on arbitrary
pytrees of arrays (model parameters, optimizer states, client deltas).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    """Scale every leaf of ``a`` by scalar ``s`` (python or 0-d array)."""
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leaf-wise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a, b):
    """Sum of elementwise products across all leaves (float32 accum)."""
    leaves_a = jax.tree.leaves(a)
    leaves_b = jax.tree.leaves(b)
    parts = [
        jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
        for x, y in zip(leaves_a, leaves_b)
    ]
    return jnp.sum(jnp.stack(parts))


def tree_sq_norm(a):
    """Squared L2 norm across all leaves (float32 accum)."""
    parts = [
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(a)
    ]
    return jnp.sum(jnp.stack(parts))


def tree_sq_dist(a, b):
    """Squared L2 distance ||a - b||^2 across all leaves."""
    parts = [
        jnp.sum(jnp.square(x.astype(jnp.float32) - y.astype(jnp.float32)))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    ]
    return jnp.sum(jnp.stack(parts))


def tree_weighted_sum(trees_stacked, weights):
    """Weighted sum over the leading (client) axis of a stacked pytree.

    ``trees_stacked`` has leaves of shape (K, ...); ``weights`` is (K,).
    Returns a pytree with the leading axis contracted:  sum_k w_k * leaf[k].
    """

    def _ws(leaf):
        w = weights.astype(jnp.float32).reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(leaf.astype(jnp.float32) * w, axis=0).astype(leaf.dtype)

    return jax.tree.map(_ws, trees_stacked)


def tree_stack(trees):
    """Stack a python list of pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n):
    """Inverse of tree_stack for a known leading size ``n``."""
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]


def tree_count_params(a):
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_bytes(a):
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, a
    )


def tree_isfinite(a):
    """True iff every element of every floating leaf is finite."""
    parts = [
        jnp.all(jnp.isfinite(x))
        for x in jax.tree.leaves(a)
        if jnp.issubdtype(x.dtype, jnp.floating)
    ]
    if not parts:
        return jnp.asarray(True)
    return jnp.all(jnp.stack(parts))


def tree_flatten_to_vector(a):
    """Concatenate all leaves into one flat f32 vector (for analysis/tests)."""
    leaves = jax.tree.leaves(a)
    return jnp.concatenate([x.astype(jnp.float32).reshape(-1) for x in leaves])

from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    sgd,
)
from repro.optim.schedules import (  # noqa: F401
    constant_schedule,
    cosine_schedule,
    warmup_cosine_schedule,
)

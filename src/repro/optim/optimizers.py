"""Minimal pure-JAX optimizers (no optax in this environment).

Interface mirrors optax's GradientTransformation:

    opt = sgd(lr=0.1, momentum=0.9)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

``update`` returns the *delta to add to params* (i.e. already negated and
scaled by the learning rate), which keeps client/server code simple.
Schedules: ``lr`` may be a float or a callable step -> lr; state carries the
step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

LrType = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]  # (grads, state, params) -> (updates, state)


def _resolve_lr(lr: LrType, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


class SgdState(NamedTuple):
    step: jnp.ndarray
    momentum: Any  # pytree like params, or None-pytree of zeros


def sgd(lr: LrType, momentum: float = 0.0, weight_decay: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    use_mom = momentum != 0.0

    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if use_mom else None
        return SgdState(step=jnp.zeros((), jnp.int32), momentum=mom)

    def update(grads, state, params=None):
        step_lr = _resolve_lr(lr, state.step)
        if weight_decay and params is not None:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        if use_mom:
            new_mom = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
            if nesterov:
                eff = jax.tree.map(lambda m, g: momentum * m + g, new_mom, grads)
            else:
                eff = new_mom
        else:
            new_mom, eff = None, grads
        updates = jax.tree.map(lambda g: -step_lr * g, eff)
        return updates, SgdState(step=state.step + 1, momentum=new_mom)

    return Optimizer(init=init, update=update)


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw(lr: LrType, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        step_lr = _resolve_lr(lr, state.step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def _upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay and p is not None:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-step_lr * u).astype(p.dtype if p is not None else m.dtype)

        if params is None:
            params = jax.tree.map(lambda m: m, mu)
        updates = jax.tree.map(_upd, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)

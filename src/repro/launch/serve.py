"""Serving launcher: batched autoregressive decode of the trained global
model (what a deployed FL system does with the aggregated weights).

Smoke mode runs a reduced config on CPU: prefill via decode loop over the
prompt, then N generation steps, reporting tokens/s.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import smoke_variant
from repro.configs.registry import get_arch
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = smoke_variant(arch.model) if args.smoke else arch.model
    model = build_model(cfg)
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    # split BEFORE init: the sampling stream must never reuse the key the
    # parameter init consumed
    key, init_key = jax.random.split(jax.random.PRNGKey(0))

    params = model.init(init_key)
    cache_len = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, cache_len)
    if cfg.is_encdec:
        frames = jnp.asarray(rng.normal(
            size=(args.batch, cfg.encoder_seq_len, cfg.d_model)), jnp.float32)
        cache = model.prefill_cross(params, cache, frames)

    step = jax.jit(model.decode_step, donate_argnums=1)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (args.batch, args.prompt_len)), jnp.int32)

    with mesh:
        # prefill by stepping the prompt through the cache
        t0 = time.time()
        logits = None
        for i in range(args.prompt_len):
            logits, cache = step(params, cache, prompt[:, i:i + 1], jnp.int32(i))
        t_prefill = time.time() - t0

        # autoregressive generation
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for g in range(args.gen):
            pos = jnp.int32(args.prompt_len + g)
            logits, cache = step(params, cache, tok, pos)
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, -1] / args.temperature)[:, None].astype(jnp.int32)
            else:
                tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        t_gen = time.time() - t0

    toks = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"prefill: {args.prompt_len} steps in {t_prefill:.2f}s; "
          f"decode: {args.batch * args.gen / t_gen:.1f} tok/s")
    print("generated token ids (first row):", toks[0].tolist())


if __name__ == "__main__":
    main()

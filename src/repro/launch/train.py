"""Training launcher: contribution-aware async FL rounds on a device mesh.

Runs REAL steps (allocates params), so it is meant for:
  * CPU/host smoke runs with reduced configs (--smoke), and
  * actual TPU slices with the full configs.

The arrival schedule (which cohort slots' uploads are buffered each round)
comes from the same heterogeneous latency model as the event-driven
simulator, so compiled training reproduces realistic staleness patterns.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --shape train_4k --smoke --rounds 10
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import INPUT_SHAPES, FLConfig
from repro.configs.registry import get_arch
from repro.configs.base import smoke_variant
from repro.core.cohort import init_cohort_state, make_cohort_step
from repro.core.simulator import LatencyModel
from repro.data.synthetic import make_lm_token_stream
from repro.launch.cli import (
    ObsStack,
    add_obs_flags,
    add_ring_codec_flag,
    add_seed_flag,
)
from repro.launch.mesh import batch_axes_for, make_host_mesh
from repro.models.model import build_model


def arrival_schedule(num_slots: int, k: int, latency: LatencyModel,
                     rounds: int, seed: int = 0) -> np.ndarray:
    """(rounds, num_slots) 0/1 masks: the K slots with the earliest
    completion times arrive each round (straggler slots roll over)."""
    rng = np.random.default_rng(seed)
    remaining = np.array([latency.sample(rng, i) for i in range(num_slots)])
    out = np.zeros((rounds, num_slots), np.float32)
    for r in range(rounds):
        order = np.argsort(remaining)
        arrive = order[:k]
        out[r, arrive] = 1.0
        t = remaining[arrive].max()
        remaining = remaining - t
        for i in arrive:
            remaining[i] = latency.sample(rng, i)
    return out


def make_batches(cfg, cohort, m, b, bp, seq, rng):
    """Synthetic non-IID LM batches for one round (host-side pipeline)."""
    def toks(lead):
        n = int(np.prod(lead))
        t = make_lm_token_stream(cfg.vocab_size, seq, n, seed=int(rng.integers(1 << 30)))
        return t.reshape(*lead, seq + 1)

    text = seq - (cfg.num_patches or 0)
    local = toks((cohort, m, b))
    probe = toks((cohort, bp))
    batch = {
        "local": {"tokens": local[..., :text], "labels": local[..., 1:text + 1]},
        "probe": {"tokens": probe[..., :text], "labels": probe[..., 1:text + 1]},
    }
    if cfg.num_patches:
        batch["local"]["patches"] = rng.normal(
            size=(cohort, m, b, cfg.num_patches, cfg.d_model)).astype(np.float32)
        batch["probe"]["patches"] = rng.normal(
            size=(cohort, bp, cfg.num_patches, cfg.d_model)).astype(np.float32)
    if cfg.is_encdec:
        batch["local"]["frames"] = rng.normal(
            size=(cohort, m, b, cfg.encoder_seq_len, cfg.d_model)).astype(np.float32)
        batch["probe"]["frames"] = rng.normal(
            size=(cohort, bp, cfg.encoder_seq_len, cfg.d_model)).astype(np.float32)
    return batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shapes (CPU-runnable)")
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--buffer-k", type=int, default=3)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--weighting", default="paper")
    add_seed_flag(ap)
    add_ring_codec_flag(
        ap, help_suffix=" — int8/delta shrink the R-deep version ring "
                        "for large models")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint path (coordinator-gated: only "
                         "process 0 writes)")
    ap.add_argument("--ckpt-every", type=int, default=5)
    # multi-host (DESIGN.md §7): same flags on every process
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0 (enables jax.distributed)")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    add_obs_flags(ap)
    args = ap.parse_args()

    obs = ObsStack.from_args(args)

    if args.coordinator and args.num_processes > 1:
        from repro.launch.multihost import initialize
        initialize(args.coordinator, args.num_processes, args.process_id)

    arch = get_arch(args.arch)
    shape = INPUT_SHAPES[args.shape]
    cfg = smoke_variant(arch.model) if args.smoke else arch.model
    cohort = args.cohort if args.smoke else 16
    seq = args.seq if args.smoke else shape.seq_len
    b = args.batch if args.smoke else shape.global_batch // cohort
    fl = FLConfig(buffer_size=args.buffer_k, local_steps=2, local_lr=5e-3,
                  weighting=args.weighting, ring_codec=args.ring_codec)
    model = build_model(cfg)
    mesh = make_host_mesh()
    latency = LatencyModel.heterogeneous(cohort, seed=args.seed)
    sched = arrival_schedule(cohort, args.buffer_k, latency, args.rounds,
                             seed=args.seed)

    rng = np.random.default_rng(args.seed)
    params = model.init(jax.random.PRNGKey(args.seed))
    state = init_cohort_state(params, cohort)
    step = jax.jit(make_cohort_step(model.loss, fl), donate_argnums=0)
    sizes = jnp.asarray(rng.integers(500, 2000, cohort), jnp.float32)

    from repro.launch.program import make_io_hooks
    log, eval_metrics, maybe_save = make_io_hooks(
        ckpt_path=args.ckpt, ckpt_every=args.ckpt_every,
        log_fn=logging.getLogger("repro.launch.train").info,
        registry=obs.registry, tracer=obs.tracer, sink=obs.sink)

    with mesh:
        for r in range(args.rounds):
            batch = make_batches(cfg, cohort, fl.local_steps, b, 2, seq, rng)
            batch = jax.tree.map(jnp.asarray, batch)
            batch["arrival"] = jnp.asarray(sched[r])
            batch["data_sizes"] = sizes
            t0 = time.time()
            state, mets = step(state, batch)
            mets = eval_metrics(mets)
            log(f"round {r + 1}: fresh_loss={mets['fresh_loss_mean']:.4f} "
                f"|u|^2={mets['update_sq_norm']:.3e} "
                f"arrivals={int(sched[r].sum())} ({time.time() - t0:.1f}s)")
            maybe_save(r + 1, {"params": state.global_params,
                               "version": state.version})
            obs.round_hook(r + 1)
    log(f"done; global version = {int(state.version)}")
    obs.finish(args.rounds)


if __name__ == "__main__":
    main()

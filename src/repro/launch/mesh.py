"""Production mesh builders.

Target hardware: TPU v5e pods — 256 chips/pod, (data=16, model=16) within a
pod; the multi-pod mesh adds a leading DCN-mapped "pod" axis (2 pods = 512
chips). Defined as FUNCTIONS so importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def make_round_mesh(data: int = 1, model: int = 0):
    """(data, model) mesh for the sharded round substrate (DESIGN.md §5).

    ``data`` carries the K-client cohort slots, ``model`` the padded flat
    parameter vector AND the engine's (R, Np) flat version ring
    (``sharding/specs.ring_pspec``: R * Np / model per-device floats,
    DESIGN.md §6). ``model=0`` spreads all remaining devices on the
    model axis. Unlike ``make_host_mesh`` this does not require using
    every device — scale-out sweeps (benchmarks/bench_shard_scale.py) pin
    subsets of the forced-host-device pool.

    In a multi-process session (``jax.distributed`` initialized via
    ``launch/multihost.initialize``) this delegates to
    ``multihost.make_round_mesh``, which lays the data axis across
    processes so the model-axis collectives stay intra-host (DESIGN.md
    §7 — the bit-parity layout).
    """
    import numpy as np
    from jax.sharding import Mesh

    if jax.process_count() > 1:
        from repro.launch.multihost import make_round_mesh as _mh_mesh
        # the single-host default data=1 means "no data parallelism";
        # multi-host needs data % process_count == 0, so map it to
        # multihost's own default (one data row per process)
        return _mh_mesh(data=0 if data <= 1 else data, model=model)
    devices = jax.devices()
    if model == 0:
        model = max(1, len(devices) // data)
    need = data * model
    if need > len(devices):
        raise ValueError(f"mesh ({data}, {model}) needs {need} devices, "
                         f"have {len(devices)}")
    devs = np.asarray(devices[:need]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def batch_axes_for(mesh) -> tuple:
    """The data-parallel axes of a mesh (cohort/batch sharding)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))

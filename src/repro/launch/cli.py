"""Shared launcher flag surface (DESIGN.md §12).

The flag soup that used to be copy-pasted between ``launch/train.py``
and ``launch/serve_fl.py`` — scenario/seed/ring-codec plus the
observability plane's flags — lives in ONE builder here, consumed by
all three launchers (train, serve_fl, and the transport client
client_fl), so the shared surface cannot drift: a flag rename or a new
default lands everywhere or nowhere.

``ObsStack.from_args`` is the runtime counterpart: it turns the obs
flags into the registry / tracer / windowed profiler / JSONL sink
quartet every launcher wires the same way (periodic snapshot flush per
round, final snapshot + trace write at exit).
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
from typing import Optional

logger = logging.getLogger("repro.launch.cli")


def add_scenario_flags(ap: argparse.ArgumentParser, *,
                       clients: int = 32) -> None:
    """--scenario/--clients/--samples-per-client/--seed: the seeded
    client population every scenario-driven launcher builds."""
    ap.add_argument("--scenario", default="paper-fig1")
    ap.add_argument("--clients", type=int, default=clients)
    ap.add_argument("--samples-per-client", type=int, default=64)
    add_seed_flag(ap)


def add_seed_flag(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--seed", type=int, default=0)


def add_ring_codec_flag(ap: argparse.ArgumentParser,
                        help_suffix: str = "") -> None:
    ap.add_argument("--ring-codec", default="f32",
                    choices=("f32", "int8", "delta"),
                    help="version-store codec (core/version_store.py, "
                         "DESIGN.md §11)" + help_suffix)


def add_obs_flags(ap: argparse.ArgumentParser) -> None:
    """The observability plane's flag quartet (DESIGN.md §9), identical
    on every launcher."""
    ap.add_argument("--log-level", default="info",
                    help="debug/info/warning/error (obs.configure_logging)")
    ap.add_argument("--trace-out", default=None,
                    help="write Chrome-trace-event JSON of the round "
                         "lifecycle here (perfetto-loadable)")
    ap.add_argument("--metrics-out", default=None,
                    help="append JSONL metrics snapshots here "
                         "(coordinator-gated)")
    ap.add_argument("--flush-every", type=int, default=8,
                    help="rounds between metrics-out snapshots")
    ap.add_argument("--profile-dir", default=None,
                    help="jax.profiler capture directory (windowed)")
    ap.add_argument("--profile-every", type=int, default=0,
                    help="rounds between device-profile windows (0 = off)")
    ap.add_argument("--profile-window", type=int, default=1,
                    help="rounds each device-profile window stays open")


@dataclasses.dataclass
class ObsStack:
    """The wired obs plane for one launcher process."""

    registry: "MetricsRegistry"
    tracer: "Tracer"
    profiler: "WindowedProfiler"
    sink: Optional["JsonlSink"]
    trace_out: Optional[str]
    metrics_out: Optional[str]
    flush_every: int

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "ObsStack":
        from repro.obs import (JsonlSink, MetricsRegistry, Tracer,
                               WindowedProfiler, configure_logging)

        configure_logging(args.log_level)
        return cls(
            registry=MetricsRegistry(),
            tracer=Tracer(enabled=bool(args.trace_out)),
            profiler=WindowedProfiler(args.profile_dir,
                                      every=args.profile_every,
                                      window=args.profile_window),
            sink=JsonlSink(args.metrics_out) if args.metrics_out else None,
            trace_out=args.trace_out, metrics_out=args.metrics_out,
            flush_every=args.flush_every)

    def round_hook(self, version: int) -> None:
        """Once per applied round: windowed profiler + periodic flush."""
        from repro.obs import emit_snapshot

        self.profiler.on_round(version)
        if self.sink is not None and self.flush_every \
                and version % self.flush_every == 0:
            emit_snapshot(self.sink, self.registry, version=version)
            self.sink.flush()

    def finish(self, version: int) -> None:
        """Final snapshot + trace write + close, same order everywhere."""
        from repro.obs import emit_snapshot

        self.profiler.close()
        if self.sink is not None:
            emit_snapshot(self.sink, self.registry, version=version,
                          final=True)
            self.sink.close()
            logger.info("metrics JSONL -> %s", self.metrics_out)
        if self.trace_out:
            self.tracer.write(self.trace_out)
            logger.info("chrome trace (%d events) -> %s",
                        len(self.tracer.events), self.trace_out)

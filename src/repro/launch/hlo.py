"""Parse collective traffic out of lowered/compiled HLO text.

``cost_analysis()`` reports FLOPs and HBM bytes but not collective bytes —
we sum the result-shape bytes of every collective op in the (stable-HLO or
post-optimization HLO) text. This is the canonical "payload bytes entering
the interconnect per participating device group" measure used by the
roofline's collective term; per-device link bytes are derived downstream
(bytes * (g-1)/g / devices for ring algorithms).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# matches e.g.:  %ag = bf16[2,512,4096]{2,1,0} all-gather(%x), ...
_HLO_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

# stablehlo/mhlo style:  stablehlo.all_reduce ... : tensor<512x4096xbf16>
_MLIR_RE = re.compile(
    r"\"?(?:stablehlo|mhlo)\.(all_gather|all_reduce|reduce_scatter|"
    r"all_to_all|collective_permute)\"?.*?tensor<([0-9x]*)x?([a-z0-9]+)>",
    re.DOTALL)


def _shape_bytes(dims: str, dtype: str) -> int:
    n = 1
    if dims:
        for d in dims.replace("x", ",").split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective-op kind. Returns {op: bytes} + total."""
    out: Dict[str, int] = defaultdict(int)
    for m in _HLO_RE.finditer(hlo_text):
        dtype, dims, op = m.groups()
        out[op] += _shape_bytes(dims, dtype)
    if not out:  # fall back to MLIR-style text
        for m in _MLIR_RE.finditer(hlo_text):
            op, dims, dtype = m.groups()
            out[op.replace("_", "-")] += _shape_bytes(dims, dtype)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))

"""Build (step_fn, shapes, shardings) for every (arch x input-shape x mesh).

This is the single source of truth used by dryrun.py (lower+compile),
train.py (real training) and serve.py. Everything is built from
ShapeDtypeStructs — no device allocation happens here.

Program kinds (from ShapeConfig.kind):
  train   -> one FL round: replicated-client cohort step or
             distributed-client streaming step (ArchConfig.fl_mode)
  prefill -> model.prefill_logits over the full prompt
  decode  -> model.decode_step: ONE new token against a seq_len KV cache
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ArchConfig, FLConfig, ModelConfig
from repro.configs.registry import get_arch
from repro.core.cohort import (
    init_cohort_state,
    init_dist_state,
    make_cohort_step,
    make_dist_step,
)
from repro.launch.mesh import batch_axes_for
from repro.models.model import build_model
from repro.sharding.specs import (
    batch_pspecs,
    cache_pspecs,
    cohort_state_pspecs,
    dist_state_pspecs,
    param_pspecs,
)

# Dry-run FL hyper-parameters: M=2 local steps keeps the round FLOPs at
# 2x(fwd+bwd) per slot; K/arrivals chosen per cohort size at build time.
DRYRUN_FL = FLConfig(local_steps=2, local_lr=1e-2, weighting="paper")
PROBE_BATCH = 4


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


def _model_batch_sds(cfg: ModelConfig, lead: Tuple[int, ...], seq: int,
                     with_labels: bool = True) -> Dict[str, Any]:
    """Batch leaves with arbitrary leading dims + a seq dim."""
    emb_dtype = jnp.dtype(cfg.compute_dtype)
    text = seq
    batch: Dict[str, Any] = {}
    if cfg.num_patches:
        text = seq - cfg.num_patches
        batch["patches"] = _sds(lead + (cfg.num_patches, cfg.d_model), emb_dtype)
    if cfg.is_encdec:
        batch["frames"] = _sds(lead + (cfg.encoder_seq_len, cfg.d_model), emb_dtype)
    batch["tokens"] = _sds(lead + (text,), jnp.int32)
    if with_labels:
        batch["labels"] = _sds(lead + (text,), jnp.int32)
    return batch


@dataclasses.dataclass
class Program:
    name: str
    kind: str
    step_fn: Callable
    arg_sds: Tuple[Any, ...]  # ShapeDtypeStructs, positional
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    static_argnums: Tuple[int, ...] = ()
    donate_argnums: Tuple[int, ...] = ()
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def resolve_model_cfg(arch: ArchConfig, shape_name: str) -> ModelConfig:
    """Apply per-shape variants (long_500k -> sliding-window for dense)."""
    cfg = arch.model
    if shape_name == "long_500k" and arch.long_context_window and not (
            cfg.attn_window or cfg.is_ssm_only):
        cfg = cfg.replace(attn_window=arch.long_context_window)
    return cfg


def build_program(arch_id: str, shape_name: str, mesh,
                  fl: Optional[FLConfig] = None,
                  model_overrides: Optional[Dict[str, Any]] = None) -> Program:
    arch = get_arch(arch_id)
    shape = INPUT_SHAPES[shape_name]
    if shape_name in arch.skip_shapes:
        raise ValueError(f"{arch_id} skips {shape_name}: see DESIGN.md")
    cfg = resolve_model_cfg(arch, shape_name)
    if model_overrides:
        cfg = cfg.replace(**model_overrides)
    model = build_model(cfg)
    baxes = batch_axes_for(mesh)
    dp = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                      for a in baxes]))
    fl = fl or DRYRUN_FL
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    meta = {"arch": arch_id, "shape": shape_name,
            "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "params": int(sum(np.prod(l.shape) for l in jax.tree.leaves(params_sds))),
            "active_params": model.active_param_count(params_sds),
            "fl_mode": arch.fl_mode, "seq_len": shape.seq_len,
            "global_batch": shape.global_batch}

    if shape.kind == "train":
        if arch.fl_mode == "replicated":
            return _build_cohort_train(model, fl, shape, mesh, baxes, dp, meta)
        return _build_dist_train(model, fl, shape, mesh, baxes, dp, meta)
    if shape.kind == "prefill":
        return _build_prefill(model, shape, arch, mesh, baxes, dp, meta)
    return _build_decode(model, shape, arch, mesh, baxes, dp, meta)


# ---------------------------------------------------------------------------


def _build_cohort_train(model, fl, shape, mesh, baxes, dp, meta) -> Program:
    cfg = model.cfg
    cohort = dp  # one client slot per data-parallel group
    assert shape.global_batch % cohort == 0, (shape.global_batch, cohort)
    b = shape.global_batch // cohort
    m = fl.local_steps
    state_sds = jax.eval_shape(lambda p: init_cohort_state(p, cohort),
                               jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    batch_sds = {
        "local": _model_batch_sds(cfg, (cohort, m, b), shape.seq_len),
        "probe": _model_batch_sds(cfg, (cohort, PROBE_BATCH), shape.seq_len),
        "arrival": _sds((cohort,), jnp.float32),
        "data_sizes": _sds((cohort,), jnp.float32),
    }
    state_specs = cohort_state_pspecs(state_sds, mesh, client_axes=baxes)
    batch_specs = batch_pspecs(batch_sds, batch_axes=baxes)
    # the round substrate shards explicitly on this mesh (DESIGN.md §5):
    # C-slot vmap over data, flat-vector server pass over model
    step = make_cohort_step(model.loss, fl, mesh=mesh)
    metrics_specs = {"fresh_loss_mean": P(), "staleness_min": P(),
                     "weights_max": P(), "update_sq_norm": P()}
    meta.update(cohort=cohort, local_batch=b, local_steps=m)
    return Program(
        name=f"{meta['arch']}:{meta['shape']}", kind="train", step_fn=step,
        arg_sds=(state_sds, batch_sds),
        in_shardings=(_named(mesh, state_specs), _named(mesh, batch_specs)),
        out_shardings=(_named(mesh, state_specs), _named(mesh, metrics_specs)),
        donate_argnums=(0,), meta=meta)


def _build_dist_train(model, fl, shape, mesh, baxes, dp, meta) -> Program:
    cfg = model.cfg
    m = fl.local_steps
    state_sds = jax.eval_shape(
        lambda p: init_dist_state(p, fl),
        jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    batch_sds = {
        "local": _model_batch_sds(cfg, (m, shape.global_batch), shape.seq_len),
        "probe": _model_batch_sds(cfg, (fl.probe_batch * dp,), shape.seq_len),
        "tau": _sds((), jnp.int32),
        "data_size": _sds((), jnp.float32),
    }
    state_specs = dist_state_pspecs(state_sds, mesh)

    def bspec(l):
        if l.ndim == 0:
            return P()
        if l.ndim >= 2:  # (M, b, ...): shard b
            ax = baxes if len(baxes) > 1 else baxes[0]
            return P(None, ax, *([None] * (l.ndim - 2)))
        return P()

    batch_specs = {
        "local": jax.tree.map(bspec, batch_sds["local"]),
        "probe": batch_pspecs(batch_sds["probe"], batch_axes=baxes),
        "tau": P(), "data_size": P(),
    }
    step = make_dist_step(model.loss, fl)
    metrics_specs = {"fresh_loss": P(), "v_weight": P(), "buffered": P(),
                     "applied": P()}
    meta.update(cohort=1, local_batch=shape.global_batch, local_steps=m)
    return Program(
        name=f"{meta['arch']}:{meta['shape']}", kind="train", step_fn=step,
        arg_sds=(state_sds, batch_sds),
        in_shardings=(_named(mesh, state_specs), _named(mesh, batch_specs)),
        out_shardings=(_named(mesh, state_specs), _named(mesh, metrics_specs)),
        donate_argnums=(0,), meta=meta)


def make_io_hooks(*, ckpt_path: Optional[str] = None, ckpt_every: int = 0,
                  log_fn: Callable[[str], None] = print,
                  registry: Optional[Any] = None,
                  tracer: Optional[Any] = None,
                  sink: Optional[Any] = None):
    """Coordinator-gated IO for multi-controller training loops (§7),
    reporting into the observability plane (§9).

    Returns ``(log, eval_metrics, maybe_save)``:

    * ``log(msg)`` — emits only on process 0 (every process may call it);
      with a ``sink`` (obs.JsonlSink / InMemorySink) each message is also
      emitted as a structured ``{"event": "log", ...}`` record (the sink
      applies its own coordinator gate);
    * ``eval_metrics(metrics)`` — fetches a metrics pytree to host floats
      from process-local addressable shards (ALL processes must call it:
      non-replicated leaves cost one resharding collective), returning
      the dict everywhere so control flow stays identical across
      processes; the fetch is a ``host_sync`` span and every metric lands
      in a ``train_<name>`` registry gauge;
    * ``maybe_save(step, tree)`` — writes ``ckpt_path`` every
      ``ckpt_every`` steps via the coordinator-gated
      ``checkpoint.save_checkpoint`` (again: call on every process),
      timed as a ``checkpoint`` span and counted in the registry.

    Keeping the gate in ONE place means a training loop written against
    these hooks runs unchanged on a laptop and on a pod slice.
    """
    from repro.checkpoint import save_checkpoint
    from repro.launch.multihost import fetch_replicated, is_coordinator
    from repro.obs.metrics import default_registry
    from repro.obs.trace import NULL_TRACER, SPAN_CHECKPOINT, SPAN_HOST_SYNC

    reg = registry if registry is not None else default_registry()
    tr = tracer if tracer is not None else NULL_TRACER
    syncs = reg.counter("train_host_syncs_total")
    ckpts = reg.counter("train_checkpoints_total")

    def log(msg: str) -> None:
        if sink is not None:
            sink.emit({"event": "log", "msg": msg})
        if is_coordinator():
            log_fn(msg)

    def eval_metrics(metrics: Any) -> Dict[str, float]:
        with tr.span(SPAN_HOST_SYNC, what="eval_metrics"):
            syncs.inc()
            host = fetch_replicated(metrics)
        out = {k: float(np.asarray(v)) for k, v in host.items()}
        for k, v in out.items():
            reg.gauge(f"train_{k}").set(v)
        return out

    def maybe_save(step: int, tree: Any) -> bool:
        if not ckpt_path or not ckpt_every or step % ckpt_every:
            return False
        with tr.span(SPAN_CHECKPOINT, step=step):
            ckpts.inc()
            save_checkpoint(ckpt_path, tree, step=step)
        return True

    return log, eval_metrics, maybe_save


def _build_prefill(model, shape, arch, mesh, baxes, dp, meta) -> Program:
    cfg = model.cfg
    fsdp = arch.fl_mode == "distributed"
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch_sds = _model_batch_sds(cfg, (shape.global_batch,), shape.seq_len,
                                 with_labels=False)
    pspecs = param_pspecs(params_sds, mesh, fsdp=fsdp)
    bspecs = batch_pspecs(batch_sds, batch_axes=baxes)

    def step(params, batch):
        return model.prefill_logits(params, batch)

    return Program(
        name=f"{meta['arch']}:{meta['shape']}", kind="prefill", step_fn=step,
        arg_sds=(params_sds, batch_sds),
        in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
        out_shardings=None, meta=meta)


def _build_decode(model, shape, arch, mesh, baxes, dp, meta) -> Program:
    cfg = model.cfg
    fsdp = arch.fl_mode == "distributed"
    b = shape.global_batch
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_sds = jax.eval_shape(lambda: model.init_cache(b, shape.seq_len))
    tok_sds = _sds((b, 1), jnp.int32)
    pspecs = param_pspecs(params_sds, mesh, fsdp=fsdp)
    cspecs = cache_pspecs(cache_sds, mesh, batch_axes=baxes)
    if b % dp == 0:
        tok_spec = P(baxes if len(baxes) > 1 else baxes[0], None)
    else:
        tok_spec = P(None, None)  # e.g. long_500k: batch=1 cannot shard

    def step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    meta.update(cache_len=min(shape.seq_len, cfg.attn_window or shape.seq_len))
    return Program(
        name=f"{meta['arch']}:{meta['shape']}", kind="decode", step_fn=step,
        arg_sds=(params_sds, cache_sds, tok_sds, _sds((), jnp.int32)),
        in_shardings=(_named(mesh, pspecs), _named(mesh, cspecs),
                      NamedSharding(mesh, tok_spec),
                      NamedSharding(mesh, P())),
        out_shardings=None, donate_argnums=(1,), meta=meta)

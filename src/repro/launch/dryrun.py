import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production mesh, with 512 placeholder host devices standing in for the TPU
pod(s). Proves the sharding config is coherent end-to-end and extracts the
roofline inputs (FLOPs, bytes, collective traffic, per-device memory).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import INPUT_SHAPES  # noqa: E402
from repro.configs.registry import get_arch, list_archs  # noqa: E402
from repro.launch.hlo import collective_bytes  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.program import build_program  # noqa: E402


def _parse_overrides(items):
    out = {}
    for it in items or []:
        k, v = it.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "True"):
            v = True
        if v in ("false", "False"):
            v = False
        out[k] = v
    return out


def run_one(arch_id: str, shape_name: str, multi_pod: bool,
            verbose: bool = True, model_overrides: dict = None,
            fl=None) -> dict:
    rec = {"arch": arch_id, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False,
           "model_overrides": model_overrides or {}}
    arch = get_arch(arch_id)
    if shape_name in arch.skip_shapes:
        rec.update(skipped=True, reason=f"skip per DESIGN.md: {arch.notes[:80]}")
        rec["ok"] = True
        if verbose:
            print(f"[dryrun] SKIP {arch_id} x {shape_name} (by design)")
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        prog = build_program(arch_id, shape_name, mesh, fl=fl,
                             model_overrides=model_overrides)
        with mesh:
            jitted = jax.jit(
                prog.step_fn,
                in_shardings=prog.in_shardings,
                out_shardings=prog.out_shardings,
                donate_argnums=prog.donate_argnums,
            )
            lowered = jitted.lower(*prog.arg_sds)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        coll = collective_bytes(hlo)
        if os.environ.get("DRYRUN_SAVE_HLO"):
            os.makedirs(os.environ["DRYRUN_SAVE_HLO"], exist_ok=True)
            with open(os.path.join(os.environ["DRYRUN_SAVE_HLO"],
                                   f"{arch_id}_{shape_name}.hlo.txt"), "w") as f:
                f.write(hlo)
        mem_rec = {}
        if mem is not None:
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                mem_rec[f] = int(getattr(mem, f, 0) or 0)
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            flops=float(cost.get("flops", 0.0)) if cost else 0.0,
            bytes_accessed=float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
            collective_bytes=coll,
            memory=mem_rec,
            meta=prog.meta,
        )
        if verbose:
            print(f"[dryrun] OK {arch_id} x {shape_name} mesh={rec['mesh']} "
                  f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"flops={rec['flops']:.3e} coll={coll.get('total', 0):.3e}B")
            if mem_rec:
                print(f"         memory_analysis: {mem_rec}")
    except Exception as e:  # noqa: BLE001 — a failure here is a finding
        rec.update(error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[dryrun] FAIL {arch_id} x {shape_name}: {rec['error']}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default=None, help="directory for per-pair JSON")
    ap.add_argument("--tag", default=None, help="suffix for output JSON names")
    ap.add_argument("--model-override", action="append", default=[],
                    help="k=v ModelConfig overrides (perf experiments)")
    ap.add_argument("--fl-override", action="append", default=[],
                    help="k=v FLConfig overrides (perf experiments)")
    args = ap.parse_args()
    overrides = _parse_overrides(args.model_override)
    fl = None
    fl_over = _parse_overrides(args.fl_override)
    if fl_over:
        import dataclasses as _dc

        from repro.launch.program import DRYRUN_FL
        fl = _dc.replace(DRYRUN_FL, **fl_over)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for multi in meshes:
        for a in archs:
            for s in shapes:
                rec = run_one(a, s, multi, model_overrides=overrides, fl=fl)
                n_fail += 0 if rec["ok"] else 1
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    suffix = f"_{args.tag}" if args.tag else ""
                    tag = f"{a}_{s}_{'multi' if multi else 'single'}{suffix}.json"
                    with open(os.path.join(args.out, tag), "w") as f:
                        json.dump(rec, f, indent=1)
    print(f"[dryrun] done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

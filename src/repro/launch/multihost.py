"""Multi-host execution substrate (DESIGN.md §7).

One Python process per host, every process running the SAME program
(single-program multi-controller): ``initialize`` brings up
``jax.distributed`` (gloo collectives on CPU), ``make_round_mesh`` builds
the process-spanning (data, model) mesh, and the put/fetch helpers move
host values onto a mesh that spans processes and read results back from
**process-local addressable shards** — never ``jax.device_get`` on a
non-addressable array.

Layout contract (what makes multi-host bit-identical to single-host):
the ``data`` axis is split across processes (each process contributes
whole data rows of its local devices) and the ``model`` axis stays
WITHIN a process whenever ``data >= process_count``. The eq. 3 psum over
``model`` then reduces the same per-shard partials in the same intra-host
collective as the equally-shaped single-process mesh, so the round log
and final params match bit-for-bit (pinned by tests/_multihost_worker.py).
Cross-process traffic on the engine path is pure data movement — the
``data``-axis allgather of client deltas and the replication broadcast of
the new params — which is exact.

On the §4 engine path every process runs the host event loop on the
same seeds, so per-round metadata (windows, batches, staleness) is
identical everywhere without communication; device arrays are the only
shared state. The §10 population engine removes even that replay:
window selection runs ON the mesh (client state sharded over ``data``,
initialized with ``out_shardings`` so each process materializes only
its addressable shards) and the round log comes back through
``fetch_replicated``. IO is coordinator-gated: ``is_coordinator()``
(process 0) guards checkpoint writes and log emission (see
checkpoint/ckpt.py, launch/program.py).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_INITIALIZED = False


def initialize(coordinator_address: str, num_processes: int,
               process_id: int, *,
               cpu_collectives: str = "gloo") -> None:
    """Bring up the jax.distributed runtime for one process.

    Must run before any computation touches the backend. On CPU the
    cross-process collectives need a real implementation (the default is
    none): ``cpu_collectives`` selects it — gloo ships in jaxlib's Linux
    wheels and is what the CI harness uses. Idempotent per process.
    """
    global _INITIALIZED
    if _INITIALIZED:
        return
    if num_processes > 1:
        try:
            jax.config.update("jax_cpu_collectives_implementation",
                              cpu_collectives)
        except AttributeError:  # renamed/absent on this jax: use defaults
            pass
        jax.distributed.initialize(coordinator_address=coordinator_address,
                                   num_processes=num_processes,
                                   process_id=process_id)
    _INITIALIZED = True


def is_coordinator() -> bool:
    """True on process 0 — the only process that writes (ckpt, logs)."""
    return jax.process_index() == 0


def mesh_spans_processes(mesh: Optional[Any]) -> bool:
    """True when ``mesh`` contains devices of more than one process."""
    if mesh is None:
        return False
    procs = {d.process_index for d in np.asarray(mesh.devices).flat}
    return len(procs) > 1


def make_round_mesh(data: int = 0, model: int = 0) -> Mesh:
    """Process-spanning (data, model) mesh for the round substrate.

    Each process contributes ``data / process_count`` whole rows of
    ``model`` of its OWN local devices, so the ``model`` axis — the eq. 3
    psum and the ``P(None, "model")`` version ring — never crosses a
    process boundary and the reduction structure matches the same-shaped
    single-process mesh exactly (the bit-parity contract). ``data=0``
    defaults to one row per process; ``model=0`` spreads each process's
    remaining local devices on the model axis. Single-process sessions
    get the same layout as ``launch/mesh.make_round_mesh``.
    """
    procs = jax.process_count()
    local = len(jax.local_devices())
    if data == 0:
        data = procs
    if data % procs:
        raise ValueError(
            f"data axis ({data}) must be a multiple of the process count "
            f"({procs}): each process contributes whole data rows")
    rows_per_proc = data // procs
    if model == 0:
        model = max(1, local // rows_per_proc)
    need = rows_per_proc * model
    if need > local:
        raise ValueError(
            f"mesh ({data}, {model}) needs {need} devices per process, "
            f"process {jax.process_index()} has {local}")
    by_proc: dict = {}
    for d in jax.devices():
        by_proc.setdefault(d.process_index, []).append(d)
    rows = []
    for p in sorted(by_proc):
        devs = sorted(by_proc[p], key=lambda d: d.id)[:need]
        rows.extend(np.asarray(devs).reshape(rows_per_proc, model))
    return Mesh(np.stack(rows), ("data", "model"))


# ---------------------------------------------------------------------------
# host <-> process-spanning-mesh transfers
# ---------------------------------------------------------------------------


def put_with_sharding(value: Any, mesh: Mesh, pspec: P) -> jax.Array:
    """Place a host value on ``mesh`` under ``pspec``, processes included.

    Every process must call this with the SAME value (the
    single-program-multi-controller contract; the engine's host event
    loop guarantees it by determinism). Uses ``make_array_from_callback``
    so each process materialises only its addressable shards.
    """
    sharding = NamedSharding(mesh, pspec)
    if not mesh_spans_processes(mesh):
        # single-process mesh: plain device_put (an on-device reshard
        # when the value already lives on device — no host round-trip)
        return jax.device_put(value, sharding)
    arr = np.asarray(value)
    return jax.make_array_from_callback(arr.shape, sharding,
                                        lambda idx: arr[idx])


def put_replicated(tree: Any, mesh: Mesh) -> Any:
    """Replicate every leaf of a host pytree across the whole mesh."""
    return jax.tree.map(lambda x: put_with_sharding(x, mesh, P()), tree)


def fetch_replicated(tree: Any) -> Any:
    """Fetch a pytree of device arrays to host numpy, multi-process safe.

    The multi-host replacement for the engine's end-of-run
    ``jax.device_get``: fully-addressable arrays fetch normally; a fully
    replicated process-spanning array is read from the FIRST
    PROCESS-LOCAL ADDRESSABLE SHARD (its data is the whole array — no
    communication, every process gets the full value); anything else is
    first all-gathered to every process by a resharding identity jit
    (one collective), then read locally. ``jax.device_get`` is never
    called on a non-addressable array.
    """

    def leaf(x):
        if not isinstance(x, jax.Array) or x.is_fully_addressable:
            return np.asarray(jax.device_get(x))
        if not x.is_fully_replicated:
            x = _replicate_fn(NamedSharding(x.sharding.mesh, P()))(x)
        return np.asarray(x.addressable_shards[0].data)

    return jax.tree.map(leaf, tree)


@functools.lru_cache(maxsize=32)
def _replicate_fn(sharding: NamedSharding):
    """One cached resharding identity jit per target sharding — a fresh
    lambda per call would defeat jax's jit cache and recompile on every
    fetch of a non-replicated leaf."""
    return jax.jit(lambda a: a, out_shardings=sharding)

"""Always-on FL serving launcher: continuous-arrival aggregation rounds.

Runs the ``core/serving.py`` controller as a long-lived endpoint behind
one of three ingresses (DESIGN.md §12):

* ``--transport inproc`` (default) — the deterministic in-process twin:
  a ``sim/`` scenario acts as the traffic generator, client uploads
  arrive on seeded per-client timelines, everything runs on the sim
  clock with no sockets. This is the CI serving smoke lane.
* ``--transport tcp`` / ``--transport http`` — a real
  ``transport.AggregatorServer``: framed-TCP or HTTP listener threads
  feed the controller's thread-safe offer queue while THIS thread runs
  the single-threaded fold loop on wall-clock time. Real clients
  (``launch/client_fl.py``) connect over loopback or the network.

Either way uploads pass admission control (bounded ingress queue,
staleness drops, queue-full backpressure with retry-after), fold through
the streaming round body, and the adaptive controller tunes K toward
``--target-latency``.

Loopback parity (the §12 gate): ``--journal-out j.jsonl`` records every
fold (client, draw seq, base version, payload sha) in fold order;
``--replay-journal j.jsonl`` reconstructs that exact fold sequence from
the seeded datasets IN PROCESS and reports the resulting
``params_sha256`` — byte-equal to the live transport run's digest when
the wire (f32) and the fold math are faithful. Parity replay requires
the live run to use ``--adapt-every 0`` (a fixed K; the adaptive
controller's wall-clock inputs are not journaled).

The observability plane (DESIGN.md §9) hangs off the shared obs flags
(``launch/cli.py``): ``--trace-out`` Chrome-trace spans (round
lifecycle + transport decode/offer spans), ``--metrics-out`` JSONL
snapshots, ``--profile-dir/--profile-every`` windowed device captures,
``--log-level``.

Examples:
  PYTHONPATH=src python -m repro.launch.serve_fl --scenario paper-fig1 \
      --clients 32 --rounds 20 --weighting fedasync_hinge --json
  PYTHONPATH=src python -m repro.launch.serve_fl --transport tcp \
      --port 0 --port-file /tmp/port --rounds 4 --adapt-every 0 \
      --journal-out /tmp/j.jsonl --json
"""
from __future__ import annotations

import argparse
import json
import logging
import os
import time
from typing import Any, Dict, Optional, TextIO

import jax

from repro.configs.base import FLConfig
from repro.core.serving import ServeConfig, ServingController, serve_stream
from repro.launch.cli import (
    ObsStack,
    add_obs_flags,
    add_ring_codec_flag,
    add_scenario_flags,
)
from repro.models.lenet import init_lenet, lenet_loss
from repro.sim import get_scenario
from repro.sim.arrivals import TrafficGenerator, draw_upload

logger = logging.getLogger("repro.launch.serve_fl")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    add_scenario_flags(ap)
    ap.add_argument("--weighting", default="paper")
    ap.add_argument("--buffer-k", type=int, default=8,
                    help="initial K (the adaptive controller moves it)")
    ap.add_argument("--max-staleness", type=int, default=12)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    add_ring_codec_flag(
        ap, help_suffix="; the streaming path keeps only the O(R) scalar "
                        "update-norm ring, so this is provenance + parity "
                        "with engine runs of the same FLConfig")
    # serving knobs
    ap.add_argument("--queue-capacity", type=int, default=64)
    ap.add_argument("--service-time", type=float, default=0.0,
                    help="modeled sim-time to fold one upload (0 = free)")
    ap.add_argument("--target-latency", type=float, default=2.0)
    ap.add_argument("--k-min", type=int, default=2)
    ap.add_argument("--k-max", type=int, default=64)
    ap.add_argument("--adapt-every", type=int, default=4,
                    help="rounds between K adjustments (0 = fixed K; "
                         "required for journal parity replay)")
    # transport ingress (DESIGN.md §12)
    ap.add_argument("--transport", default="inproc",
                    choices=("inproc", "tcp", "http"),
                    help="inproc = scenario-driven deterministic twin; "
                         "tcp/http = real socket ingress for client_fl")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0 = ephemeral; see --port-file)")
    ap.add_argument("--port-file", default=None,
                    help="write the bound port here once listening "
                         "(atomic rename), so --port 0 orchestration "
                         "can find the server")
    ap.add_argument("--max-wall-time", type=float, default=None,
                    help="wall-clock bound for the transport fold loop "
                         "(safety net when clients die early)")
    ap.add_argument("--journal-out", default=None,
                    help="record every fold (cid/seq/base_version/sha) "
                         "as JSONL, in fold order — the parity replay "
                         "input")
    ap.add_argument("--replay-journal", default=None,
                    help="re-fold a recorded journal in-process from the "
                         "seeded datasets and report params_sha256 "
                         "(ignores --transport)")
    # run bounds (a service has no natural end; at least one must bind)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--max-events", type=int, default=None)
    ap.add_argument("--max-time", type=float, default=None,
                    help="sim-time horizon (inproc only)")
    ap.add_argument("--json", action="store_true",
                    help="dump the full metrics dict as JSON")
    add_obs_flags(ap)
    return ap


def _attach_journal(ctrl: ServingController, f: TextIO) -> None:
    """Journal every fold, in fold order. Runs on the aggregator thread
    (pump's single owner), so plain writes are race-free."""
    from repro.transport import wire

    def hook(upload, tau: int) -> None:
        f.write(json.dumps({
            "cid": int(upload.client_id), "seq": int(upload.seq),
            "base_version": int(upload.base_version), "tau": int(tau),
            "sent_at": float(upload.sent_at),
            "sha": wire.payload_sha256(upload)}) + "\n")

    ctrl.fold_hook = hook


def replay_journal(path: str, ctrl: ServingController, clients,
                   fl: FLConfig) -> int:
    """Re-fold a recorded journal from the seeded datasets.

    Each entry's upload is reconstructed via the shared ``draw_upload``
    (skipped seqs — uploads that were drawn but never folded, e.g.
    dropped as stale — consume their dataset draws and are discarded),
    sha-verified against the journal, then offered + pumped with a
    FIXED K, reproducing the live run's fold order and taus exactly.
    Returns the number of folds replayed.
    """
    drawn = [0] * len(clients)
    folded = 0
    with open(path) as f:
        for line in f:
            e = json.loads(line)
            cid, seq = int(e["cid"]), int(e["seq"])
            ds = clients[cid]
            # burn the client's skipped draws so seq-th draw aligns
            while drawn[cid] < seq:
                draw_upload(ds, cid, fl, base_version=0, t=0.0)
                drawn[cid] += 1
            if drawn[cid] > seq:
                raise ValueError(
                    f"journal out of order: client {cid} seq {seq} after "
                    f"{drawn[cid]} draws")
            up = draw_upload(ds, cid, fl,
                             base_version=int(e["base_version"]),
                             t=float(e["sent_at"]), seq=seq)
            drawn[cid] += 1
            from repro.transport import wire
            sha = wire.payload_sha256(up)
            if sha != e["sha"]:
                raise ValueError(
                    f"journal sha mismatch for client {cid} seq {seq}: "
                    f"replay {sha[:12]} != recorded {e['sha'][:12]} "
                    "(seed/scenario/flags differ from the live run?)")
            adm = ctrl.offer(up, float(e["sent_at"]))
            if not adm.accepted:
                raise ValueError(
                    f"replay rejected client {cid} seq {seq} "
                    f"({adm.reason}); the live run folded it — config "
                    "mismatch")
            ctrl.pump(float(e["sent_at"]))
            folded += 1
    return folded


def _write_port_file(path: str, port: int) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(str(port))
    os.replace(tmp, path)  # atomic: readers never see a partial write


def main() -> None:
    args = build_parser().parse_args()
    obs = ObsStack.from_args(args)

    fl = FLConfig(num_clients=args.clients, buffer_size=args.buffer_k,
                  max_staleness=args.max_staleness,
                  local_steps=args.local_steps, batch_size=args.batch,
                  weighting=args.weighting, ring_codec=args.ring_codec)
    cfg = ServeConfig(queue_capacity=args.queue_capacity,
                      service_time=args.service_time,
                      target_round_latency=args.target_latency,
                      k_min=args.k_min, k_max=args.k_max,
                      adapt_every=args.adapt_every)
    sc = get_scenario(args.scenario)
    clients, _ = sc.make_dataset(args.clients,
                                 samples_per_client=args.samples_per_client,
                                 seed=args.seed)

    params = init_lenet(jax.random.PRNGKey(args.seed))

    if args.replay_journal:
        # parity replay is in-process by construction: fixed K, free
        # service, fold-per-offer — the journal IS the event stream
        cfg = ServeConfig(queue_capacity=args.queue_capacity,
                          service_time=0.0,
                          target_round_latency=args.target_latency,
                          k_min=args.k_min, k_max=args.k_max,
                          adapt_every=0)
        ctrl = ServingController(lenet_loss, params, fl, cfg,
                                 registry=obs.registry, tracer=obs.tracer)
        t0 = time.perf_counter()
        folded = replay_journal(args.replay_journal, ctrl, clients, fl)
        out = ctrl.snapshot()
        out["seconds"] = time.perf_counter() - t0
        out["replayed"] = folded
        _finish(args, obs, ctrl, out)
        return

    ctrl = ServingController(lenet_loss, params, fl, cfg,
                             registry=obs.registry, tracer=obs.tracer)
    journal = open(args.journal_out, "w") if args.journal_out else None
    if journal is not None:
        _attach_journal(ctrl, journal)

    try:
        if args.transport == "inproc":
            out = _serve_inproc(args, obs, ctrl, sc, clients, fl)
        else:
            out = _serve_transport(args, obs, ctrl)
    finally:
        if journal is not None:
            journal.close()
            logger.info("fold journal -> %s", args.journal_out)
    _finish(args, obs, ctrl, out)


def _serve_inproc(args, obs: ObsStack, ctrl: ServingController, sc,
                  clients, fl: FLConfig) -> Dict[str, Any]:
    behavior = sc.behavior(args.clients, seed=args.seed)
    gen = TrafficGenerator(clients, behavior, fl)
    logger.info("serving scenario=%s clients=%d weighting=%s K0=%d "
                "target_latency=%s", sc.name, args.clients, args.weighting,
                ctrl.k, args.target_latency)
    t0 = time.perf_counter()
    out = serve_stream(ctrl, gen, max_rounds=args.rounds,
                       max_events=args.max_events, max_time=args.max_time,
                       round_hook=obs.round_hook)
    dt = time.perf_counter() - t0
    out["seconds"] = dt
    out["uploads_per_sec"] = out["folded"] / dt if dt > 0 else 0.0
    logger.info("admission: admitted=%d queue_full=%d stale_ingress=%d "
                "stale_queue=%d lost=%d retries=%d queue_depth_max=%d",
                out["admitted"], out["rejected_queue_full"],
                out["dropped_stale_ingress"], out["dropped_stale_queue"],
                out["lost_in_transit"], out["retries_scheduled"],
                out["queue_depth_max"])
    return out


def _serve_transport(args, obs: ObsStack,
                     ctrl: ServingController) -> Dict[str, Any]:
    from repro.transport.server import AggregatorServer

    srv = AggregatorServer(ctrl, transport=args.transport, host=args.host,
                           port=args.port, registry=obs.registry,
                           tracer=obs.tracer)
    if args.port_file:
        _write_port_file(args.port_file, srv.port)
    srv.start()
    logger.info("serving %s on %s:%d until version >= %d%s",
                args.transport, args.host, srv.port, args.rounds,
                f" or {args.max_wall_time}s" if args.max_wall_time else "")
    t0 = time.perf_counter()

    def stop() -> bool:
        if ctrl.version >= args.rounds:
            return True
        return bool(args.max_wall_time
                    and time.perf_counter() - t0 > args.max_wall_time)

    try:
        srv.serve(stop=stop, round_hook=obs.round_hook)
    finally:
        srv.shutdown()
    dt = time.perf_counter() - t0
    out = ctrl.snapshot()
    out["seconds"] = dt
    out["uploads_per_sec"] = out["folded"] / dt if dt > 0 else 0.0
    out["transport"] = args.transport
    out["port"] = srv.port
    return out


def _finish(args, obs: ObsStack, ctrl: ServingController,
            out: Dict[str, Any]) -> None:
    from repro.transport import wire

    version, params = ctrl.pull()
    out["params_sha256"] = wire.params_sha256(version, params)
    logger.info("%d rounds / %d uploads folded in %.2fs -> %.1f uploads/s",
                out["rounds"], out["folded"], out["seconds"],
                out.get("uploads_per_sec", 0.0))
    logger.info("round latency p50=%.3fs p99=%.3fs, cadence mean=%.3fs, "
                "arrival rate=%.2f/s, K -> %d; params_sha256=%s",
                out["round_latency_p50"], out["round_latency_p99"],
                out["round_cadence_mean"], out["arrival_rate"], out["k"],
                out["params_sha256"][:16])
    obs.finish(ctrl.version)
    if args.json:
        print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()

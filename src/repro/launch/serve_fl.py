"""Always-on FL serving launcher: continuous-arrival aggregation rounds.

Runs the ``core/serving.py`` controller as a long-lived endpoint with a
``sim/`` scenario acting as the in-process traffic generator: client
uploads arrive on the scenario's seeded per-client timelines, pass
admission control (bounded ingress queue, staleness drops, queue-full
backpressure with retry-after), and are folded through the streaming
round body; the adaptive controller tunes buffer size K to the observed
arrival rate to hold round cadence near ``--target-latency``.

Everything is in-process and deterministic under ``--seed`` — no sockets
— so the same entry point doubles as the CI serving smoke lane.

The observability plane (DESIGN.md §9) hangs off four flags:

* ``--trace-out t.json``    Chrome-trace spans of the round lifecycle
                            (``collect_window``/``contribute``/``apply``)
                            — load in perfetto / chrome://tracing; the CI
                            smoke lane validates the schema and >= 95%
                            round-wall-time span coverage;
* ``--metrics-out m.jsonl`` JSONL metrics snapshots, one event every
                            ``--flush-every`` rounds plus a final one
                            (coordinator-gated; the nightly job uploads
                            this as an artifact);
* ``--profile-dir d``       with ``--profile-every N``: a windowed
                            ``jax.profiler`` device capture every N
                            rounds, host spans annotated onto the device
                            timeline;
* ``--log-level``           drives ``obs.configure_logging``.

Example:
  PYTHONPATH=src python -m repro.launch.serve_fl --scenario paper-fig1 \
      --clients 32 --rounds 20 --weighting fedasync_hinge \
      --trace-out serve_trace.json --json
"""
from __future__ import annotations

import argparse
import json
import logging
import time

import jax

from repro.configs.base import FLConfig
from repro.core.serving import ServeConfig, ServingController, serve_stream
from repro.models.lenet import init_lenet, lenet_loss
from repro.obs import (
    JsonlSink,
    MetricsRegistry,
    Tracer,
    WindowedProfiler,
    configure_logging,
    emit_snapshot,
)
from repro.sim import get_scenario
from repro.sim.arrivals import TrafficGenerator

logger = logging.getLogger("repro.launch.serve_fl")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="paper-fig1")
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--samples-per-client", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--weighting", default="paper")
    ap.add_argument("--buffer-k", type=int, default=8,
                    help="initial K (the adaptive controller moves it)")
    ap.add_argument("--max-staleness", type=int, default=12)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ring-codec", default="f32",
                    choices=("f32", "int8", "delta"),
                    help="version-store codec (core/version_store.py); the "
                         "streaming path keeps only the O(R) scalar "
                         "update-norm ring, so this is provenance + parity "
                         "with engine runs of the same FLConfig")
    # serving knobs
    ap.add_argument("--queue-capacity", type=int, default=64)
    ap.add_argument("--service-time", type=float, default=0.0,
                    help="modeled sim-time to fold one upload (0 = free)")
    ap.add_argument("--target-latency", type=float, default=2.0)
    ap.add_argument("--k-min", type=int, default=2)
    ap.add_argument("--k-max", type=int, default=64)
    ap.add_argument("--adapt-every", type=int, default=4,
                    help="rounds between K adjustments (0 = fixed K)")
    # run bounds (a service has no natural end; at least one must bind)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--max-events", type=int, default=None)
    ap.add_argument("--max-time", type=float, default=None,
                    help="sim-time horizon")
    ap.add_argument("--json", action="store_true",
                    help="dump the full metrics dict as JSON")
    # observability (DESIGN.md §9)
    ap.add_argument("--log-level", default="info",
                    help="debug/info/warning/error (obs.configure_logging)")
    ap.add_argument("--trace-out", default=None,
                    help="write Chrome-trace-event JSON of the round "
                         "lifecycle here (perfetto-loadable)")
    ap.add_argument("--metrics-out", default=None,
                    help="append JSONL metrics snapshots here "
                         "(coordinator-gated)")
    ap.add_argument("--flush-every", type=int, default=8,
                    help="rounds between metrics-out snapshots")
    ap.add_argument("--profile-dir", default=None,
                    help="jax.profiler capture directory (windowed)")
    ap.add_argument("--profile-every", type=int, default=0,
                    help="rounds between device-profile windows (0 = off)")
    ap.add_argument("--profile-window", type=int, default=1,
                    help="rounds each device-profile window stays open")
    args = ap.parse_args()

    configure_logging(args.log_level)
    registry = MetricsRegistry()
    tracer = Tracer(enabled=bool(args.trace_out))
    profiler = WindowedProfiler(args.profile_dir, every=args.profile_every,
                                window=args.profile_window)
    sink = JsonlSink(args.metrics_out) if args.metrics_out else None

    fl = FLConfig(num_clients=args.clients, buffer_size=args.buffer_k,
                  max_staleness=args.max_staleness,
                  local_steps=args.local_steps, batch_size=args.batch,
                  weighting=args.weighting, ring_codec=args.ring_codec)
    cfg = ServeConfig(queue_capacity=args.queue_capacity,
                      service_time=args.service_time,
                      target_round_latency=args.target_latency,
                      k_min=args.k_min, k_max=args.k_max,
                      adapt_every=args.adapt_every)
    sc = get_scenario(args.scenario)
    clients, _ = sc.make_dataset(args.clients,
                                 samples_per_client=args.samples_per_client,
                                 seed=args.seed)
    behavior = sc.behavior(args.clients, seed=args.seed)

    params = init_lenet(jax.random.PRNGKey(args.seed))
    ctrl = ServingController(lenet_loss, params, fl, cfg,
                             registry=registry, tracer=tracer)
    gen = TrafficGenerator(clients, behavior, fl)

    def round_hook(version: int) -> None:
        profiler.on_round(version)
        if sink is not None and args.flush_every \
                and version % args.flush_every == 0:
            emit_snapshot(sink, registry, version=version)
            sink.flush()

    logger.info("serving scenario=%s clients=%d weighting=%s K0=%d "
                "target_latency=%s", sc.name, args.clients, args.weighting,
                ctrl.k, args.target_latency)
    t0 = time.perf_counter()
    out = serve_stream(ctrl, gen, max_rounds=args.rounds,
                       max_events=args.max_events, max_time=args.max_time,
                       round_hook=round_hook)
    dt = time.perf_counter() - t0
    out["seconds"] = dt
    out["uploads_per_sec"] = out["folded"] / dt if dt > 0 else 0.0

    logger.info("%d rounds / %d uploads folded in %.2fs -> %.1f uploads/s",
                out["rounds"], out["folded"], dt, out["uploads_per_sec"])
    logger.info("round latency p50=%.3fs p99=%.3fs (sim), cadence "
                "mean=%.3fs, arrival rate=%.2f/s, K -> %d",
                out["round_latency_p50"], out["round_latency_p99"],
                out["round_cadence_mean"], out["arrival_rate"], out["k"])
    logger.info("admission: admitted=%d queue_full=%d stale_ingress=%d "
                "stale_queue=%d lost=%d retries=%d queue_depth_max=%d",
                out["admitted"], out["rejected_queue_full"],
                out["dropped_stale_ingress"], out["dropped_stale_queue"],
                out["lost_in_transit"], out["retries_scheduled"],
                out["queue_depth_max"])

    profiler.close()
    if sink is not None:
        emit_snapshot(sink, registry, version=ctrl.version, final=True)
        sink.close()
        logger.info("metrics JSONL -> %s", args.metrics_out)
    if args.trace_out:
        tracer.write(args.trace_out)
        logger.info("chrome trace (%d events) -> %s", len(tracer.events),
                    args.trace_out)
    if args.json:
        print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()

"""Always-on FL serving launcher: continuous-arrival aggregation rounds.

Runs the ``core/serving.py`` controller as a long-lived endpoint with a
``sim/`` scenario acting as the in-process traffic generator: client
uploads arrive on the scenario's seeded per-client timelines, pass
admission control (bounded ingress queue, staleness drops, queue-full
backpressure with retry-after), and are folded through the streaming
round body; the adaptive controller tunes buffer size K to the observed
arrival rate to hold round cadence near ``--target-latency``.

Everything is in-process and deterministic under ``--seed`` — no sockets
— so the same entry point doubles as the CI serving smoke lane.

Example:
  PYTHONPATH=src python -m repro.launch.serve_fl --scenario paper-fig1 \
      --clients 32 --rounds 20 --weighting fedasync_hinge --json
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs.base import FLConfig
from repro.core.serving import ServeConfig, ServingController, serve_stream
from repro.models.lenet import init_lenet, lenet_loss
from repro.sim import get_scenario
from repro.sim.arrivals import TrafficGenerator


def log(msg: str) -> None:
    print(msg, flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="paper-fig1")
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--samples-per-client", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--weighting", default="paper")
    ap.add_argument("--buffer-k", type=int, default=8,
                    help="initial K (the adaptive controller moves it)")
    ap.add_argument("--max-staleness", type=int, default=12)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    # serving knobs
    ap.add_argument("--queue-capacity", type=int, default=64)
    ap.add_argument("--service-time", type=float, default=0.0,
                    help="modeled sim-time to fold one upload (0 = free)")
    ap.add_argument("--target-latency", type=float, default=2.0)
    ap.add_argument("--k-min", type=int, default=2)
    ap.add_argument("--k-max", type=int, default=64)
    ap.add_argument("--adapt-every", type=int, default=4,
                    help="rounds between K adjustments (0 = fixed K)")
    # run bounds (a service has no natural end; at least one must bind)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--max-events", type=int, default=None)
    ap.add_argument("--max-time", type=float, default=None,
                    help="sim-time horizon")
    ap.add_argument("--json", action="store_true",
                    help="dump the full metrics dict as JSON")
    args = ap.parse_args()

    fl = FLConfig(num_clients=args.clients, buffer_size=args.buffer_k,
                  max_staleness=args.max_staleness,
                  local_steps=args.local_steps, batch_size=args.batch,
                  weighting=args.weighting)
    cfg = ServeConfig(queue_capacity=args.queue_capacity,
                      service_time=args.service_time,
                      target_round_latency=args.target_latency,
                      k_min=args.k_min, k_max=args.k_max,
                      adapt_every=args.adapt_every)
    sc = get_scenario(args.scenario)
    clients, _ = sc.make_dataset(args.clients,
                                 samples_per_client=args.samples_per_client,
                                 seed=args.seed)
    behavior = sc.behavior(args.clients, seed=args.seed)

    params = init_lenet(jax.random.PRNGKey(args.seed))
    ctrl = ServingController(lenet_loss, params, fl, cfg)
    gen = TrafficGenerator(clients, behavior, fl)

    log(f"serving scenario={sc.name} clients={args.clients} "
        f"weighting={args.weighting} K0={ctrl.k} "
        f"target_latency={args.target_latency}")
    t0 = time.perf_counter()
    out = serve_stream(ctrl, gen, max_rounds=args.rounds,
                       max_events=args.max_events, max_time=args.max_time)
    dt = time.perf_counter() - t0
    out["seconds"] = dt
    out["uploads_per_sec"] = out["folded"] / dt if dt > 0 else 0.0

    log(f"{out['rounds']} rounds / {out['folded']} uploads folded in "
        f"{dt:.2f}s -> {out['uploads_per_sec']:.1f} uploads/s")
    log(f"round latency p50={out['round_latency_p50']:.3f}s "
        f"p99={out['round_latency_p99']:.3f}s (sim), "
        f"cadence mean={out['round_cadence_mean']:.3f}s, "
        f"arrival rate={out['arrival_rate']:.2f}/s, K -> {out['k']}")
    log(f"admission: admitted={out['admitted']} "
        f"queue_full={out['rejected_queue_full']} "
        f"stale_ingress={out['dropped_stale_ingress']} "
        f"stale_queue={out['dropped_stale_queue']} "
        f"lost={out['lost_in_transit']} retries={out['retries_scheduled']} "
        f"queue_depth_max={out['queue_depth_max']}")
    if args.json:
        print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()

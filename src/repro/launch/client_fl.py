"""FL client launcher: one real client against a serve_fl transport.

The socket-side half of the loopback smoke (DESIGN.md §12): builds the
SAME seeded scenario datasets as the server (``--scenario/--seed`` must
match), picks its ``--cid`` slice, connects a ``RemoteAggregator`` over
tcp or http, and runs the ``transport.client.run_client`` lifecycle —
pull, draw the seeded local round, offer, honor queue-full
``retry_after`` hints by re-offering the SAME (now staler) upload,
re-pull after every admit/stale-drop. Connection loss is retried with
jittered exponential backoff, so the client survives a server that
comes up late or restarts.

Exits once ``--uploads`` draws are spent, the pulled version reaches
``--stop-at-version``, or ``--max-wall-time`` elapses — whichever is
first. Prints its ledger (drawn/admitted/retries/dropped_stale/
reconnects) as JSON on stdout.

Example (against serve_fl --transport tcp --port-file /tmp/port):
  PYTHONPATH=src python -m repro.launch.client_fl --port-file /tmp/port \
      --cid 3 --uploads 16 --stop-at-version 4
"""
from __future__ import annotations

import argparse
import json
import logging
import os
import time

from repro.configs.base import FLConfig
from repro.launch.cli import ObsStack, add_obs_flags, add_scenario_flags
from repro.sim import get_scenario
from repro.transport.client import RemoteAggregator, run_client

logger = logging.getLogger("repro.launch.client_fl")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    add_scenario_flags(ap)
    ap.add_argument("--cid", type=int, required=True,
                    help="this client's index into the scenario population")
    # local-round shape: MUST match the server's flags for parity
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    # endpoint
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--port-file", default=None,
                    help="poll this file for the server's bound port "
                         "(serve_fl --port-file); overrides --port")
    ap.add_argument("--port-wait", type=float, default=30.0,
                    help="seconds to wait for --port-file to appear")
    ap.add_argument("--transport", default="tcp", choices=("tcp", "http"))
    ap.add_argument("--wire-codec", default="f32",
                    choices=("f32", "int8"),
                    help="upload payload codec (f32 = bit-exact parity; "
                         "int8 = per-block affine, ~4x smaller)")
    # lifecycle
    ap.add_argument("--uploads", type=int, default=16,
                    help="max local rounds to draw")
    ap.add_argument("--stop-at-version", type=int, default=0,
                    help="exit once the pulled model reaches this version "
                         "(0 = never; set to the server's --rounds)")
    ap.add_argument("--think-time", type=float, default=0.0,
                    help="modeled local-training wall time per round")
    ap.add_argument("--max-wall-time", type=float, default=0.0)
    # reconnect budget
    ap.add_argument("--max-retries", type=int, default=8)
    ap.add_argument("--backoff-base", type=float, default=0.05)
    ap.add_argument("--backoff-cap", type=float, default=2.0)
    add_obs_flags(ap)
    return ap


def _resolve_port(args) -> int:
    if not args.port_file:
        if not args.port:
            raise SystemExit("need --port or --port-file")
        return args.port
    deadline = time.monotonic() + args.port_wait
    while time.monotonic() < deadline:
        if os.path.exists(args.port_file):
            text = open(args.port_file).read().strip()
            if text:
                return int(text)
        time.sleep(0.05)
    raise SystemExit(f"--port-file {args.port_file} did not appear within "
                     f"{args.port_wait}s")


def main() -> None:
    args = build_parser().parse_args()
    obs = ObsStack.from_args(args)

    fl = FLConfig(num_clients=args.clients, local_steps=args.local_steps,
                  batch_size=args.batch)
    sc = get_scenario(args.scenario)
    clients, _ = sc.make_dataset(args.clients,
                                 samples_per_client=args.samples_per_client,
                                 seed=args.seed)
    if not 0 <= args.cid < len(clients):
        raise SystemExit(f"--cid {args.cid} outside population "
                         f"[0, {len(clients)})")

    port = _resolve_port(args)
    svc = RemoteAggregator(args.host, port, transport=args.transport,
                           codec=args.wire_codec,
                           max_retries=args.max_retries,
                           backoff_base=args.backoff_base,
                           backoff_cap=args.backoff_cap,
                           seed=args.seed)
    logger.info("client %d -> %s://%s:%d (codec=%s, uploads<=%d)",
                args.cid, args.transport, args.host, port,
                args.wire_codec, args.uploads)
    try:
        stats = run_client(svc, clients[args.cid], args.cid, fl,
                           uploads=args.uploads,
                           stop_at_version=args.stop_at_version,
                           think_time=args.think_time,
                           max_wall_time=args.max_wall_time,
                           seed=args.seed)
    finally:
        svc.close()
    stats["cid"] = args.cid
    stats["reconnects"] = svc.reconnects
    for k, v in stats.items():
        obs.registry.gauge("client_" + k, cid=args.cid).set(float(v))
    obs.finish(0)
    print(json.dumps(stats))


if __name__ == "__main__":
    main()

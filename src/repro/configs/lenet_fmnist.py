"""LeNet on (synthetic) Fashion-MNIST — the paper's own experiment backbone.

Not part of the assigned pool; used by the paper-reproduction benchmark
(Fig. 1) and the FL examples.
"""
from repro.configs.base import ArchConfig, FLConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="lenet",
        family="vision",
        num_layers=0,
        d_model=0,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=10,  # num classes
    ),
    source="[paper §5: LeNet on Fashion-MNIST, 30 clients x 1500]",
    notes="Conv(6,5x5)-pool-Conv(16,5x5)-pool-FC120-FC84-FC10.",
    skip_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

PAPER_FL = FLConfig(
    num_clients=30,
    buffer_size=10,
    local_steps=4,
    local_lr=0.05,
    batch_size=32,
    weighting="paper",
)

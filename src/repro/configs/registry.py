"""Architecture registry: ``get_arch(id)`` / ``list_archs()``.

IDs match the assignment table exactly (``--arch <id>`` in launchers).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

_MODULES: Dict[str, str] = {
    "stablelm-12b": "repro.configs.stablelm_12b",
    "arctic-480b": "repro.configs.arctic_480b",
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "qwen1.5-110b": "repro.configs.qwen15_110b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "gemma-7b": "repro.configs.gemma_7b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "qwen3-1.7b": "repro.configs.qwen3_1p7b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    # paper's own experiment backbone (not in the assigned pool)
    "lenet": "repro.configs.lenet_fmnist",
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "lenet"]


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(ASSIGNED_ARCHS)

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    ArchConfig,
    FLConfig,
    ModelConfig,
    ShapeConfig,
    smoke_variant,
)
from repro.configs.registry import ASSIGNED_ARCHS, get_arch, list_archs  # noqa: F401

"""Qwen3-1.7B — dense decoder with QK-norm, GQA kv=8. [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="qwen3-1.7b",
        family="dense",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=6144,
        vocab_size=151936,
        activation="swiglu",
        qk_norm=True,
        rope_theta=1000000.0,
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    ),
    source="[hf:Qwen/Qwen3-8B]",
    notes="Per-head RMSNorm on q and k before RoPE.",
    long_context_window=4096,
)

"""Snowflake Arctic 480B — dense-MoE hybrid: 128 experts top-2 with a dense
residual branch in parallel. [hf:Snowflake/snowflake-arctic-base]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        activation="swiglu",
        num_experts=128,
        experts_per_token=2,
        moe_d_ff=4864,
        dense_residual_ff=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    ),
    source="[hf:Snowflake/snowflake-arctic-base]",
    notes="Dense-MoE hybrid residual: dense FFN (d_ff=4864) parallel to "
          "128-expert top-2 MoE in every layer; experts expert-parallel "
          "over the model axis.",
    long_context_window=4096,
    fl_mode="distributed",  # 960 GB of bf16 params: a client spans the mesh
)

"""Qwen1.5-110B — dense decoder with QKV bias, GQA kv=8.
[hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=49152,
        vocab_size=152064,
        activation="swiglu",
        qkv_bias=True,
        rope_theta=1000000.0,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    ),
    source="[hf:Qwen/Qwen1.5-0.5B]",
    notes="Largest dense arch in the pool; FSDP over the data axis is "
          "required to fit v5e HBM.",
    long_context_window=4096,
    fl_mode="distributed",  # 220 GB of bf16 params: a client spans the mesh
)

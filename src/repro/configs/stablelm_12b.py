"""StableLM-2-12B — dense decoder, GQA kv=8. [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="stablelm-12b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_ff=13824,
        vocab_size=100352,
        activation="swiglu",
        norm="layernorm",
        rope_theta=10000.0,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    ),
    source="[hf:stabilityai/stablelm-2-1_6b]",
    notes="Dense decoder; parallel attention/MLP omitted (sequential blocks).",
    long_context_window=4096,  # long_500k runs as SWA variant
)

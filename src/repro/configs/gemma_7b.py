"""Gemma-7B — dense decoder, GeGLU, head_dim=256, 16 heads / 16 kv heads
(MHA; the 2B variant uses MQA). [arXiv:2403.08295]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="gemma-7b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=16,
        num_kv_heads=16,
        head_dim=256,  # head_dim * heads = 4096 != d_model
        d_ff=24576,
        vocab_size=256000,
        activation="geglu",
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    ),
    source="[arXiv:2403.08295]",
    notes="GeGLU MLP, embedding-scaled inputs, tied softmax/embedding. "
          "256k vocab makes the embedding/LM head the sharding hot-spot.",
    long_context_window=4096,
)

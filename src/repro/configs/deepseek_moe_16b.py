"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed experts, top-6,
per-expert d_ff=1408. [arXiv:2401.06066]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        activation="swiglu",
        num_experts=64,
        experts_per_token=6,
        num_shared_experts=2,
        moe_d_ff=1408,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    ),
    source="[arXiv:2401.06066]",
    notes="Fine-grained expert segmentation; shared experts always active. "
          "Deviation: the released model's first layer is dense — we use "
          "MoE in all layers for scan-over-layers homogeneity (protocol- "
          "irrelevant; recorded).",
    long_context_window=4096,
)

"""Whisper-tiny — encoder-decoder with conv/mel frontend (STUBBED per
assignment): input_specs() provides precomputed frame embeddings.
[arXiv:2212.04356]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="whisper-tiny",
        family="audio",
        num_layers=4,  # decoder layers
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        activation="gelu",
        norm="layernorm",
        rope_theta=0.0,  # learned absolute positions, no RoPE
        encoder_layers=4,
        encoder_seq_len=1500,  # 30s audio -> 1500 frames post-conv
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    ),
    source="[arXiv:2212.04356]",
    notes="Mel-spectrogram + conv feature extractor stubbed: encoder "
          "consumes (B, 1500, 384) frame embeddings. decode_32k lowers "
          "(self-attn KV ring + cross-attn cache).",
    skip_shapes=("long_500k",),  # full attention, 448-token trained context;
    # no faithful sub-quadratic variant — recorded in DESIGN.md.
)

"""Pixtral-12B — VLM: pixtral-ViT frontend (STUBBED per assignment) feeding
a mistral-nemo-style dense decoder. [hf:mistralai/Pixtral-12B-2409]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="pixtral-12b",
        family="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,  # mistral-nemo: head_dim 128 != d_model/32=160
        d_ff=14336,
        vocab_size=131072,
        activation="swiglu",
        rope_theta=1000000.0,
        num_patches=256,  # stub vision frontend emits 256 patch embeddings
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    ),
    source="[hf:mistralai/Pixtral-12B-2409]",
    notes="Vision encoder + projector stubbed: input_specs() provides "
          "precomputed patch embeddings (B, 256, d_model) prepended to the "
          "token stream. Decoder is the trainable backbone.",
    long_context_window=4096,
)

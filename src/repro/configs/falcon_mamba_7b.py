"""Falcon-Mamba-7B — pure Mamba-1 SSM, attention-free. [arXiv:2410.05355]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        num_layers=64,
        d_model=4096,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,  # mamba block subsumes the MLP (expand=2)
        vocab_size=65024,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    ),
    source="[arXiv:2410.05355]",
    notes="Attention-free; O(1) decode state => long_500k runs natively. "
          "CA-AFL applies unchanged (protocol is architecture-agnostic); "
          "the attention-sharding aspect of other papers is moot here — "
          "see DESIGN.md §Arch-applicability.",
)

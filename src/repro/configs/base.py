"""Config dataclasses for models, input shapes, FL, and launches.

Every assigned architecture gets one module in this package defining
``CONFIG: ArchConfig`` with the exact published dimensions (citation in
``source``). ``smoke_variant()`` derives the reduced CPU-testable config
(<=2 layers, d_model<=512, <=4 experts) from the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    activation: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- attention variant ---
    attn_window: Optional[int] = None  # sliding-window size; None = full causal
    force_chunked_attn: bool = False  # perf: chunked online-softmax even at
    # short seq (no (S,S) score materialisation; see EXPERIMENTS.md §Perf)
    ce_chunk: int = 0  # perf: cross-entropy in token chunks — the (T, V)
    # logits tensor is never materialised (head matmul fused per chunk)
    remat_block: int = 0  # perf: sqrt-remat — checkpoint every Nth layer
    # boundary instead of every layer (L/N saved carries + N transient)
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: Optional[int] = None  # per-expert hidden size (defaults to d_ff)
    dense_residual_ff: bool = False  # arctic: dense FFN in parallel with MoE
    router_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25
    moe_groups: int = 1  # dispatch groups: sort/scatter stay LOCAL to each
    # group (group = data shard) so SPMD never re-replicates the token
    # tensor — see EXPERIMENTS.md §Perf (arctic iteration 2)
    # --- SSM (mamba-1) ---
    ssm_state: int = 0  # N (state size); 0 = no ssm
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: Optional[int] = None  # default ceil(d_model / 16)
    # --- hybrid ---
    parallel_ssm_attn: bool = False  # hymba: attn and mamba heads in parallel
    # --- encoder/decoder (audio) ---
    encoder_layers: int = 0  # >0 => enc-dec with cross attention
    encoder_seq_len: int = 0  # stubbed frontend output frames
    # --- vlm ---
    num_patches: int = 0  # stubbed vision frontend output patches
    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.ssm_state > 0 and self.num_heads == 0

    @property
    def is_hybrid(self) -> bool:
        return self.parallel_ssm_attn

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        if self.ssm_dt_rank:
            return self.ssm_dt_rank
        return max(1, (self.d_model + 15) // 16)

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff else self.d_ff

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# FL / training configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FLConfig:
    """Contribution-aware asynchronous FL hyper-parameters (the paper)."""

    num_clients: int = 30
    buffer_size: int = 10  # K — server aggregates once K updates arrive
    local_steps: int = 4  # M local SGD steps per upload
    local_lr: float = 0.05
    local_momentum: float = 0.0
    global_lr: float = 1.0  # eta_g
    batch_size: int = 32
    clients_per_round: int = 0  # sync FedAvg participation; 0 = all N
    weighting: str = "paper"  # paper | multiplicative | fedbuff | polynomial
    # | fedasync | fedasync_{constant,hinge,poly} (core/weighting.POLICIES)
    normalize: str = "mean"  # mean | none
    s_min: float = 1e-3  # floor on S_i for the paper's division (numerics)
    poly_a: float = 0.5  # exponent for the polynomial staleness discount
    hinge_a: float = 10.0  # fedasync_hinge slope (FLGo default)
    hinge_b: float = 6.0  # fedasync_hinge knee: s(tau)=1 while tau <= b
    staleness_mode: str = "model_diff"  # model_diff (eq.3) | rounds
    max_staleness: int = 32  # ring-buffer depth for version tracking
    seed: int = 0
    # perf knobs (EXPERIMENTS.md §Perf)
    accum_dtype: str = "float32"  # distributed-mode delta accumulator dtype
    probe_batch: int = 4  # eq.-4 probe sequences per data-parallel group
    # device-resident server pass (DESIGN.md §3): auto picks the fused
    # Pallas kernel on TPU and the pure-jnp reference body elsewhere
    server_pass_mode: str = "auto"  # auto | reference | batched | fused
    server_pass_block_n: int = 0  # kernel N-tile; 0 = auto (lane-aligned)
    # compressed version store (core/version_store.py, DESIGN.md §11)
    ring_codec: str = "f32"  # f32 | int8 | delta (version_store.CODECS)
    ring_qblock: int = 256  # int8: params per affine quantization block
    ring_delta_density: float = 0.05  # delta: kept residual fraction of Np
    ring_base_refresh: int = 0  # delta: ring writes between base-snapshot
    # refreshes; 0 = every R = max_staleness + 1 writes (one ring lap)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    model: ModelConfig
    source: str  # citation: [hf:...] or [arXiv:...]
    notes: str = ""
    # shapes this arch skips, with reasons (recorded in DESIGN.md too)
    skip_shapes: Tuple[str, ...] = ()
    # per-shape model overrides, e.g. long_500k -> sliding window variant
    long_context_window: Optional[int] = None  # if set, long_500k uses SWA
    # FL deployment mapping (DESIGN.md §2.1): "replicated" = one client per
    # data-axis group (exact eq.-3 staleness); "distributed" = one client
    # spans the mesh (FSDP x TP), K-buffer fills sequentially.
    fl_mode: str = "replicated"


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    d_model = min(cfg.d_model, 128)
    num_heads = min(cfg.num_heads, 4) or 0
    head_dim = None
    if cfg.num_heads:
        # keep any special head_dim relation (e.g. gemma 256 > d_model/H)
        head_dim = 32 if cfg.resolved_head_dim != cfg.d_model // cfg.num_heads else None
    num_kv = min(cfg.num_kv_heads, num_heads) if num_heads else 0
    if num_heads and num_kv and num_heads % num_kv:
        num_kv = 1
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.is_moe:
        kw.update(
            num_experts=min(cfg.num_experts, 4),
            experts_per_token=min(cfg.experts_per_token, 2),
            num_shared_experts=min(cfg.num_shared_experts, 1),
            moe_d_ff=min(cfg.resolved_moe_d_ff, 128),
            moe_capacity_factor=8.0,  # dropless in smoke: decode == forward
        )
    if cfg.encoder_layers:
        kw.update(encoder_layers=1, encoder_seq_len=min(cfg.encoder_seq_len, 64))
    if cfg.num_patches:
        kw.update(num_patches=min(cfg.num_patches, 16))
    if cfg.attn_window:
        kw.update(attn_window=min(cfg.attn_window, 32))
    return cfg.replace(**kw)

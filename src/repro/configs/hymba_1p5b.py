"""NVIDIA Hymba-1.5B — hybrid-head: attention and mamba heads run in
parallel within every layer and their (normalized) outputs are fused.
[arXiv:2411.13676]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        d_ff=5504,
        vocab_size=32001,
        activation="swiglu",
        ssm_state=16,
        parallel_ssm_attn=True,
        attn_window=1024,  # hymba uses SWA on most layers; global layers omitted
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    ),
    source="[arXiv:2411.13676]",
    notes="Parallel attn+mamba heads fused by mean of per-branch RMSNorm; "
          "meta-tokens from the paper omitted (orthogonal to this repro). "
          "Sub-quadratic natively (SWA + SSM) => long_500k runs as-is.",
)

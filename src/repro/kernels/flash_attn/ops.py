"""Jit'd wrapper: (B, S, H, D) layout, kernel-vs-oracle switch, padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn import kernel as _k
from repro.kernels.flash_attn import ref as _ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "use_kernel",
                                             "interpret", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    use_kernel: bool = True, interpret: bool = True,
                    block_q: int = 128, block_k: int = 128):
    """q, k, v: (B, S, H, D) (H already GQA-expanded). Returns (B, S, H, D)."""
    b, s, h, d = q.shape

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], d)

    qf, kf, vf = to_bh(q), to_bh(k), to_bh(v)
    if not use_kernel:
        out = _ref.attention_ref(qf, kf, vf, causal=causal, window=window)
    else:
        bq = min(block_q, s)
        bk = min(block_k, kf.shape[1])
        pad_q = (-s) % bq
        pad_k = (-kf.shape[1]) % bk
        if pad_k:
            assert causal, "kv padding requires a causal mask to stay exact"
        if pad_q or pad_k:
            # pad kv with fully-masked positions (kpos >= original length is
            # never attended because q rows are causal and padded q dropped)
            qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
            kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
            vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
        out = _k.flash_attention_pallas(qf, kf, vf, causal=causal,
                                        window=window, block_q=bq, block_k=bk,
                                        interpret=interpret)
        out = out[:, :s]
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)

"""Pallas TPU flash attention (online-softmax, causal / sliding-window).

TPU adaptation of the FlashAttention idea: instead of GPU shared-memory
tiles + warp shuffles, the kernel streams lane-aligned (block_q x head_dim)
and (block_k x head_dim) tiles through VMEM and keeps the online-softmax
accumulators (acc, running max m, running sum l) in VMEM scratch that
persists across the sequential kv grid dimension (TPU grids execute the
minor dimension innermost and in order — the scratch-carry replaces the
GPU's per-CTA loop). Matmul tiles are multiples of (8, 128) so the MXU is
fed at full occupancy; masking is positional arithmetic, no materialised
(S, S) score matrix ever exists in HBM.

TARGET: TPU (Mosaic). VALIDATION: interpret=True on CPU vs ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, num_kv_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, D)
    k = k_ref[0].astype(jnp.float32)  # (bk, D)
    v = v_ref[0].astype(jnp.float32)  # (bk, D)
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    qi = pl.program_id(1)
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q, k, v: (BH, S, D). Returns (BH, S, D). S divisible by blocks."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = d ** -0.5
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk)
    return pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),  # acc
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),  # running sum l
        ],
        interpret=interpret,
    )(q, k, v)

"""Pure-jnp oracle for flash attention (materialised softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q, k, v: (BH, S, D) -> (BH, S, D)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    qpos = jnp.arange(q.shape[1])[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones(s.shape[1:], dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)

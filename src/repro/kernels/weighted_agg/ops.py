"""Jit'd public wrappers: pad to lane-aligned tiles, pick kernel vs oracle."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.weighted_agg import kernel as _k
from repro.kernels.weighted_agg import ref as _ref


def _pad_to(n: int, block: int) -> int:
    return (n + block - 1) // block * block


def _pick_block(n: int) -> int:
    """Largest lane-aligned tile <= DEFAULT that keeps padding waste small."""
    if n >= _k.DEFAULT_BLOCK_N:
        return _k.DEFAULT_BLOCK_N
    return max(_k.LANE, _pad_to(n, _k.LANE) // max(1, _pad_to(n, _k.LANE) // 2048))


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def weighted_sum(deltas, weights, use_kernel: bool = True, interpret: bool = True):
    """deltas: (K, N), weights: (K,) -> (N,) = sum_k w_k * deltas_k."""
    deltas = deltas.astype(jnp.float32)
    weights = weights.astype(jnp.float32)
    if not use_kernel:
        return _ref.weighted_sum_ref(deltas, weights)
    k, n = deltas.shape
    block = _pick_block(n)
    npad = _pad_to(n, block)
    if npad != n:
        deltas = jnp.pad(deltas, ((0, 0), (0, npad - n)))
    out = _k.weighted_sum_pallas(deltas, weights, block_n=block,
                                 interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def sq_dists(x, bases, use_kernel: bool = True, interpret: bool = True):
    """x: (N,), bases: (K, N) -> (K,) squared distances ||x - base_k||^2."""
    x = x.astype(jnp.float32)
    bases = bases.astype(jnp.float32)
    if not use_kernel:
        return _ref.sq_dists_ref(x, bases)
    k, n = bases.shape
    block = _pick_block(n)
    npad = _pad_to(n, block)
    if npad != n:
        x = jnp.pad(x, (0, npad - n))
        bases = jnp.pad(bases, ((0, 0), (0, npad - n)))
    return _k.sq_dists_pallas(x, bases, block_n=block, interpret=interpret)

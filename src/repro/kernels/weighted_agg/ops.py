"""Jit'd public wrappers: pad to lane-aligned tiles, pick kernel vs oracle."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.weighted_agg import kernel as _k
from repro.kernels.weighted_agg import ref as _ref


def pad_to(n: int, block: int) -> int:
    return (n + block - 1) // block * block


def pick_block(n: int) -> int:
    """Lane-aligned tile that always divides the padded length.

    n >= DEFAULT_BLOCK_N: use the default tile (padding waste < one tile).
    n <  DEFAULT_BLOCK_N: a single lane-padded tile (grid of 1).
    """
    if n >= _k.DEFAULT_BLOCK_N:
        return _k.DEFAULT_BLOCK_N
    return max(_k.LANE, pad_to(n, _k.LANE))


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def weighted_sum(deltas, weights, use_kernel: bool = True, interpret: bool = True):
    """deltas: (K, N), weights: (K,) -> (N,) = sum_k w_k * deltas_k."""
    deltas = deltas.astype(jnp.float32)
    weights = weights.astype(jnp.float32)
    if not use_kernel:
        return _ref.weighted_sum_ref(deltas, weights)
    k, n = deltas.shape
    block = pick_block(n)
    npad = pad_to(n, block)
    if npad != n:
        deltas = jnp.pad(deltas, ((0, 0), (0, npad - n)))
    out = _k.weighted_sum_pallas(deltas, weights, block_n=block,
                                 interpret=interpret)
    return out[:n]


def server_update(x, bases, deltas, p_stat, taus, arrival_mask=None, *,
                  policy: str = "paper", eta_g: float = 1.0,
                  s_min: float = 1e-3, poly_a: float = 0.5,
                  hinge_a: float = 10.0, hinge_b: float = 6.0,
                  normalize: str = "mean", block_n: int = 0,
                  interpret: bool = False):
    """Fused single-launch server pass (eq. 3 + weighting + eq. 5).

    x: (N,), bases/deltas: (K, N), p_stat/taus: (K,). Pads N to a lane
    multiple with zeros (distance- and sum-neutral) and slices back.
    Returns (upd (N,), sq_dists (K,), weights (K,)); ``upd`` carries the
    eta_g / k_eff scale of eq. 5 so ``x_new = x - upd``.
    """
    x = x.astype(jnp.float32)
    bases = bases.astype(jnp.float32)
    deltas = deltas.astype(jnp.float32)
    k, n = bases.shape
    if arrival_mask is None:
        arrival_mask = jnp.ones((k,), jnp.float32)
    block = block_n or pick_block(n)
    npad = pad_to(n, block)
    if npad != n:
        x = jnp.pad(x, (0, npad - n))
        bases = jnp.pad(bases, ((0, 0), (0, npad - n)))
        deltas = jnp.pad(deltas, ((0, 0), (0, npad - n)))
    upd, dists, w = _k.fused_server_pallas(
        x, bases, deltas, p_stat, taus, arrival_mask, policy=policy,
        eta_g=eta_g, s_min=s_min, poly_a=poly_a, hinge_a=hinge_a,
        hinge_b=hinge_b, normalize=normalize, block_n=block,
        interpret=interpret)
    return upd[:n], dists, w


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def sq_dists(x, bases, use_kernel: bool = True, interpret: bool = True):
    """x: (N,), bases: (K, N) -> (K,) squared distances ||x - base_k||^2."""
    x = x.astype(jnp.float32)
    bases = bases.astype(jnp.float32)
    if not use_kernel:
        return _ref.sq_dists_ref(x, bases)
    k, n = bases.shape
    block = pick_block(n)
    npad = pad_to(n, block)
    if npad != n:
        x = jnp.pad(x, (0, npad - n))
        bases = jnp.pad(bases, ((0, 0), (0, npad - n)))
    return _k.sq_dists_pallas(x, bases, block_n=block, interpret=interpret)

from repro.kernels.weighted_agg.ops import sq_dists, weighted_sum  # noqa: F401

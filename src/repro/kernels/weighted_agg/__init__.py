from repro.kernels.weighted_agg.ops import server_update, sq_dists, weighted_sum  # noqa: F401

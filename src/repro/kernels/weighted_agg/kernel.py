"""Pallas TPU kernels for the CA-AFL server hot loop.

The server pass over a model of N params with K buffered updates is
memory-bound streaming: for each parameter tile it must
  (a) reduce K client deltas with contribution weights (eq. 5), and
  (b) accumulate per-client squared distances ||x - base_i||^2 (eq. 3).

``fused_server_pallas`` does (a) and (b) plus the weighting policy in a
single two-phase launch (see its docstring); the two single-purpose
kernels below remain as the batched mode and the building blocks.

All kernels tile the flattened parameter axis into VMEM-resident blocks
(lane-aligned multiples of 128; K rides the sublane dimension), so one HBM
pass per tile feeds the VPU — on TPU the arithmetic intensity is K flops
per 4*K bytes loaded, i.e. firmly bandwidth-bound, and fusing the weighting
into the reduction avoids materialising weighted deltas in HBM (which is
what a naive jnp einsum would do between two kernels).

TARGET: TPU (Mosaic). VALIDATION: interpret=True on CPU (tests sweep
shapes/dtypes against ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_N = 16 * 1024  # f32 tile of (K<=32, 16k) stays well under VMEM


def _weighted_sum_kernel(d_ref, w_ref, o_ref):
    """o[n] = sum_k w[k] * d[k, n] for one N-tile. d:(K,bn) w:(K,1) o:(bn,)."""
    d = d_ref[...]  # (K, bn)
    w = w_ref[...]  # (K, 1)
    o_ref[...] = jnp.sum(d * w, axis=0)


def weighted_sum_pallas(deltas: jnp.ndarray, weights: jnp.ndarray,
                        block_n: int = DEFAULT_BLOCK_N,
                        interpret: bool = False) -> jnp.ndarray:
    """deltas: (K, N) f32, weights: (K,) f32 -> (N,) f32. N % block_n == 0."""
    k, n = deltas.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _weighted_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(deltas, weights.reshape(k, 1))


def _fused_server_kernel(x_ref, b_ref, d_ref, p_ref, tau_ref, m_ref,
                         upd_ref, dist_ref, w_ref, *,
                         policy: str, eta_g: float, s_min: float,
                         poly_a: float, hinge_a: float, hinge_b: float,
                         normalize: str, eps: float):
    """Whole eq. 3 + weighting + eq. 5 server reduction in ONE kernel.

    Two-phase sequential grid (ph, i) with ph in {0, 1}, i over N-tiles:
      phase 0  accumulates per-client ||x - base_k||^2 into the resident
               (K, 1) dist block (bases stream through VMEM once);
      boundary (ph=1, i=0) turns distances into eq.-3 staleness degrees,
               applies the weighting policy + mean normalisation in-VMEM
               (a K-vector — no host round-trip, no second kernel launch);
      phase 1  streams the deltas once, reducing sum_k w_k * d[k, tile]
               scaled by eta_g / k_eff straight into the output tiles.

    Index maps park the inactive operand on block 0 during the other
    phase, so bases and deltas are each read from HBM exactly once.
    x:(1,bn) b:(K,bn) d:(K,bn) p/tau/m:(K,1) -> upd:(bn,) dist/w:(K,1).
    """
    ph = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(jnp.logical_and(ph == 0, i == 0))
    def _init():
        dist_ref[...] = jnp.zeros_like(dist_ref)
        w_ref[...] = jnp.zeros_like(w_ref)

    @pl.when(ph == 0)
    def _accum_dists():
        diff = b_ref[...] - x_ref[...]  # (K, bn), broadcast over clients
        dist_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)
        # phase-0 out index is parked on tile 0; keep it defined (it is
        # overwritten by the real reduction at (ph=1, i=0) before flush).
        upd_ref[...] = jnp.zeros_like(upd_ref)

    @pl.when(jnp.logical_and(ph == 1, i == 0))
    def _weights():
        # eq. 3 — staleness degree: min reference over ARRIVED slots only
        # (absent slots park on max(d)); mirrors core/weighting.py exactly
        d = jnp.maximum(dist_ref[...], 0.0)  # (K, 1)
        mn = jnp.min(jnp.where(m_ref[...] > 0, d, jnp.max(d)))
        s = jnp.clip((mn + eps) / (d + eps), 0.0, 1.0)
        p = p_ref[...]
        if policy == "paper":
            w = p / jnp.maximum(s, s_min)
        elif policy == "multiplicative":
            w = p * s
        elif policy in ("fedbuff", "fedasync_constant"):
            w = jnp.ones_like(p)
        elif policy == "fedasync_hinge":
            t = tau_ref[...]
            w = jnp.where(t <= hinge_b, jnp.ones_like(t),
                          1.0 / jnp.maximum(hinge_a * (t - hinge_b), 1e-12))
        else:  # polynomial / fedasync / fedasync_poly
            w = (1.0 + tau_ref[...]) ** (-poly_a)
        mask = m_ref[...]
        w = w * mask
        if normalize == "mean":
            denom_n = jnp.maximum(jnp.sum(mask), 1.0)
            w = w * denom_n / jnp.maximum(jnp.sum(w), 1e-12)
        w_ref[...] = w

    @pl.when(ph == 1)
    def _reduce():
        k_eff = jnp.maximum(jnp.sum(m_ref[...]), 1.0)
        scale = eta_g / k_eff
        upd_ref[...] = jnp.sum(d_ref[...] * (w_ref[...] * scale), axis=0)


def fused_server_pallas(x: jnp.ndarray, bases: jnp.ndarray,
                        deltas: jnp.ndarray, p_stat: jnp.ndarray,
                        taus: jnp.ndarray, arrival_mask: jnp.ndarray,
                        *, policy: str = "paper", eta_g: float = 1.0,
                        s_min: float = 1e-3, poly_a: float = 0.5,
                        hinge_a: float = 10.0, hinge_b: float = 6.0,
                        normalize: str = "mean", eps: float = 1e-12,
                        block_n: int = DEFAULT_BLOCK_N,
                        interpret: bool = False):
    """One-launch server pass. x:(N,), bases/deltas:(K,N) f32; the rest (K,).

    Returns (upd (N,), sq_dists (K,), weights (K,)) where
    upd = (eta_g / k_eff) * sum_k w_k * deltas[k] already carries eq. 5's
    scale. N % block_n == 0 (use the ops wrapper for padding).
    """
    if policy not in ("paper", "multiplicative", "fedbuff", "polynomial",
                      "fedasync", "fedasync_constant", "fedasync_hinge",
                      "fedasync_poly"):
        raise ValueError(f"unknown policy {policy!r}")
    if normalize not in ("mean", "none"):
        raise ValueError(f"unknown normalize {normalize!r}")
    k, n = bases.shape
    assert deltas.shape == (k, n) and x.shape == (n,)
    assert n % block_n == 0, (n, block_n)
    tiles = n // block_n
    grid = (2, tiles)
    col2 = lambda a: a.astype(jnp.float32).reshape(k, 1)
    kernel = functools.partial(
        _fused_server_kernel, policy=policy, eta_g=eta_g, s_min=s_min,
        poly_a=poly_a, hinge_a=hinge_a, hinge_b=hinge_b,
        normalize=normalize, eps=eps)
    upd, dists, w = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # park the phase-inactive operand on tile 0 (single fetch)
            pl.BlockSpec((1, block_n), lambda ph, i: (0, i * (1 - ph))),
            pl.BlockSpec((k, block_n), lambda ph, i: (0, i * (1 - ph))),
            pl.BlockSpec((k, block_n), lambda ph, i: (0, i * ph)),
            pl.BlockSpec((k, 1), lambda ph, i: (0, 0)),
            pl.BlockSpec((k, 1), lambda ph, i: (0, 0)),
            pl.BlockSpec((k, 1), lambda ph, i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda ph, i: (i * ph,)),
            pl.BlockSpec((k, 1), lambda ph, i: (0, 0)),
            pl.BlockSpec((k, 1), lambda ph, i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x.reshape(1, n), bases, deltas, col2(p_stat), col2(taus),
      col2(arrival_mask))
    return upd, dists[:, 0], w[:, 0]


def _sq_dist_kernel(x_ref, b_ref, o_ref):
    """Accumulate per-client ||x - base_k||^2 over N-tiles.

    Sequential-grid accumulation: the single (K,1) output block is carried
    across grid steps (TPU grid is sequential), initialised at step 0.
    x:(1,bn) b:(K,bn) o:(K,1).
    """
    i = pl.program_id(0)
    diff = b_ref[...] - x_ref[...]  # (K, bn) broadcast over clients
    part = jnp.sum(diff * diff, axis=1, keepdims=True)  # (K, 1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def sq_dists_pallas(x: jnp.ndarray, bases: jnp.ndarray,
                    block_n: int = DEFAULT_BLOCK_N,
                    interpret: bool = False) -> jnp.ndarray:
    """x: (N,) f32, bases: (K, N) f32 -> (K,) per-client squared distance."""
    k, n = bases.shape
    assert x.shape == (n,)
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    out = pl.pallas_call(
        _sq_dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((k, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.float32),
        interpret=interpret,
    )(x.reshape(1, n), bases)
    return out[:, 0]

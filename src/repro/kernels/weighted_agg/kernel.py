"""Pallas TPU kernels for the CA-AFL server hot loop.

The server pass over a model of N params with K buffered updates is
memory-bound streaming: for each parameter tile it must
  (a) reduce K client deltas with contribution weights (eq. 5), and
  (b) accumulate per-client squared distances ||x - base_i||^2 (eq. 3).

Both kernels tile the flattened parameter axis into VMEM-resident blocks
(lane-aligned multiples of 128; K rides the sublane dimension), so one HBM
pass per tile feeds the VPU — on TPU the arithmetic intensity is K flops
per 4*K bytes loaded, i.e. firmly bandwidth-bound, and fusing the weighting
into the reduction avoids materialising weighted deltas in HBM (which is
what a naive jnp einsum would do between two kernels).

TARGET: TPU (Mosaic). VALIDATION: interpret=True on CPU (tests sweep
shapes/dtypes against ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_N = 16 * 1024  # f32 tile of (K<=32, 16k) stays well under VMEM


def _weighted_sum_kernel(d_ref, w_ref, o_ref):
    """o[n] = sum_k w[k] * d[k, n] for one N-tile. d:(K,bn) w:(K,1) o:(bn,)."""
    d = d_ref[...]  # (K, bn)
    w = w_ref[...]  # (K, 1)
    o_ref[...] = jnp.sum(d * w, axis=0)


def weighted_sum_pallas(deltas: jnp.ndarray, weights: jnp.ndarray,
                        block_n: int = DEFAULT_BLOCK_N,
                        interpret: bool = False) -> jnp.ndarray:
    """deltas: (K, N) f32, weights: (K,) f32 -> (N,) f32. N % block_n == 0."""
    k, n = deltas.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _weighted_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(deltas, weights.reshape(k, 1))


def _sq_dist_kernel(x_ref, b_ref, o_ref):
    """Accumulate per-client ||x - base_k||^2 over N-tiles.

    Sequential-grid accumulation: the single (K,1) output block is carried
    across grid steps (TPU grid is sequential), initialised at step 0.
    x:(1,bn) b:(K,bn) o:(K,1).
    """
    i = pl.program_id(0)
    diff = b_ref[...] - x_ref[...]  # (K, bn) broadcast over clients
    part = jnp.sum(diff * diff, axis=1, keepdims=True)  # (K, 1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def sq_dists_pallas(x: jnp.ndarray, bases: jnp.ndarray,
                    block_n: int = DEFAULT_BLOCK_N,
                    interpret: bool = False) -> jnp.ndarray:
    """x: (N,) f32, bases: (K, N) f32 -> (K,) per-client squared distance."""
    k, n = bases.shape
    assert x.shape == (n,)
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    out = pl.pallas_call(
        _sq_dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((k, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.float32),
        interpret=interpret,
    )(x.reshape(1, n), bases)
    return out[:, 0]

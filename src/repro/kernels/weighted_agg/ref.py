"""Pure-jnp oracles for the weighted_agg kernels."""
from __future__ import annotations

import jax.numpy as jnp


def weighted_sum_ref(deltas: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """deltas: (K, N), weights: (K,) -> (N,)."""
    return jnp.einsum("kn,k->n", deltas.astype(jnp.float32),
                      weights.astype(jnp.float32))


def sq_dists_ref(x: jnp.ndarray, bases: jnp.ndarray) -> jnp.ndarray:
    """x: (N,), bases: (K, N) -> (K,)."""
    diff = bases.astype(jnp.float32) - x.astype(jnp.float32)[None]
    return jnp.sum(diff * diff, axis=1)

"""jnp oracle for the int8 dequantize-distance path (CPU-everywhere).

The reference DOES materialize the (K, N) dequantized rows — that is the
memory cost the Pallas kernel exists to avoid — but it defines the exact
arithmetic the kernel must reproduce, and it is what non-TPU backends
run (same role as ``kernels/weighted_agg/ref.py``).
"""
from __future__ import annotations

import jax.numpy as jnp


def dequant_ref(codes: jnp.ndarray, scales: jnp.ndarray,
                zeros: jnp.ndarray, qblock: int) -> jnp.ndarray:
    """codes: (K, N) int8, scales/zeros: (K, N // qblock) f32 -> (K, N) f32.

    Per-block affine decode: ``row[b*qblock + j] = q * scale[b] + zero[b]``.
    """
    k, n = codes.shape
    q = codes.astype(jnp.float32).reshape(k, n // qblock, qblock)
    deq = q * scales[..., None] + zeros[..., None]
    return deq.reshape(k, n)


def int8_sq_dists_ref(x: jnp.ndarray, codes: jnp.ndarray,
                      scales: jnp.ndarray, zeros: jnp.ndarray,
                      qblock: int) -> jnp.ndarray:
    """x: (N,) f32 vs K quantized rows -> (K,) ||x - dequant(row_k)||^2."""
    diff = dequant_ref(codes, scales, zeros, qblock) - x[None]
    return jnp.sum(diff * diff, axis=1)

"""Fused dequantize-distance kernels for the compressed version ring.

``core/version_store.py`` stores ring rows as int8 codewords + per-block
affine (scale, zero) pairs; the eq. 3 staleness distance against those
rows is computed here WITHOUT materializing the K decoded f32 rows —
each VMEM tile is dequantized in-register and folded straight into the
per-client partial squared distance (``kernel.int8_sq_dists_pallas``),
or via the pure-jnp reference (``ref.int8_sq_dists_ref``) everywhere a
Mosaic program can't compile. ``ops.int8_sq_dists`` is the public
dispatcher mirroring ``kernels/weighted_agg/ops.py``.
"""
from repro.kernels.ring_codec.ops import int8_sq_dists  # noqa: F401
from repro.kernels.ring_codec.ref import (  # noqa: F401
    dequant_ref,
    int8_sq_dists_ref,
)

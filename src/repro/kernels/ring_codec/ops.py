"""Public dispatcher: fused-kernel vs jnp-reference int8 distance."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ring_codec import kernel as _k
from repro.kernels.ring_codec import ref as _ref


def int8_sq_dists(x: jnp.ndarray, codes: jnp.ndarray, scales: jnp.ndarray,
                  zeros: jnp.ndarray, *, qblock: int, block_n: int = 0,
                  use_kernel: bool = False,
                  interpret: bool = False) -> jnp.ndarray:
    """eq. 3 squared distances against K int8-quantized ring rows.

    x: (N,) f32, codes: (K, N) int8, scales/zeros: (K, N // qblock) f32
    -> (K,). Inputs arrive already padded on the flat-spec layout (N a
    ``block_n`` multiple, ``qblock`` dividing ``block_n`` — see
    ``version_store.resolve_qblock``), so unlike ``weighted_agg.ops``
    there is no pad/slice here. ``use_kernel`` picks the fused Mosaic
    kernel (TPU, or ``interpret=True`` validation); otherwise the jnp
    reference runs — same dispatch convention as the server pass's
    batched/fused vs reference modes.
    """
    x = x.astype(jnp.float32)
    if not use_kernel:
        return _ref.int8_sq_dists_ref(x, codes, scales, zeros, qblock)
    n = x.shape[0]
    block = block_n or _k.DEFAULT_BLOCK_N
    if n % block:  # single lane-padded tile (small models)
        block = n
    return _k.int8_sq_dists_pallas(x, codes, scales, zeros, qblock=qblock,
                                   block_n=block, interpret=interpret)

"""Pallas TPU kernel: fused int8 dequantize + eq. 3 distance.

The compressed ring keeps rows as int8 codewords with per-block affine
(scale, zero) pairs (``core/version_store.py``). A naive distance path
would decode the K rows to (K, N) f32 in HBM — 4x the bytes the codec
just saved — and only then stream them through ``sq_dists_pallas``. This
kernel fuses the decode into the distance accumulation: each grid step
loads one int8 tile (plus its scale/zero columns), dequantizes it
in-register, and folds ``||x - deq||^2`` into the resident (K, 1)
accumulator. The decoded f32 rows never exist anywhere — per tile HBM
traffic is ``K * bn`` int8 bytes + ``2 * K * bn / qblock`` f32 scales
instead of ``4 * K * bn`` f32 bytes, so the distance pass inherits the
codec's ~4x bandwidth win on a bandwidth-bound loop.

Same sequential-grid accumulation idiom as
``weighted_agg.kernel.sq_dists_pallas`` (the single (K, 1) output block
is carried across grid steps and initialised at step 0). Under a model
mesh the caller runs this per shard and psums the partials — identical
communication shape to the f32 path (DESIGN.md §5).

TARGET: TPU (Mosaic). VALIDATION: interpret=True on CPU
(tests/test_version_store.py sweeps shapes against ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.weighted_agg.kernel import DEFAULT_BLOCK_N, LANE  # noqa: F401


def _int8_sq_dist_kernel(x_ref, c_ref, s_ref, z_ref, o_ref, *, qblock: int):
    """x:(1,bn) c:(K,bn) int8, s/z:(K,bn//qblock), o:(K,1) accumulator."""
    i = pl.program_id(0)
    k, bn = c_ref.shape
    q = c_ref[...].astype(jnp.float32).reshape(k, bn // qblock, qblock)
    deq = (q * s_ref[...][..., None] + z_ref[...][..., None]).reshape(k, bn)
    diff = deq - x_ref[...]  # broadcast over the K clients
    part = jnp.sum(diff * diff, axis=1, keepdims=True)  # (K, 1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def int8_sq_dists_pallas(x: jnp.ndarray, codes: jnp.ndarray,
                         scales: jnp.ndarray, zeros: jnp.ndarray, *,
                         qblock: int, block_n: int = DEFAULT_BLOCK_N,
                         interpret: bool = False) -> jnp.ndarray:
    """x: (N,) f32, codes: (K, N) int8, scales/zeros: (K, N // qblock) f32
    -> (K,) ``||x - dequant(row_k)||^2``. Requires ``N % block_n == 0``
    and ``block_n % qblock == 0`` (the ops wrapper and
    ``version_store.resolve_qblock`` guarantee both).
    """
    k, n = codes.shape
    assert x.shape == (n,)
    assert n % block_n == 0, (n, block_n)
    assert block_n % qblock == 0, (block_n, qblock)
    sb = block_n // qblock  # scale/zero columns per tile
    grid = (n // block_n,)
    out = pl.pallas_call(
        functools.partial(_int8_sq_dist_kernel, qblock=qblock),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
            pl.BlockSpec((k, sb), lambda i: (0, i)),
            pl.BlockSpec((k, sb), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((k, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.float32),
        interpret=interpret,
    )(x.reshape(1, n), codes, scales, zeros)
    return out[:, 0]

"""Jit'd wrapper for the selective-scan kernel (kernel vs oracle switch)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import kernel as _k
from repro.kernels.ssm_scan import ref as _ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret",
                                             "chunk", "block_d"))
def selective_scan(x, dt, b, c, a, use_kernel: bool = True,
                   interpret: bool = True, chunk: int = 128,
                   block_d: int = 512):
    """x, dt: (B, S, di); b, c: (B, S, N); a: (di, N) -> y (B, S, di) f32."""
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)
    a = a.astype(jnp.float32)
    if not use_kernel:
        return _ref.selective_scan_ref(x, dt, b, c, a)
    bsz, s, di = x.shape
    chunk = min(chunk, s)
    block_d = min(block_d, di)
    pad_s = (-s) % chunk
    pad_d = (-di) % block_d
    if pad_s or pad_d:
        pad3 = ((0, 0), (0, pad_s), (0, pad_d))
        x = jnp.pad(x, pad3)
        dt = jnp.pad(dt, pad3)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad_s), (0, 0)))
        a = jnp.pad(a, ((0, pad_d), (0, 0)))
    y = _k.selective_scan_pallas(x, dt, b, c, a, chunk=chunk, block_d=block_d,
                                 interpret=interpret)
    return y[:, :s, :di]

"""Pure-jnp oracle for the selective scan (sequential, materialised)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, b, c, a):
    """Same contract as the kernel: returns y_t = C_t . h_t."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    bsz, s, di = x.shape
    n = a.shape[1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt[..., None] * a[None])  # (B, di, N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    xs = (xf.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
          bf.transpose(1, 0, 2), cf.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2)

"""Pallas TPU kernel for the Mamba-1 selective scan.

TPU adaptation: the CUDA kernel's per-thread sequential scan over registers
becomes a *chunked* scan whose working set lives in VMEM — grid =
(batch, d_inner blocks, seq chunks) with the seq-chunk dimension innermost
(TPU grids run the minor dimension sequentially, so the (bd, N) hidden
state carried in VMEM scratch plays the role of cross-chunk registers).
Within a chunk the recurrence h_t = da_t * h_{t-1} + (dt_t x_t) B_t runs as
a fori_loop over VMEM-resident tiles; discretisation (exp(dt*A)) is fused —
neither da nor h is ever materialised in HBM, which is the whole point:
the jnp reference materialises (B, S, d, N) intermediates, this kernel
streams (chunk, bd) tiles.

TARGET: TPU (Mosaic). VALIDATION: interpret=True on CPU vs ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, o_ref, h_ref, *,
                chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...]  # (bd, N)

    def body(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)  # (bd,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)  # (bd,)
        bt = b_ref[0, t, :].astype(jnp.float32)  # (N,)
        ct = c_ref[0, t, :].astype(jnp.float32)  # (N,)
        da = jnp.exp(dtt[:, None] * a)  # (bd, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=1)  # (bd,)
        o_ref[0, t, :] = y.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_ref[...])
    h_ref[...] = h


def selective_scan_pallas(x, dt, b, c, a, *, chunk: int = 128,
                          block_d: int = 512, interpret: bool = False):
    """Chunked selective scan.

    x, dt: (B, S, di) — post-conv activations and softplus'd step sizes
    b, c : (B, S, N)  — input/output projections
    a    : (di, N)    — negative state matrix (continuous-time)
    Returns y: (B, S, di) with y_t = C_t . h_t (the D*x and z-gate terms are
    applied outside — they are elementwise and fuse fine in XLA).
    """
    bsz, s, di = x.shape
    n = a.shape[1]
    chunk = min(chunk, s)
    block_d = min(block_d, di)
    assert s % chunk == 0 and di % block_d == 0, (s, chunk, di, block_d)
    nc, nd = s // chunk, di // block_d
    kern = functools.partial(_ssm_kernel, chunk=chunk, num_chunks=nc)
    return pl.pallas_call(
        kern,
        grid=(bsz, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, d, ci: (bi, ci, d)),
            pl.BlockSpec((1, chunk, block_d), lambda bi, d, ci: (bi, ci, d)),
            pl.BlockSpec((1, chunk, n), lambda bi, d, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, d, ci: (bi, ci, 0)),
            pl.BlockSpec((block_d, n), lambda bi, d, ci: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda bi, d, ci: (bi, ci, d)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a)

from repro.data.partition import dirichlet_partition, shard_partition  # noqa: F401
from repro.data.synthetic import (  # noqa: F401
    ClientDataset,
    make_federated_image_dataset,
    make_lm_token_stream,
    synthetic_image_classes,
)

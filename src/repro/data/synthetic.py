"""Synthetic datasets.

This container is offline, so the paper's Fashion-MNIST experiment is
reproduced on a *synthetic class-clustered image dataset* with the same
cardinality and shape (28x28x1, 10 classes). Each class c has a random
prototype image P_c; samples are P_c + Gaussian noise + random shift. What
the paper's claim exercises — non-IID label skew across async clients — is
preserved exactly by this generator + the Dirichlet partitioner.

Also provides a synthetic LM token stream for the big-architecture training
paths (power-law unigram over the vocab so loss has learnable structure).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.data.partition import dirichlet_partition


@dataclasses.dataclass
class ClientDataset:
    """In-memory dataset for one federated client."""

    x: np.ndarray  # (n, ...) features
    y: np.ndarray  # (n,) int labels (or next tokens)
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    @property
    def size(self) -> int:
        return int(self.x.shape[0])

    def batch_indices(self, batch_size: int) -> np.ndarray:
        """One batch worth of sample indices (replacement iff n < batch)."""
        n = self.size
        return self._rng.choice(n, size=batch_size, replace=n < batch_size)

    def batch(self, batch_size: int):
        """Sample a random mini-batch (with replacement if n < batch_size)."""
        idx = self.batch_indices(batch_size)
        return self.x[idx], self.y[idx]

    def batches(self, batch_size: int, count: int):
        """``count`` stacked mini-batches, (count, B, ...), in ONE gather.

        Index draws are ``count`` sequential ``batch_indices`` calls, so
        the RNG stream — and therefore every batch — is bit-identical to
        ``count`` successive ``batch()`` calls; only the per-batch fancy
        indexing and stacking (the host-side cost at large N) collapses
        into a single vectorized gather + reshape.
        """
        idx = np.concatenate([self.batch_indices(batch_size)
                              for _ in range(count)])
        return (self.x[idx].reshape(count, batch_size, *self.x.shape[1:]),
                self.y[idx].reshape(count, batch_size, *self.y.shape[1:]))

    # -- checkpointing (engine resume) ----------------------------------
    def rng_state(self) -> np.ndarray:
        """(6,) uint64 snapshot of the batch-sampling stream."""
        from repro.utils.rngstate import pack_pcg64
        return pack_pcg64([self._rng])[0]

    def set_rng_state(self, row: np.ndarray) -> None:
        """Restore ``rng_state``: the next batch draw continues the
        snapshotted stream exactly."""
        from repro.utils.rngstate import unpack_pcg64
        self._rng = unpack_pcg64(np.asarray(row)[None])[0]


def synthetic_image_classes(num_samples: int, num_classes: int = 10,
                            shape=(28, 28, 1), noise: float = 0.35,
                            seed: int = 0):
    """Class-clustered images: per-class smooth prototype + noise."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.0, 1.0, size=(num_classes,) + tuple(shape)).astype(np.float32)
    # low-pass the prototypes so classes are "image-like" (local structure)
    for _ in range(2):
        protos = 0.5 * protos + 0.25 * (np.roll(protos, 1, axis=1) + np.roll(protos, -1, axis=1))
        protos = 0.5 * protos + 0.25 * (np.roll(protos, 1, axis=2) + np.roll(protos, -1, axis=2))
    y = rng.integers(0, num_classes, size=num_samples).astype(np.int32)
    x = protos[y] + noise * rng.normal(size=(num_samples,) + tuple(shape)).astype(np.float32)
    return x.astype(np.float32), y


def make_federated_image_dataset(num_clients: int = 30, samples_per_client: int = 1500,
                                 num_classes: int = 10, alpha: float = 0.3,
                                 noise: float = 0.35, seed: int = 0,
                                 test_fraction: float = 0.1):
    """Paper-experiment setup: 30 clients x 1500 samples, non-IID Dirichlet.

    Returns (clients: List[ClientDataset], (x_test, y_test)).
    """
    total = num_clients * samples_per_client
    n_test = int(total * test_fraction)
    x, y = synthetic_image_classes(total + n_test, num_classes=num_classes,
                                   noise=noise, seed=seed)
    x_test, y_test = x[total:], y[total:]
    x, y = x[:total], y[:total]
    parts = dirichlet_partition(y, num_clients, alpha=alpha, seed=seed + 1,
                                min_per_client=8)
    clients = []
    for i, idx in enumerate(parts):
        # equalize sizes to samples_per_client by resampling (paper: equal sizes)
        rng = np.random.default_rng(seed + 100 + i)
        if len(idx) >= samples_per_client:
            idx = idx[:samples_per_client]
        else:
            idx = np.concatenate([idx, rng.choice(idx, samples_per_client - len(idx))])
        clients.append(ClientDataset(x=x[idx], y=y[idx], seed=seed + 200 + i))
    return clients, (x_test, y_test)


def make_lm_token_stream(vocab_size: int, seq_len: int, num_sequences: int,
                         seed: int = 0, order: int = 2):
    """Synthetic token stream with learnable bigram structure.

    Tokens follow a sparse random bigram transition over a power-law
    unigram, so cross-entropy decreases materially under training.
    Returns tokens (num_sequences, seq_len+1) int32 — inputs are [:, :-1],
    labels are [:, 1:].
    """
    rng = np.random.default_rng(seed)
    v = int(vocab_size)
    # power-law unigram
    ranks = np.arange(1, v + 1)
    unigram = 1.0 / ranks ** 1.1
    unigram /= unigram.sum()
    # each token deterministically prefers a small successor set
    succ = rng.integers(0, v, size=(v, 4))
    toks = np.empty((num_sequences, seq_len + 1), dtype=np.int64)
    toks[:, 0] = rng.choice(v, size=num_sequences, p=unigram)
    for t in range(seq_len):
        prev = toks[:, t]
        use_bigram = rng.random(num_sequences) < 0.8
        choice = succ[prev, rng.integers(0, 4, size=num_sequences)]
        rand = rng.choice(v, size=num_sequences, p=unigram)
        toks[:, t + 1] = np.where(use_bigram, choice, rand)
    return toks.astype(np.int32)


def make_federated_lm_dataset(num_clients: int, vocab_size: int, seq_len: int,
                              sequences_per_client: int, seed: int = 0):
    """Per-client LM shards with heterogeneous token distributions."""
    clients: List[ClientDataset] = []
    for i in range(num_clients):
        # heterogeneity: each client's stream uses a shifted successor table
        toks = make_lm_token_stream(vocab_size, seq_len, sequences_per_client,
                                    seed=seed + 31 * i)
        clients.append(ClientDataset(x=toks[:, :-1], y=toks[:, 1:], seed=seed + i))
    return clients

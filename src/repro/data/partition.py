"""Non-IID partitioners for federated datasets.

``dirichlet_partition`` is the standard label-skew generator (Hsu et al.
2019): client i's label distribution ~ Dir(alpha). Low alpha => extreme
heterogeneity (each client sees few classes), alpha -> inf => IID.
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, num_clients: int, alpha: float,
                        seed: int = 0, min_per_client: int = 1):
    """Partition indices of ``labels`` into ``num_clients`` non-IID shards.

    Returns a list of np.ndarray index arrays, one per client. Every sample
    is assigned to exactly one client; each client gets >= min_per_client.
    """
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    num_classes = int(labels.max()) + 1
    class_idx = [np.flatnonzero(labels == c) for c in range(num_classes)]
    for idx in class_idx:
        rng.shuffle(idx)

    while True:
        client_idx = [[] for _ in range(num_clients)]
        for c in range(num_classes):
            props = rng.dirichlet(np.full(num_clients, alpha))
            # split this class's indices proportionally
            counts = np.floor(props * len(class_idx[c])).astype(int)
            # distribute remainder to the largest proportions
            rem = len(class_idx[c]) - counts.sum()
            order = np.argsort(-props)
            for k in range(rem):
                counts[order[k % num_clients]] += 1
            start = 0
            for i in range(num_clients):
                client_idx[i].extend(class_idx[c][start:start + counts[i]])
                start += counts[i]
        sizes = np.array([len(ci) for ci in client_idx])
        if sizes.min() >= min_per_client:
            break
    out = []
    for ci in client_idx:
        arr = np.array(ci, dtype=np.int64)
        rng.shuffle(arr)
        out.append(arr)
    return out


def shard_partition(labels: np.ndarray, num_clients: int, shards_per_client: int = 2,
                    seed: int = 0):
    """McMahan-style pathological split: sort by label, deal out shards."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    order = np.argsort(labels, kind="stable")
    num_shards = num_clients * shards_per_client
    shards = np.array_split(order, num_shards)
    perm = rng.permutation(num_shards)
    out = []
    for i in range(num_clients):
        take = perm[i * shards_per_client:(i + 1) * shards_per_client]
        idx = np.concatenate([shards[s] for s in take])
        rng.shuffle(idx)
        out.append(idx.astype(np.int64))
    return out

"""Versioned, codec-aware wire schema for the serving ingress (§12).

One frame format for every RPC of the ``AggregatorService`` protocol
(offer / pull / snapshot), over raw TCP and as HTTP bodies alike:

    +--------+----------------+------------+--------------+-----------+
    | b"FW"  | u16 schema_ver | u32 hd_len | header JSON  | blobs ... |
    +--------+----------------+------------+--------------+-----------+

(big-endian integers; on TCP the frame is preceded by a u32 total
length so the reader can recv exactly one frame). The header is UTF-8
JSON — msgpack would shave a few bytes but the container is stdlib-only,
and the tensor payloads dominate anyway:

    {"kind": "offer" | "admission" | "pull" | "model" | "metrics"
             | "error",
     "meta": {...},                      # message-specific JSON
     "tensors": [{"name": ..., "dtype": "float32", "shape": [...],
                  "codec": "f32" | "int8", "nbytes": ...,
                  "qblock": 256}, ...]}  # blob manifest, in blob order

``schema_version`` is stamped on encode and CHECKED on decode — a
mismatched peer fails loudly with ``WireError`` instead of folding
garbage into the aggregate.

Payload codecs (per tensor; non-float32 leaves — labels — always ship
raw):

* ``f32`` — raw little-endian float32 bytes. Bit-exact round-trip: the
  loopback parity gate (served params byte-identical between the
  in-process twin and the socket path) rides on this.
* ``int8`` — per-block affine quantization, ``qblock`` params per block
  (the compressed version store's scheme, DESIGN.md §11, applied to the
  client->server upload direction): blob = int8 codes + per-block f32
  scale + per-block f32 min, ~3.9x fewer bytes than f32 at qblock=256.
  Lossy — used for bandwidth, never under the parity gate.
"""
from __future__ import annotations

import hashlib
import json
import struct
from typing import Any, BinaryIO, Dict, List, Optional, Tuple

import numpy as np

SCHEMA_VERSION = 1
MAGIC = b"FW"
WIRE_CODECS = ("f32", "int8")
_HDR = struct.Struct(">2sHI")  # magic, schema_version, header_len
_LEN = struct.Struct(">I")  # TCP frame-length prefix
MAX_FRAME_BYTES = 1 << 30  # refuse absurd lengths before allocating


class WireError(ValueError):
    """Malformed / truncated / wrong-schema frame."""


# -- tensor payload codecs ----------------------------------------------

def _encode_tensor(name: str, arr: np.ndarray, codec: str,
                   qblock: int) -> Tuple[Dict[str, Any], bytes]:
    """(manifest entry, blob bytes) for one tensor."""
    arr = np.ascontiguousarray(arr)
    entry: Dict[str, Any] = {"name": name, "dtype": str(arr.dtype),
                             "shape": list(arr.shape)}
    if codec == "int8" and arr.dtype == np.float32:
        x = arr.ravel()
        n = x.size
        nb = max(1, -(-n // qblock))
        padded = np.zeros(nb * qblock, np.float32)
        padded[:n] = x
        blocks = padded.reshape(nb, qblock)
        mn = blocks.min(axis=1)
        scale = (blocks.max(axis=1) - mn) / 255.0
        scale = np.where(scale > 0, scale, 1.0).astype(np.float32)
        q = np.rint((blocks - mn[:, None]) / scale[:, None]) - 128
        blob = (q.astype(np.int8).tobytes() +
                scale.astype("<f4").tobytes() + mn.astype("<f4").tobytes())
        entry.update(codec="int8", qblock=qblock, nbytes=len(blob))
        return entry, blob
    if codec not in WIRE_CODECS:
        raise WireError(f"unknown wire codec {codec!r} (have {WIRE_CODECS})")
    blob = arr.astype(arr.dtype.newbyteorder("<")).tobytes()
    entry.update(codec="f32", nbytes=len(blob))
    return entry, blob


def _decode_tensor(entry: Dict[str, Any], blob: bytes) -> np.ndarray:
    dtype = np.dtype(entry["dtype"])
    shape = tuple(entry["shape"])
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    if entry["codec"] == "int8":
        qblock = int(entry["qblock"])
        nb = max(1, -(-n // qblock))
        off = nb * qblock
        if len(blob) != off + 8 * nb:
            raise WireError(f"int8 blob for {entry['name']!r}: "
                            f"{len(blob)} bytes, expected {off + 8 * nb}")
        q = np.frombuffer(blob, np.int8, count=off).astype(np.float32)
        scale = np.frombuffer(blob, "<f4", count=nb, offset=off)
        mn = np.frombuffer(blob, "<f4", count=nb, offset=off + 4 * nb)
        x = (q.reshape(nb, qblock) + 128.0) * scale[:, None] + mn[:, None]
        return x.ravel()[:n].astype(np.float32).reshape(shape)
    if entry["codec"] != "f32":
        raise WireError(f"unknown tensor codec {entry['codec']!r}")
    expect = n * dtype.itemsize
    if len(blob) != expect:
        raise WireError(f"raw blob for {entry['name']!r}: {len(blob)} "
                        f"bytes, expected {expect}")
    return np.frombuffer(blob, dtype.newbyteorder("<")).astype(
        dtype, copy=False).reshape(shape)


# -- frame encode / decode ----------------------------------------------

def encode_message(kind: str, meta: Dict[str, Any],
                   tensors: Optional[Dict[str, np.ndarray]] = None,
                   codec: str = "f32", qblock: int = 256) -> bytes:
    """One complete frame (schema-stamped header + tensor blobs)."""
    manifest: List[Dict[str, Any]] = []
    blobs: List[bytes] = []
    for name in sorted(tensors or ()):
        entry, blob = _encode_tensor(name, tensors[name], codec, qblock)
        manifest.append(entry)
        blobs.append(blob)
    header = json.dumps({"kind": kind, "meta": meta, "tensors": manifest},
                        separators=(",", ":")).encode()
    return b"".join([_HDR.pack(MAGIC, SCHEMA_VERSION, len(header)), header,
                     *blobs])


def decode_message(data: bytes
                   ) -> Tuple[str, Dict[str, Any], Dict[str, np.ndarray]]:
    """Parse one complete frame -> (kind, meta, tensors).

    Raises ``WireError`` on a bad magic, a schema_version mismatch, or a
    truncated / oversized payload."""
    if len(data) < _HDR.size:
        raise WireError(f"frame truncated: {len(data)} bytes")
    magic, version, hd_len = _HDR.unpack_from(data)
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r} (not a wire frame)")
    if version != SCHEMA_VERSION:
        raise WireError(f"schema_version mismatch: peer speaks {version}, "
                        f"this build speaks {SCHEMA_VERSION}")
    off = _HDR.size + hd_len
    if len(data) < off:
        raise WireError("frame truncated inside the header")
    try:
        header = json.loads(data[_HDR.size:off].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError(f"unparseable frame header: {e}") from e
    tensors: Dict[str, np.ndarray] = {}
    for entry in header.get("tensors", ()):
        nbytes = int(entry["nbytes"])
        if len(data) < off + nbytes:
            raise WireError(f"frame truncated inside tensor "
                            f"{entry['name']!r}")
        tensors[entry["name"]] = _decode_tensor(entry,
                                                data[off:off + nbytes])
        off += nbytes
    return header["kind"], header.get("meta", {}), tensors


def write_frame(stream: BinaryIO, frame: bytes) -> None:
    """TCP framing: u32 length prefix + the frame."""
    stream.write(_LEN.pack(len(frame)) + frame)
    stream.flush()


def _read_exact(stream: BinaryIO, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = stream.read(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed mid-frame"
                                  if buf else "peer closed")
        buf += chunk
    return buf


def read_message(stream: BinaryIO
                 ) -> Tuple[str, Dict[str, Any], Dict[str, np.ndarray]]:
    """Read one length-prefixed frame off a TCP stream and decode it."""
    (total,) = _LEN.unpack(_read_exact(stream, _LEN.size))
    if total > MAX_FRAME_BYTES:
        raise WireError(f"frame length {total} exceeds the "
                        f"{MAX_FRAME_BYTES}-byte cap")
    return decode_message(_read_exact(stream, total))


# -- content digests (the loopback parity gate) -------------------------

def payload_sha256(upload) -> str:
    """Digest of an Upload's tensor content (batch + probe), byte-exact.

    Used by the fold journal: the parity replay reconstructs each folded
    upload from the seeded client datasets and checks the digest before
    folding, so a desynced reconstruction fails loudly instead of
    producing a silently-different aggregate."""
    _, tensors = upload.to_wire()
    h = hashlib.sha256()
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name])
        h.update(name.encode())
        h.update(str(arr.dtype).encode() + str(arr.shape).encode())
        h.update(arr.astype(arr.dtype.newbyteorder("<")).tobytes())
    h.update(str(int(upload.base_version)).encode())
    return h.hexdigest()


def params_sha256(version: int, params: Any) -> str:
    """Digest of a served model: the byte-identity the loopback parity
    acceptance gate compares between the in-process twin and the socket
    path."""
    from repro.core.serving import tree_to_wire

    tensors: Dict[str, np.ndarray] = {}
    tree_to_wire("params", params, tensors)
    h = hashlib.sha256()
    h.update(str(int(version)).encode())
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name])
        h.update(name.encode())
        h.update(arr.astype(arr.dtype.newbyteorder("<")).tobytes())
    return h.hexdigest()

"""Socket-side ``AggregatorService``: the FL client runner (§12).

``RemoteAggregator`` speaks the wire schema to an ``AggregatorServer``
over a persistent TCP or HTTP connection and presents the SAME
``offer`` / ``pull`` / ``snapshot`` protocol as the in-process
``ServingController`` — callers (the client loop below, the parity
tests, the transport benchmark) cannot tell a socket from a direct
call. Connection loss is retried with jittered exponential backoff
(deterministic under a seed), so client churn and server restarts are
survivable instead of fatal.

``run_client`` is the client lifecycle the paper's serving regime
needs, mirroring ``sim/arrivals.py``'s in-process twin semantics
event for event:

    pull (version, params) -> local training (the streaming mapping
    folds server-side, so "training" = drawing the seeded local-step
    batches + eq.-4 probe; Upload docstring) -> offer
      * admitted / dropped-stale -> re-pull the CURRENT version, next
        local round (the stale drop means the base fell out of the
        version window: restart, don't ship unweightable work)
      * queue full -> sleep the advertised retry_after (plus jitter)
        and re-offer the SAME upload — same seq, same base_version,
        now staler
"""
from __future__ import annotations

import dataclasses
import http.client
import logging
import random
import socket
import time
from typing import Any, Callable, Dict, Optional, Tuple

from repro.configs.base import FLConfig
from repro.core.serving import (
    REJECT_QUEUE_FULL,
    Admission,
    AggregatorService,
    Upload,
    tree_from_wire,
)
from repro.transport import wire

logger = logging.getLogger("repro.transport.client")


class TransportError(ConnectionError):
    """RPC failed after exhausting the reconnect budget."""


# THE shared draw (sim/arrivals.py): the in-process twin, real clients,
# and the journal replay all materialize uploads through one function,
# so a client's seq-th upload is bit-identical everywhere — the property
# the loopback parity gate rides on.
from repro.sim.arrivals import draw_upload  # noqa: E402,F401


class RemoteAggregator(AggregatorService):
    """``AggregatorService`` over a persistent socket (tcp or http).

    Every RPC is wrapped in the reconnect loop: on a connection error
    the proxy sleeps ``backoff_base * 2**attempt`` seconds (capped at
    ``backoff_cap``, multiplied by a seeded uniform jitter in
    [0.5, 1.5) so a fleet of clients doesn't reconnect in lockstep)
    and redials, up to ``max_retries`` times before raising
    ``TransportError``.
    """

    def __init__(self, host: str, port: int, *, transport: str = "tcp",
                 codec: str = "f32", max_retries: int = 8,
                 backoff_base: float = 0.05, backoff_cap: float = 2.0,
                 timeout: float = 30.0, seed: int = 0):
        if codec not in wire.WIRE_CODECS:
            raise ValueError(f"codec must be one of {wire.WIRE_CODECS}")
        self.host, self.port = host, port
        self.transport = transport
        self.codec = codec
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.timeout = timeout
        self._jitter = random.Random(seed)
        self._sock: Optional[socket.socket] = None
        self._file = None
        self._http: Optional[http.client.HTTPConnection] = None
        self.reconnects = 0  # telemetry: how flaky was the link

    # -- connection management -------------------------------------------
    def _connect(self) -> None:
        self.close()
        if self.transport == "tcp":
            self._sock = socket.create_connection(
                (self.host, self.port), timeout=self.timeout)
            self._sock.setsockopt(socket.IPPROTO_TCP,
                                  socket.TCP_NODELAY, 1)
            self._file = self._sock.makefile("rwb")
        else:
            self._http = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout)
            self._http.connect()
            # headers and body go out in separate sends; without NODELAY
            # Nagle + delayed-ACK stalls every request ~40ms
            self._http.sock.setsockopt(socket.IPPROTO_TCP,
                                       socket.TCP_NODELAY, 1)

    def close(self) -> None:
        for closer in (self._file, self._sock, self._http):
            if closer is not None:
                try:
                    closer.close()
                except OSError:
                    pass
        self._sock = self._file = self._http = None

    def _rpc(self, frame: bytes, *, path: str, method: str
             ) -> Tuple[str, Dict[str, Any], Dict[str, Any]]:
        """One request/response with connection-loss retry + backoff."""
        last: Exception = ConnectionError("never connected")
        for attempt in range(self.max_retries):
            try:
                if self._sock is None and self._http is None:
                    self._connect()
                if self.transport == "tcp":
                    wire.write_frame(self._file, frame)
                    return wire.read_message(self._file)
                # GET endpoints carry no body (the server synthesizes the
                # request frame); a body on a GET would linger unread in
                # the keep-alive stream and corrupt the next request line
                self._http.request(method, path,
                                   body=frame if method == "POST" else None)
                resp = self._http.getresponse()
                body = resp.read()
                if resp.status != 200:
                    raise wire.WireError(
                        f"HTTP {resp.status}: {body[:200]!r}")
                return wire.decode_message(body)
            except (ConnectionError, socket.timeout, OSError,
                    http.client.HTTPException) as e:
                last = e
                self.close()
                self.reconnects += 1
                delay = min(self.backoff_cap,
                            self.backoff_base * (2.0 ** attempt))
                delay *= 0.5 + self._jitter.random()  # jittered
                logger.debug("rpc %s failed (%s); retry %d/%d in %.3fs",
                             path, e, attempt + 1, self.max_retries, delay)
                time.sleep(delay)
        raise TransportError(
            f"{method} {path} to {self.host}:{self.port} failed after "
            f"{self.max_retries} attempts: {last}") from last

    # -- AggregatorService ------------------------------------------------
    def offer(self, upload: Upload, now: float) -> Admission:
        meta, tensors = upload.to_wire()
        frame = wire.encode_message("offer", meta, tensors,
                                    codec=self.codec)
        kind, rmeta, _ = self._rpc(frame, path="/v1/offer", method="POST")
        if kind != "admission":
            raise wire.WireError(f"expected admission, got {kind!r}: "
                                 f"{rmeta}")
        return Admission.from_wire(rmeta)

    def pull(self) -> Tuple[int, Any]:
        frame = wire.encode_message("pull", {})
        kind, meta, tensors = self._rpc(frame, path="/v1/model",
                                        method="GET")
        if kind != "model":
            raise wire.WireError(f"expected model, got {kind!r}: {meta}")
        return int(meta["version"]), tree_from_wire(meta["params"], tensors)

    def snapshot(self) -> Dict[str, Any]:
        frame = wire.encode_message("metrics", {})
        kind, meta, _ = self._rpc(frame, path="/v1/metrics", method="GET")
        if kind != "metrics":
            raise wire.WireError(f"expected metrics, got {kind!r}: {meta}")
        return meta["metrics"]


def run_client(service: AggregatorService, ds, cid: int, fl: FLConfig, *,
               uploads: int, stop_at_version: int = 0,
               think_time: float = 0.0, max_wall_time: float = 0.0,
               clock: Callable[[], float] = time.monotonic,
               sleep: Callable[[float], None] = time.sleep,
               seed: int = 0) -> Dict[str, int]:
    """Drive one client against ANY ``AggregatorService`` (remote proxy
    or an in-process controller — the tests use both interchangeably).

    Draws up to ``uploads`` local rounds; stops early once the pulled
    version reaches ``stop_at_version`` (> 0) or ``max_wall_time``
    elapses. Returns the client-side ledger (draws / admitted /
    queue-full retries / stale drops / reconnect-ish failures).
    """
    jitter = random.Random(seed * 1000003 + cid)
    t_start = clock()
    stats = {"drawn": 0, "admitted": 0, "retries": 0, "dropped_stale": 0}
    version, _params = service.pull()
    for seq in range(uploads):
        if stop_at_version and version >= stop_at_version:
            break
        if max_wall_time and clock() - t_start > max_wall_time:
            break
        if think_time:
            sleep(think_time)  # models local-training wall time
        up = draw_upload(ds, cid, fl, base_version=version, t=clock(),
                         seq=seq)
        stats["drawn"] += 1
        while True:
            adm = service.offer(
                dataclasses.replace(up, sent_at=clock()), clock())
            if adm.accepted or adm.reason != REJECT_QUEUE_FULL:
                break
            # backpressure: honor the hint (same upload, now staler);
            # small multiplicative jitter de-synchronizes the fleet
            stats["retries"] += 1
            sleep(adm.retry_after * (1.0 + 0.1 * jitter.random()))
        if adm.accepted:
            stats["admitted"] += 1
        else:
            stats["dropped_stale"] += 1
        # admitted or hopelessly stale: either way re-pull and retrain
        version, _params = service.pull()
    return stats

"""Real-transport serving ingress (DESIGN.md §12).

The serving loop's network face, split so no layer leaks into another:

* ``transport.wire`` — the versioned, codec-aware wire schema: length-
  prefixed frames (JSON header + raw tensor blobs, flat f32 or int8
  per-block affine payloads), ``schema_version`` stamped and checked;
* ``transport.server`` — stdlib-only TCP/HTTP ingress: threaded socket
  accept loop feeding the thread-safe ``ServingController.offer`` queue,
  with the existing single-threaded ``pump()`` fold loop on wall-clock;
* ``transport.client`` — ``RemoteAggregator`` (the socket-side
  ``AggregatorService``) plus the client training loop that honors
  ``retry_after`` backoff, staleness re-pulls, and connection-loss
  retry with jittered exponential backoff.

``core/serving.py`` defines the ``AggregatorService`` protocol both
sides meet; the deterministic in-process twin (``sim/arrivals.py``)
stays the CI path, and loopback parity between the two is pinned byte-
for-byte (tests/test_transport.py, scripts/loopback_smoke.py).
"""
from repro.transport.wire import (  # noqa: F401
    SCHEMA_VERSION,
    WIRE_CODECS,
    WireError,
    decode_message,
    encode_message,
    params_sha256,
    payload_sha256,
    read_message,
)
from repro.transport.server import AggregatorServer  # noqa: F401
from repro.transport.client import (  # noqa: F401
    RemoteAggregator,
    run_client,
)

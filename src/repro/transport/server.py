"""Stdlib-only TCP/HTTP ingress for the serving loop (DESIGN.md §12).

``AggregatorServer`` puts a real, unreliable-network face on a
``ServingController`` without the controller ever learning about
sockets:

* a **threaded accept loop** — either a raw framed-TCP listener (one
  thread per connection, persistent connections, the fast path the
  transport benchmark gates) or a ``ThreadingHTTPServer`` speaking the
  same frames as POST/GET bodies (``--transport http``, the CI smoke
  lane) — both dispatching into one ``_handle``;
* the controller's **thread-safe offer queue**: worker threads call
  ``ServingController.offer`` directly (its single lock IS the
  admission queue's synchronization) and nudge the fold loop through a
  condition variable;
* the **single-threaded fold loop**: ``serve()`` runs the existing
  ``pump()`` on the caller's thread with wall-clock ``now``, preserving
  the jit-once contribute/apply contract — folding never migrates off
  the aggregator thread (the controller's documented thread-safety
  contract).

Observability: every worker reports ``transport_rx_bytes_total`` /
``transport_tx_bytes_total`` / ``transport_requests_total`` labeled by a
bounded ``worker`` slot (thread-id mod 8 — fixed label cardinality on a
long-lived service), decode latency lands in a
``transport_decode_seconds`` histogram, and each request opens
``transport_decode`` -> ``transport_offer`` spans on the tracer.
"""
from __future__ import annotations

import logging
import socket
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, Optional

from repro.core.serving import ServingController
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import (
    NULL_TRACER,
    SPAN_TRANSPORT_DECODE,
    SPAN_TRANSPORT_OFFER,
    Tracer,
)
from repro.transport import wire

logger = logging.getLogger("repro.transport.server")

TRANSPORTS = ("tcp", "http")
_WORKER_SLOTS = 8  # bounded label cardinality for per-worker series


def _json_safe(obj: Any) -> Any:
    """Metrics dicts hold numpy scalars / tuples; make them JSON-able."""
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if hasattr(obj, "item"):
        return obj.item()
    return obj


class AggregatorServer:
    """One serving endpoint: listener threads -> offer queue -> fold loop.

    The server implements the WIRE side of ``AggregatorService``: every
    request frame maps 1:1 onto a protocol method (offer / pull /
    snapshot). Construction binds the socket (``port=0`` picks an
    ephemeral port, reported by ``.port``); ``serve()`` runs the fold
    loop on the calling thread until ``stop()`` returns True or
    ``shutdown()`` is called from elsewhere.
    """

    def __init__(self, controller: ServingController, *,
                 transport: str = "tcp", host: str = "127.0.0.1",
                 port: int = 0, registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        if transport not in TRANSPORTS:
            raise ValueError(f"transport must be one of {TRANSPORTS}, "
                             f"got {transport!r}")
        self.controller = controller
        self.transport = transport
        self.registry = (registry if registry is not None
                         else controller.registry)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._decode_hist = self.registry.histogram(
            "transport_decode_seconds")
        self._t0 = time.monotonic()
        self._cond = threading.Condition()
        self._stop = threading.Event()
        self._threads = []
        if transport == "tcp":
            self._listener = socket.create_server((host, port))
            self.port = self._listener.getsockname()[1]
            self._httpd = None
        else:
            server = self

            class Handler(BaseHTTPRequestHandler):
                protocol_version = "HTTP/1.1"
                # headers and body are separate sends; Nagle + the
                # peer's delayed ACK would stall every response ~40ms
                disable_nagle_algorithm = True

                def log_message(self, *a):  # quiet: obs plane has counters
                    pass

                def _reply(self, code: int, body: bytes,
                           ctype: str = "application/octet-stream"):
                    self.send_response(code)
                    self.send_header("Content-Type", ctype)
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)

                def do_POST(self):
                    if self.path != "/v1/offer":
                        self._reply(404, b"unknown endpoint",
                                    "text/plain")
                        return
                    n = int(self.headers.get("Content-Length", 0))
                    self._reply(200, server._handle(self.rfile.read(n)))

                def do_GET(self):
                    if self.path == "/v1/model":
                        req = wire.encode_message("pull", {})
                    elif self.path == "/v1/metrics":
                        req = wire.encode_message("metrics", {})
                    else:
                        self._reply(404, b"unknown endpoint",
                                    "text/plain")
                        return
                    self._reply(200, server._handle(req))

            self._listener = None
            self._httpd = ThreadingHTTPServer((host, port), Handler)
            self._httpd.daemon_threads = True
            self.port = self._httpd.server_address[1]
        logger.info("%s transport listening on %s:%d", transport, host,
                    self.port)

    # -- service clock ---------------------------------------------------
    def clock(self) -> float:
        """Wall-clock seconds since the server came up — the ``now`` every
        offer and the fold loop share (retry_after hints are in these
        units, per the Admission contract)."""
        return time.monotonic() - self._t0

    # -- request dispatch (shared by both listeners) ---------------------
    def _worker_label(self) -> str:
        return f"w{threading.get_ident() % _WORKER_SLOTS}"

    def _handle(self, data: bytes) -> bytes:
        """Decode one request frame, run the protocol method, encode the
        response. Runs on a transport worker thread."""
        worker = self._worker_label()
        rx = self.registry.counter("transport_rx_bytes_total",
                                   worker=worker)
        tx = self.registry.counter("transport_tx_bytes_total",
                                   worker=worker)
        rx.inc(len(data))
        t0 = time.perf_counter()
        try:
            with self.tracer.span(SPAN_TRANSPORT_DECODE, cat="transport",
                                  worker=worker):
                kind, meta, tensors = wire.decode_message(data)
            self._decode_hist.observe(time.perf_counter() - t0)
        except wire.WireError as e:
            resp = wire.encode_message("error", {"error": str(e)})
            tx.inc(len(resp))
            return resp
        self.registry.counter("transport_requests_total", kind=kind,
                              worker=worker).inc()
        if kind == "offer":
            import dataclasses

            from repro.core.serving import Upload

            upload = Upload.from_wire(meta, tensors)
            # re-stamp arrival on the SERVICE clock (Upload.sent_at
            # contract): the client's clock is a different process's
            # monotonic origin, meaningless for round-latency math here
            now = self.clock()
            upload = dataclasses.replace(upload, sent_at=now)
            with self.tracer.span(SPAN_TRANSPORT_OFFER, cat="transport",
                                  worker=worker, client=upload.client_id):
                adm = self.controller.offer(upload, now)
            with self._cond:
                self._cond.notify()  # wake the fold loop
            resp = wire.encode_message("admission", adm.to_wire())
        elif kind == "pull":
            from repro.core.serving import tree_to_wire

            version, params = self.controller.pull()
            out: Dict[str, Any] = {}
            skel = tree_to_wire("params", params, out)
            # model dissemination stays f32: the parity gate pins the
            # pulled bytes == the served params bytes
            resp = wire.encode_message("model",
                                       {"version": version,
                                        "params": skel}, out)
        elif kind == "metrics":
            resp = wire.encode_message(
                "metrics", {"metrics": _json_safe(
                    self.controller.snapshot())})
        else:
            resp = wire.encode_message("error",
                                       {"error": f"unknown kind {kind!r}"})
        tx.inc(len(resp))
        return resp

    # -- TCP listener -----------------------------------------------------
    def _tcp_accept_loop(self) -> None:
        conn_id = 0
        while not self._stop.is_set():
            try:
                conn, addr = self._listener.accept()
            except OSError:  # listener closed by shutdown()
                return
            t = threading.Thread(target=self._tcp_serve_conn,
                                 args=(conn,), daemon=True,
                                 name=f"transport-conn-{conn_id}")
            conn_id += 1
            t.start()

    def _tcp_serve_conn(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            with conn, conn.makefile("rwb") as f:
                while not self._stop.is_set():
                    try:
                        (total,) = wire._LEN.unpack(
                            wire._read_exact(f, wire._LEN.size))
                        if total > wire.MAX_FRAME_BYTES:
                            raise wire.WireError("oversized frame")
                        data = wire._read_exact(f, total)
                    except (ConnectionError, OSError):
                        return  # peer went away: normal churn
                    resp = self._handle(data)
                    wire.write_frame(f, resp)
        except (ConnectionError, OSError):
            return

    # -- lifecycle --------------------------------------------------------
    def start(self) -> None:
        """Start the listener threads (accept loop / HTTP server)."""
        if self.transport == "tcp":
            t = threading.Thread(target=self._tcp_accept_loop, daemon=True,
                                 name="transport-accept")
        else:
            t = threading.Thread(target=self._httpd.serve_forever,
                                 kwargs={"poll_interval": 0.05},
                                 daemon=True, name="transport-http")
        t.start()
        self._threads.append(t)

    def serve(self, *, stop: Optional[Callable[[], bool]] = None,
              round_hook: Optional[Callable[[int], None]] = None,
              poll: float = 0.05) -> None:
        """The fold loop: run ``pump`` on THIS thread (the single
        aggregator thread) whenever offers arrive, until ``stop()`` or
        ``shutdown()``. ``round_hook(version)`` fires once per applied
        round, same contract as ``serve_stream``."""
        ctrl = self.controller
        while not self._stop.is_set():
            with self._cond:
                self._cond.wait(timeout=poll)
            before = ctrl.version
            ctrl.pump(self.clock())
            if round_hook is not None:
                for v in range(before + 1, ctrl.version + 1):
                    round_hook(v)
            if stop is not None and stop():
                return

    def shutdown(self) -> None:
        """Stop listeners and wake the fold loop (idempotent)."""
        self._stop.set()
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
        with self._cond:
            self._cond.notify_all()

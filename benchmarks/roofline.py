"""Roofline analysis: three terms per (arch x shape x mesh).

Terms (seconds, per §ROOFLINE):
  compute    = FLOPs / (chips * 197e12)          [bf16 peak, v5e]
  memory     = HBM bytes / (chips * 819e9)
  collective = per-chip ICI link bytes / 50e9

FLOP/byte accounting is ANALYTIC, derived from the model configs and the
exact structure of the compiled step (which attention path is taken, remat
policy, FL-protocol extras), because XLA's ``cost_analysis()`` counts
while-loop bodies (our layer scans) exactly once — verified experimentally,
see EXPERIMENTS.md §Dry-run. The dry-run JSONs supply exact param counts
and the HLO-level numbers for cross-checking; the analytic model is
validated against cost_analysis on 2-layer unrolled variants (test suite).

Conventions:
* all-reduce over g devices (ring): per-chip link bytes = 2*(g-1)/g * payload
* all-gather / reduce-scatter: (g-1)/g * payload
* "payload" = the full logical tensor for TP collectives; params-shard for
  the data-axis delta psum.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Dict, Optional

import numpy as np

from repro.configs.base import INPUT_SHAPES, FLConfig, ModelConfig
from repro.configs.registry import get_arch, list_archs
from repro.launch.program import DRYRUN_FL, PROBE_BATCH, resolve_model_cfg

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link
FULL_ATTN_MAX_SEQ = 4096
Q_CHUNK = 512

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


@dataclasses.dataclass
class MeshSpec:
    chips: int
    model: int = 16
    data: int = 16
    pods: int = 1

    @property
    def dp(self):
        return self.data * self.pods


SINGLE = MeshSpec(chips=256)
MULTI = MeshSpec(chips=512, pods=2)


def _param_count(arch_id: str) -> int:
    for f in glob.glob(os.path.join(DRYRUN_DIR, f"{arch_id}_*_single.json")):
        r = json.load(open(f))
        if r.get("ok") and r.get("meta"):
            return int(r["meta"]["params"])
    raise FileNotFoundError(f"no dryrun meta for {arch_id}")


def _active_ratio(cfg: ModelConfig) -> float:
    """Fraction of (non-embedding) params active per token (MoE top-k)."""
    if not cfg.is_moe:
        return 1.0
    d, dff, e, k = cfg.d_model, cfg.resolved_moe_d_ff, cfg.num_experts, cfg.experts_per_token
    routed = 3 * d * dff * e * cfg.num_layers
    active_routed = routed * k / e
    # everything else is always active — compute the rest from a param count
    return None  # handled explicitly in flops_per_token


def _embed_params(cfg: ModelConfig) -> int:
    n = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        n *= 2
    return n


def flops_per_token_fwd(cfg: ModelConfig, seq: int, window: Optional[int],
                        params: int, with_logits: bool = True) -> float:
    """Forward FLOPs per token: 2*(active matmul params) + attention maths.

    Matmul params = total - embeddings (gather is free; LM head counted via
    with_logits) - inactive experts.
    """
    body = params - _embed_params(cfg)
    if cfg.is_moe:
        routed = 3 * cfg.d_model * cfg.resolved_moe_d_ff * cfg.num_experts \
            * cfg.num_layers
        body = body - routed + routed * cfg.experts_per_token / cfg.num_experts
    f = 2.0 * body
    if with_logits:
        f += 2.0 * cfg.d_model * cfg.vocab_size
    # attention score/AV maths per token per layer: 4 * S_eff * H * hd
    if cfg.num_heads:
        s_eff = seq
        w = window if window is not None else cfg.attn_window
        if w:
            s_eff = min(w + min(Q_CHUNK, seq), seq)
        # our compiled paths compute the full (masked) range — no causal skip
        f += cfg.num_layers * 4.0 * s_eff * cfg.num_heads * cfg.resolved_head_dim
    if cfg.ssm_state:
        di, n = cfg.ssm_d_inner, cfg.ssm_state
        f += cfg.num_layers * 10.0 * di * n  # discretise+scan+readout, f32
    if cfg.is_encdec:
        # encoder runs once per sequence: amortise per decoded token
        enc_body = 2.0 * (params // 3)  # encoder ~ same layer cost, L_enc
        f += enc_body * cfg.encoder_seq_len / max(seq, 1) * 0  # counted in seq pass
    return f


def attention_bytes_per_token(cfg: ModelConfig, seq: int,
                              window: Optional[int],
                              flash: bool = False) -> float:
    """HBM traffic of the attention maths per token per layer (bf16/f32).

    XLA paths (baseline): scores materialised in f32 -> ~4 passes over the
    (S_eff) score row per token (write, softmax r+w, AV read); chunked/SWA
    same asymptotics over the banded range.
    Pallas flash kernel: scores live in VMEM — HBM traffic collapses to the
    K/V stream, amortised over the q-block: 2 tensors * Hkv * hd * bf16 *
    S_eff / block_q per token.
    """
    if not cfg.num_heads:
        return 0.0
    s_eff = seq
    w = window if window is not None else cfg.attn_window
    if w:
        s_eff = min(w + min(Q_CHUNK, seq), seq)
    if flash:
        kv_stream = 2 * cfg.num_kv_heads * cfg.resolved_head_dim * 2
        return cfg.num_layers * kv_stream * s_eff / 128.0  # block_q = 128
    return cfg.num_layers * cfg.num_heads * s_eff * 4.0 * 4  # bytes


def analyze(arch_id: str, shape_name: str, mesh: MeshSpec,
            fl: FLConfig = DRYRUN_FL,
            overrides: Optional[Dict] = None,
            flash_attn: bool = False) -> Optional[Dict]:
    """Analytic roofline record for one (arch, shape, mesh)."""
    arch = get_arch(arch_id)
    if shape_name in arch.skip_shapes:
        return None
    shape = INPUT_SHAPES[shape_name]
    cfg = resolve_model_cfg(arch, shape_name)
    if overrides:
        cfg = cfg.replace(**{k: v for k, v in overrides.items()
                             if hasattr(cfg, k)})
    params = _param_count(arch_id)
    pbytes = params * 2  # bf16
    window = cfg.attn_window
    dist_mode = arch.fl_mode == "distributed"

    chips = mesh.chips
    tp = mesh.model
    dp = mesh.dp

    text_seq = shape.seq_len - (cfg.num_patches or 0)
    f_tok = flops_per_token_fwd(cfg, shape.seq_len, window, params)

    coll = {}
    if shape.kind == "train":
        m = fl.local_steps
        cohort = dp if not dist_mode else 1
        local_tokens = shape.global_batch * text_seq * m
        probe_tokens = PROBE_BATCH * (dp if not dist_mode else dp) * text_seq
        # fwd + 2x bwd + remat re-fwd
        flops = 4.0 * f_tok * local_tokens + 1.0 * f_tok * probe_tokens
        # FL-protocol elementwise passes (distances, weighted agg, resync)
        flops += (6.0 if not dist_mode else 3.0) * params * (cohort if not dist_mode else 1)

        # ---- memory (per chip) ----
        group_chips = tp if not dist_mode else chips
        params_local = pbytes / group_chips
        tokens_chip = local_tokens / chips
        w_traffic = 4.0 * params_local * m  # fwd+bwd+remat reads + delta write
        act_traffic = tokens_chip * cfg.d_model * (cfg.num_layers or 1) * 24.0
        attn_traffic = tokens_chip * attention_bytes_per_token(
            cfg, shape.seq_len, window, flash=flash_attn) * 3.0
        fl_traffic = (8.0 if not dist_mode else 4.0) * params_local
        mem_bytes = w_traffic + act_traffic + attn_traffic + fl_traffic

        # ---- collectives ----
        # TP all-reduces: ~2 per layer per pass, 3 passes (fwd,bwd,remat-fwd is
        # local) -> 4 ARs/layer counting fwd+bwd; payload = tokens_group * d.
        # In distributed-client mode each data row TP-reduces only its own
        # batch shard (tokens / dp).
        tokens_group = local_tokens / (cohort if not dist_mode else dp)
        ar_payload = tokens_group * cfg.d_model * 2
        n_ar = (cfg.num_layers or 1) * 4
        coll["tp_allreduce"] = n_ar * 2 * (tp - 1) / tp * ar_payload / tp
        if cfg.is_moe:
            # all-to-all there+back, fwd+bwd: 4x routed activations
            a2a = 4.0 * cfg.experts_per_token * tokens_group * cfg.d_model * 2
            coll["moe_all_to_all"] = a2a * (tp - 1) / tp / tp
        if dist_mode:
            # FSDP: all-gather params each pass (3x) + reduce-scatter grads
            ag = 3.0 * m * pbytes * (dp - 1) / dp / tp
            rs = 1.0 * m * pbytes * 2 * (dp - 1) / dp / tp  # f32 grads
            coll["fsdp_ag_rs"] = ag + rs
        else:
            # delta psum over data axis: params-shard payload per group
            coll["delta_psum"] = 2 * (dp - 1) / dp * (pbytes / tp)
        per_chip_link = sum(coll.values())

    elif shape.kind == "prefill":
        tokens = shape.global_batch * text_seq
        flops = f_tok * tokens  # fwd only (last-token logits ~free)
        tokens_chip = tokens / chips
        params_local = pbytes / (chips if dist_mode else tp)
        mem_bytes = (params_local + tokens_chip * cfg.d_model *
                     (cfg.num_layers or 1) * 16.0 +
                     tokens_chip * attention_bytes_per_token(
                         cfg, shape.seq_len, window, flash=flash_attn))
        tokens_group = tokens / dp
        ar_payload = tokens_group * cfg.d_model * 2
        coll["tp_allreduce"] = (cfg.num_layers or 1) * 2 * 2 * (tp - 1) / tp * ar_payload / tp
        if cfg.is_moe:
            coll["moe_all_to_all"] = (2.0 * cfg.experts_per_token * tokens_group
                                      * cfg.d_model * 2) * (tp - 1) / tp / tp
        if dist_mode:
            coll["fsdp_ag"] = pbytes * (dp - 1) / dp / tp
        per_chip_link = sum(coll.values())

    else:  # decode
        b = shape.global_batch
        cache_len = min(shape.seq_len, window or shape.seq_len)
        f_tok_dec = flops_per_token_fwd(cfg, cache_len, window, params)
        flops = f_tok_dec * b
        # memory: weights + KV cache read dominate
        params_local = pbytes / (chips if dist_mode else tp)
        if cfg.num_heads:
            kv_bytes = (cfg.num_layers * 2 * b * cache_len *
                        cfg.num_kv_heads * cfg.resolved_head_dim * 2)
        else:
            kv_bytes = 0
        if cfg.ssm_state:
            kv_bytes += cfg.num_layers * b * cfg.ssm_d_inner * (cfg.ssm_state * 4 + (cfg.ssm_conv - 1) * 2)
        if cfg.is_encdec:
            kv_bytes += (cfg.num_layers * 2 * b * cfg.encoder_seq_len *
                         cfg.num_kv_heads * cfg.resolved_head_dim * 2)
        mem_bytes = params_local + kv_bytes / chips
        ar_payload = b * cfg.d_model * 2
        coll["tp_allreduce"] = (cfg.num_layers or 1) * 2 * 2 * (tp - 1) / tp * ar_payload / tp
        if cfg.is_moe:
            coll["moe_all_to_all"] = (2.0 * cfg.experts_per_token * b *
                                      cfg.d_model * 2) * (tp - 1) / tp / tp
        if dist_mode:
            coll["fsdp_ag"] = pbytes * (dp - 1) / dp / tp
        per_chip_link = sum(coll.values())

    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = mem_bytes / HBM_BW
    t_coll = per_chip_link / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())

    # MODEL_FLOPS: 6*N*D (train) / 2*N_active*D (inference) over the same data
    if shape.kind == "train":
        n_act = _param_count_active(cfg, params)
        model_flops = 6.0 * n_act * shape.global_batch * text_seq * fl.local_steps
    elif shape.kind == "prefill":
        n_act = _param_count_active(cfg, params)
        model_flops = 2.0 * n_act * shape.global_batch * text_seq
    else:
        n_act = _param_count_active(cfg, params)
        model_flops = 2.0 * n_act * shape.global_batch
    useful = model_flops / flops if flops else 0.0

    return {
        "arch": arch_id, "shape": shape_name,
        "mesh": f"{mesh.pods}x16x16" if mesh.pods > 1 else "16x16",
        "params": params,
        "flops": flops, "hbm_bytes": mem_bytes, "link_bytes": per_chip_link,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "step_time_s": step_time,
        "model_flops": model_flops, "useful_ratio": useful,
        "collectives": coll,
        "roofline_frac": t_compute / step_time if step_time else 0.0,
    }


def _param_count_active(cfg: ModelConfig, params: int) -> float:
    if not cfg.is_moe:
        return params
    routed = 3 * cfg.d_model * cfg.resolved_moe_d_ff * cfg.num_experts * cfg.num_layers
    return params - routed + routed * cfg.experts_per_token / cfg.num_experts


def full_table(mesh: MeshSpec = SINGLE):
    rows = []
    for a in list_archs():
        for s in INPUT_SHAPES:
            r = analyze(a, s, mesh)
            if r:
                rows.append(r)
    return rows


def hlo_record(arch_id: str, shape_name: str, mesh_tag: str = "single") -> Dict:
    path = os.path.join(DRYRUN_DIR, f"{arch_id}_{shape_name}_{mesh_tag}.json")
    return json.load(open(path))


def main():
    print(f"{'arch':18s}{'shape':13s}{'dom':11s}{'t_comp':>10s}{'t_mem':>10s}"
          f"{'t_coll':>10s}{'useful':>8s}")
    for r in full_table():
        print(f"{r['arch']:18s}{r['shape']:13s}{r['dominant']:11s}"
              f"{r['t_compute_s']:10.4f}{r['t_memory_s']:10.4f}"
              f"{r['t_collective_s']:10.4f}{r['useful_ratio']:8.2f}")


if __name__ == "__main__":
    main()

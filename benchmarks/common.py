"""Shared benchmark utilities (timing, CSV output, run metadata)."""
from __future__ import annotations

import datetime
import json
import os
import resource
import subprocess
import sys
import time
from typing import Callable, Dict, List

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=_REPO_ROOT, check=True).stdout.strip()
    except (subprocess.CalledProcessError, OSError):
        return "unknown"


def peak_rss_mb() -> float:
    """Peak resident set size of this process so far, in MiB.

    ``getrusage`` reports ``ru_maxrss`` in KiB on Linux and bytes on
    macOS; monotone over the process lifetime, so sampling it before and
    after a phase bounds that phase's host-memory high-water mark — the
    number ``bench_population_scale.py`` asserts is flat in N.
    """
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    scale = 1024.0 * 1024.0 if sys.platform == "darwin" else 1024.0
    return float(rss) / scale


def run_metadata() -> Dict[str, object]:
    """Provenance stamp for every ``BENCH_*.json``: which software, which
    hardware, which commit, and when.  ``check_regression.py`` reads
    ``backend``/``device_kind`` to refuse cross-backend comparisons —
    absolute events/sec figures are meaningless across hardware classes.
    ``peak_rss_mb`` records the host high-water mark at stamp time (the
    benches stamp at exit, so it covers the whole run).
    ``ring_codec`` / ``ring_bytes_per_device`` record the active
    compressed-version-store configuration of the last ring the process
    built (``core/version_store.ring_provenance``; null when the bench
    never built one) so every BENCH_*.json says which ring layout its
    numbers were measured under."""
    devices = jax.devices()
    from repro.core.version_store import ring_provenance
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devices[0].device_kind if devices else "unknown",
        "device_count": jax.device_count(),
        "process_count": jax.process_count(),
        "git_sha": _git_sha(),
        "peak_rss_mb": round(peak_rss_mb(), 1),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        **ring_provenance(),
    }


def write_bench_json(path: str, doc: dict) -> str:
    """Write a benchmark result dict with the ``meta`` provenance stamp.

    An existing ``meta`` dict is merged in (its keys win), so benches can
    carry bench-specific notes alongside the standard provenance fields."""
    doc = dict(doc)
    doc["meta"] = {**run_metadata(), **doc.get("meta", {})}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def write_csv(name: str, header: List[str], rows: List[List]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path


def ascii_curve(xs, ys, width: int = 60, label: str = "") -> str:
    """One-line sparkline for quick terminal inspection."""
    if not ys:
        return f"{label}: (no data)"
    lo, hi = min(ys), max(ys)
    span = (hi - lo) or 1.0
    chars = " .:-=+*#%@"
    pts = []
    for i in range(width):
        j = int(i / width * (len(ys) - 1))
        pts.append(chars[int((ys[j] - lo) / span * (len(chars) - 1))])
    return f"{label:24s} [{''.join(pts)}] {lo:.3f}..{hi:.3f}"

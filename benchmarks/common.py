"""Shared benchmark utilities (timing, CSV output, ASCII curves)."""
from __future__ import annotations

import os
import time
from typing import Callable, List

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def write_csv(name: str, header: List[str], rows: List[List]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path


def ascii_curve(xs, ys, width: int = 60, label: str = "") -> str:
    """One-line sparkline for quick terminal inspection."""
    if not ys:
        return f"{label}: (no data)"
    lo, hi = min(ys), max(ys)
    span = (hi - lo) or 1.0
    chars = " .:-=+*#%@"
    pts = []
    for i in range(width):
        j = int(i / width * (len(ys) - 1))
        pts.append(chars[int((ys[j] - lo) / span * (len(chars) - 1))])
    return f"{label:24s} [{''.join(pts)}] {lo:.3f}..{hi:.3f}"

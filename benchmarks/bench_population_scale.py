"""Population-scale scenario engine: device event machine vs host walk.

Sweeps the client population N upward (to 1e6 in ``--full``) running the
device-resident window kernel (``repro.sim.population.collect_windows``:
counter-based RNG, vmapped behavior kernel, device top-k selection, one
dispatch + one sync for the whole T-window scan) and, at small N, the
host event walk it is pinned against (``host_walk_windows`` over the
PCG64-backed ``ClientBehavior`` — a heapq pop, a Python-level duration
draw and a reschedule per event).

Events-only on both sides (no training data plane): this isolates the
dispatch-bound cost the tentpole targets — advancing the population's
event state machine — from per-round training compute, which is
O(K·model) and identical under either engine.

Timing covers a COLD population each iteration: host side counts
``ClientBehavior`` construction (N PCG64 generator objects) plus the
initial N-event schedule plus the walk; device side counts the jitted
statics/init kernels plus the window scan (compile amortised by a
warmup — steady-state sweep throughput is what a scenarios×seeds runner
experiences). That asymmetry IS the point: host-side population state is
O(N) Python objects, device-side state is seven (N,) arrays.

Two assertions back the ISSUE's acceptance criteria:

* host-RSS flatness — peak RSS sampled after each device N must grow
  by less than ``RSS_BUDGET_MB`` across the whole sweep (N grows 10-100x;
  the device arrays are ~30 MB at N=1e6, while host-side behavior state
  would be GBs);
* >= ``MIN_SPEEDUP``x events/sec over the host walk at N=1e4.

Writes ``BENCH_population_scale.json`` (nightly regression gate:
events/sec per N, the 1e4 speedup, and the RSS-growth ceiling —
``benchmarks/check_regression.py``) plus a CSV curve.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import peak_rss_mb, write_bench_json, write_csv
from repro.configs.base import FLConfig
from repro.sim import get_scenario
from repro.sim.population import collect_windows, host_walk_windows
from repro.sim.scenarios import ClientBehavior

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCENARIO = "paper-fig1"     # heterogeneous tiers, no drops: pure dispatch
WINDOWS = 50                # T server rounds per measurement
BUFFER_K = 64               # uploads per window
RSS_BUDGET_MB = 1024.0      # max peak-RSS growth across the device sweep
MIN_SPEEDUP = 10.0          # device/host events-per-sec floor at N=1e4
SPEEDUP_N = 10_000
SEED = 0

# num_clients is metadata to the events-only paths (both key off the
# behavior's N); buffer_size / max_staleness are what the kernel reads
FL = FLConfig(num_clients=SPEEDUP_N, buffer_size=BUFFER_K, local_steps=1,
              local_lr=0.05, batch_size=8, max_staleness=8)


def _device_record(n: int) -> dict:
    """Median-of-3 cold-population device sweep at population size N."""
    # warmup compiles the statics/init/scan kernels at this N
    collect_windows(get_scenario(SCENARIO), n, FL, WINDOWS, seed=SEED)
    times, events = [], 0
    for _ in range(3):
        t0 = time.perf_counter()
        out = collect_windows(get_scenario(SCENARIO), n, FL, WINDOWS,
                              seed=SEED)
        times.append(time.perf_counter() - t0)
        events = out["num_events"]
    times.sort()
    dt = times[len(times) // 2]
    return {"events": int(events), "seconds": round(dt, 4),
            "events_per_sec": round(events / dt, 1)}


def _host_record(n: int) -> dict:
    """One cold-population host walk (construction + schedule + walk)."""
    t0 = time.perf_counter()
    behavior = ClientBehavior(get_scenario(SCENARIO), n, seed=SEED)
    out = host_walk_windows(behavior, FL, WINDOWS)
    dt = time.perf_counter() - t0
    return {"events": int(out["num_events"]), "seconds": round(dt, 4),
            "events_per_sec": round(out["num_events"] / dt, 1)}


def run(quick: bool = False) -> None:
    device_ns = [10_000, 100_000] if quick else [10_000, 100_000, 1_000_000]
    host_ns = [1_000, 10_000]

    records: dict = {}
    rss_samples = []
    # ascending N, host phase strictly AFTER: ru_maxrss is a monotone
    # high-water mark, so the device samples must be taken before the
    # host walk allocates its N PCG64 generators
    for n in device_ns:
        rec = _device_record(n)
        rss_samples.append(peak_rss_mb())
        rec["peak_rss_mb"] = round(rss_samples[-1], 1)
        records[str(n)] = {"device": rec}
        print(f"  device N={n:>9,}: {rec['events_per_sec']:>12,.1f} ev/s "
              f"({rec['events']} events, {rec['seconds']:.3f}s, "
              f"rss {rec['peak_rss_mb']:.0f} MB)")

    rss_growth = rss_samples[-1] - rss_samples[0]
    print(f"  peak-RSS growth over device sweep "
          f"(N={device_ns[0]:,} -> {device_ns[-1]:,}): {rss_growth:.1f} MB")
    if rss_growth >= RSS_BUDGET_MB:
        raise RuntimeError(
            f"host RSS not flat in N: peak grew {rss_growth:.1f} MB across "
            f"the device sweep (budget {RSS_BUDGET_MB:.0f} MB)")

    for n in host_ns:
        rec = _host_record(n)
        records.setdefault(str(n), {})["host"] = rec
        print(f"  host   N={n:>9,}: {rec['events_per_sec']:>12,.1f} ev/s "
              f"({rec['events']} events, {rec['seconds']:.3f}s)")

    dev = records[str(SPEEDUP_N)]["device"]["events_per_sec"]
    host = records[str(SPEEDUP_N)]["host"]["events_per_sec"]
    speedup = round(dev / host, 2)
    print(f"  speedup at N={SPEEDUP_N:,}: {speedup:.1f}x "
          f"(gate >= {MIN_SPEEDUP:.0f}x)")
    if speedup < MIN_SPEEDUP:
        raise RuntimeError(
            f"device engine only {speedup:.1f}x over the host walk at "
            f"N={SPEEDUP_N:,} (gate {MIN_SPEEDUP:.0f}x)")

    out = {
        "bench": "population_scale",
        "scenario": SCENARIO,
        "windows": WINDOWS,
        "buffer_k": BUFFER_K,
        "max_n": device_ns[-1],
        "records": records,
        "speedup_at_10k": speedup,
        "rss_growth_mb": round(rss_growth, 1),
        "rss_budget_mb": RSS_BUDGET_MB,
    }
    path = write_bench_json(os.path.join(ROOT, "BENCH_population_scale.json"),
                            out)
    rows = []
    for n_str in sorted(records, key=int):
        rec = records[n_str]
        rows.append([n_str,
                     rec.get("device", {}).get("events_per_sec", ""),
                     rec.get("device", {}).get("peak_rss_mb", ""),
                     rec.get("host", {}).get("events_per_sec", "")])
    csv = write_csv("population_scale.csv",
                    ["n", "device_events_per_sec", "device_peak_rss_mb",
                     "host_events_per_sec"], rows)
    print(f"  wrote {os.path.normpath(path)} and {os.path.normpath(csv)}")


if __name__ == "__main__":
    run(quick=os.environ.get("BENCH_QUICK", "") == "1")

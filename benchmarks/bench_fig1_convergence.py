"""Paper Fig. 1 — Performance Comparison, under any named scenario.

Reproduces the paper's experiment: image classification, 30 clients x 1500
samples (synthetic Fashion-MNIST stand-in, see DESIGN.md §1.1), non-IID
Dirichlet split, LeNet backbone, buffered-async server with K=10. The
client population (label skew, device speeds, availability, dropouts,
network tiers) comes from ``repro.sim.scenarios`` — default
``paper-fig1``; pass ``--scenario diurnal-phones`` etc. to stress the
weighting policies under different system behaviors.

Compared protocols (identical per-client duration streams, so identical
client timelines — see DESIGN.md §4):
  ca-afl (paper)   : eq. 3/4/5 contribution-aware weighting  <- the paper
  fedbuff          : uniform 1/K averaging                  <- baseline [26]
  polynomial       : (1+tau)^-0.5 staleness discount        <- cited prior
  fedasync (K=1)   : fully-async polynomial mixing
  fedavg (sync)    : synchronous straggler-bound FedAvg
  fedavg (sync,C=K): FedAvg sampling only K clients per round

Outputs accuracy-vs-server-round and accuracy-vs-sim-time curves (CSV) and
rounds/time-to-target-accuracy summaries. The paper's claim under test:
ca-afl converges faster than uniform FedBuff under staleness + non-IID.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import ascii_curve, write_csv
from repro.configs.base import FLConfig
from repro.core import run_async, run_sync
from repro.models.lenet import apply_lenet, init_lenet, lenet_loss
from repro.sim import get_scenario, registry


def run(num_clients: int = 30, samples_per_client: int = 1500,
        rounds: int = 40, noise: float = 1.2, buffer_k: int = 10,
        seed: int = 0, quick: bool = False, scenario: str = "paper-fig1",
        engine: str = "vectorized"):
    if quick:
        num_clients, samples_per_client, rounds = 10, 300, 12
        buffer_k = 4
    sc = get_scenario(scenario)
    clients, (xt, yt) = sc.make_dataset(
        num_clients, samples_per_client=samples_per_client, seed=seed,
        noise=noise)
    params = init_lenet(jax.random.PRNGKey(seed))
    xt, yt = xt[:1024], yt[:1024]
    ev = jax.jit(lambda p: jnp.mean(
        (jnp.argmax(apply_lenet(p, xt), -1) == yt).astype(jnp.float32)))
    eval_fn = lambda p: {"acc": float(ev(p))}

    base = dict(num_clients=num_clients, local_steps=4, local_lr=0.05,
                batch_size=32, global_lr=1.0)
    protocols = {
        "ca-afl(paper)": ("async", FLConfig(buffer_size=buffer_k,
                                            weighting="paper", **base)),
        "fedbuff": ("async", FLConfig(buffer_size=buffer_k,
                                      weighting="fedbuff", **base)),
        "polynomial": ("async", FLConfig(buffer_size=buffer_k,
                                         weighting="polynomial", **base)),
        "fedasync(K=1)": ("async", FLConfig(buffer_size=1,
                                            weighting="polynomial", **base)),
        "fedavg(sync)": ("sync", FLConfig(buffer_size=num_clients,
                                          weighting="fedbuff", **base)),
        "fedavg(sync,C=K)": ("sync", FLConfig(buffer_size=buffer_k,
                                              clients_per_round=buffer_k,
                                              weighting="fedbuff", **base)),
    }

    rows = []
    results = {}
    for name, (mode, fl) in protocols.items():
        # a fresh behavior per protocol, same seed: every protocol sees
        # the exact same per-client duration draws (fair comparison)
        kw = dict(scenario=sc, seed=seed)
        if mode == "async":
            runner, r, kw["engine"] = run_async, rounds, engine
        else:
            runner = run_sync
            # full-participation sync rounds scaled for comparable work;
            # the C=K variant does K updates/round like the async runs
            r = (rounds if fl.clients_per_round
                 else max(3, rounds * buffer_k // num_clients))
        res = runner(lenet_loss, params, clients, fl, total_rounds=r,
                     eval_fn=eval_fn, eval_every=max(1, rounds // 20), **kw)
        results[name] = res
        for h in res.history:
            rows.append([name, h["round"], round(h["time"], 3),
                         round(h["acc"], 4)])
        print(ascii_curve([h["round"] for h in res.history],
                          [h["acc"] for h in res.history], label=name))

    path = write_csv("fig1_convergence.csv",
                     ["protocol", "server_round", "sim_time", "accuracy"], rows)

    # headline numbers: rounds/time to target accuracy
    final_accs = {n: r.history[-1]["acc"] for n, r in results.items()}
    target = 0.95 * max(final_accs.values())
    print(f"\n  target acc = {target:.3f} (95% of best final)")
    summary = []
    for name, res in results.items():
        rt = res.rounds_to_target("acc", target)
        tt = res.time_to_target("acc", target)
        summary.append([name, final_accs[name], rt, tt])
        print(f"  {name:16s} final={final_accs[name]:.3f} "
              f"rounds_to_target={rt} time_to_target="
              f"{'-' if tt is None else round(tt, 1)}")
    write_csv("fig1_summary.csv",
              ["protocol", "final_acc", "rounds_to_target", "time_to_target"],
              summary)
    print(f"  wrote {path}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--scenario", default="paper-fig1",
                    choices=sorted(registry()))
    ap.add_argument("--engine", default="vectorized",
                    choices=["vectorized", "legacy"])
    a = ap.parse_args()
    run(rounds=a.rounds, quick=a.quick, scenario=a.scenario, engine=a.engine)

"""Nightly benchmark regression gate.

Compares freshly produced ``BENCH_sim_engine.json`` /
``BENCH_shard_scale.json`` / ``BENCH_serve.json`` /
``BENCH_transport.json`` / ``BENCH_population_scale.json`` /
``BENCH_ring_memory.json`` against the
COMMITTED baselines
(``git show
<ref>:<file>``) and exits non-zero on a real regression, so the nightly
lane goes red instead of silently uploading artifacts:

* throughput: any tracked events/sec figure dropping more than
  ``--threshold`` (default 20% — forced-host-device CPU numbers are
  noisy, real regressions are structural and large);
* speedup: the sim-engine vectorized/legacy ratio and the population
  engine's device/host-walk ratio — hardware-RELATIVE, so they stay
  meaningful even when the runner differs from the machine that
  produced the baseline;
* launch count: the engine's num_launches growing AT ALL (the
  O(T / rounds_per_launch) dispatch contract is exact, not statistical);
* memory ceiling: the population engine's peak-RSS growth across its
  N sweep exceeding 1.5x baseline + 64 MB (the flat-in-N host-memory
  contract, with slack for allocator jitter), and the compressed version
  store's per-device ring bytes per (model, codec) re-inflating past the
  committed quote (DESIGN.md §11).

Absolute events/sec baselines encode the hardware they were measured
on: when the ``meta`` provenance stamp (benchmarks/common.py) shows the
baseline and the fresh run used different backends or device kinds, the
comparison is REFUSED (skipped loudly with regeneration instructions)
instead of flagging a bogus hardware-delta "regression". After a
runner-class change, regenerate ``BENCH_*.json`` and commit it to
re-arm the gate.

Usage (the nightly job, after the benches rewrote the files in place):

    python -m benchmarks.check_regression            # baseline = HEAD
    python -m benchmarks.check_regression --baseline-ref origin/main
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

ROOT = os.path.join(os.path.dirname(__file__), "..")


def load_baseline(name: str, ref: str) -> Optional[dict]:
    """The committed version of ``name`` at ``ref`` (None if absent)."""
    try:
        blob = subprocess.run(
            ["git", "show", f"{ref}:{name}"], capture_output=True,
            text=True, cwd=ROOT, check=True).stdout
        return json.loads(blob)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


def load_fresh(name: str) -> Optional[dict]:
    path = os.path.join(ROOT, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _provenance(doc: dict) -> Tuple[Optional[str], Optional[str]]:
    """(backend, device_kind) for a bench doc, preferring the ``meta``
    stamp (benchmarks.common.run_metadata) over the legacy top-level
    ``backend`` key.  Backend strings are normalized to their first token
    so pre-stamp docs like ``"cpu (forced host devices; ...)"`` compare
    equal to the stamped ``"cpu"``."""
    meta = doc.get("meta") if isinstance(doc.get("meta"), dict) else {}
    backend = meta.get("backend") or doc.get("backend")
    if isinstance(backend, str) and backend:
        backend = backend.split()[0]
    else:
        backend = None
    kind = meta.get("device_kind")
    return backend, kind if isinstance(kind, str) else None


def backend_mismatch(base_doc: dict, fresh_doc: dict) -> Optional[str]:
    """Human-readable reason the two docs are NOT comparable (different
    backend or device kind), or None when comparison is meaningful.
    Fields missing on either side are not compared — old baselines
    without a ``meta`` stamp still gate on whatever they do record."""
    base_b, base_k = _provenance(base_doc)
    fresh_b, fresh_k = _provenance(fresh_doc)
    if base_b and fresh_b and base_b != fresh_b:
        return f"backend {base_b!r} (baseline) vs {fresh_b!r} (fresh)"
    if base_k and fresh_k and base_k != fresh_k:
        return f"device_kind {base_k!r} (baseline) vs {fresh_k!r} (fresh)"
    return None


def _get(d: dict, path: Tuple[str, ...]) -> Optional[float]:
    for key in path:
        if not isinstance(d, dict) or key not in d:
            return None
        d = d[key]
    return d  # type: ignore[return-value]


def sim_engine_metrics(doc: dict) -> Dict[str, float]:
    """Vectorized events/sec per workload, plus the vectorized/legacy
    speedup (hardware-relative: both sides ran on the same machine)."""
    out = {}
    for wname, rec in doc.get("workloads", {}).items():
        v = _get(rec, ("vectorized", "events_per_sec"))
        if v is not None:
            out[f"sim_engine/{wname}/events_per_sec"] = float(v)
        s = rec.get("speedup")
        if s is not None:
            out[f"sim_engine/{wname}/speedup"] = float(s)
    return out


def shard_scale_metrics(doc: dict) -> Dict[str, float]:
    out = {}
    for d, rec in doc.get("records", {}).items():
        v = _get(rec, ("engine", "events_per_sec"))
        if v is not None:
            out[f"shard_scale/D={d}/events_per_sec"] = float(v)
    return out


def serve_metrics(doc: dict) -> Dict[str, float]:
    """Sustained fold throughput per weighting policy (wall-clock; same
    -20% gate as the engines' events/sec figures)."""
    out = {}
    for policy, rec in doc.get("policies", {}).items():
        v = rec.get("uploads_per_sec")
        if v is not None:
            out[f"serve/{policy}/uploads_per_sec"] = float(v)
    return out


def transport_metrics(doc: dict) -> Dict[str, float]:
    """Socket-ingress throughput per (transport, codec, mode) row plus
    the int8/f32 wire-size ratio. Throughput rows ride the standard
    -20% gate; the byte ratio is deterministic (same payload, same
    codec), so a >20% drop there means the codec itself regressed."""
    out = {}
    for name, rec in doc.get("records", {}).items():
        v = rec.get("uploads_per_sec") if isinstance(rec, dict) else None
        if v is not None:
            out[f"transport/{name}/uploads_per_sec"] = float(v)
    r = doc.get("f32_over_int8_bytes")
    if r is not None:
        out["transport/f32_over_int8_bytes"] = float(r)
    return out


def shard_scale_launches(doc: dict) -> Dict[str, int]:
    out = {}
    for d, rec in doc.get("records", {}).items():
        v = _get(rec, ("engine", "num_launches"))
        if v is not None:
            out[f"shard_scale/D={d}/num_launches"] = int(v)
    return out


def population_metrics(doc: dict) -> Dict[str, float]:
    """Device events/sec per population size, plus the N=1e4 speedup over
    the host event walk (hardware-relative, like the sim-engine one)."""
    out = {}
    for n, rec in doc.get("records", {}).items():
        v = _get(rec, ("device", "events_per_sec"))
        if v is not None:
            out[f"population/N={n}/events_per_sec"] = float(v)
    s = doc.get("speedup_at_10k")
    if s is not None:
        out["population/speedup_at_10k"] = float(s)
    return out


def ring_memory_bytes(doc: dict) -> Dict[str, float]:
    """Per-device ring bytes per (model, codec) — gated as a CEILING:
    the compressed version store regresses when a codec re-inflates the
    ring (bytes are deterministic functions of the layout, so any real
    growth is a code change, not noise)."""
    out = {}
    for model, rec in doc.get("records", {}).items():
        if not isinstance(rec, dict):
            continue
        for codec, crec in rec.items():
            v = crec.get("bytes_per_device") if isinstance(crec, dict) \
                else None
            if v is not None:
                out[f"ring_memory/{model}/{codec}/bytes_per_device"] = \
                    float(v)
    return out


def population_rss(doc: dict) -> Dict[str, float]:
    """Peak-RSS growth across the device N sweep — gated as a CEILING:
    the flat-in-N host-memory contract regresses when it grows, not when
    it shrinks."""
    v = doc.get("rss_growth_mb")
    return {} if v is None else {"population/rss_growth_mb": float(v)}


def compare(fresh: Dict[str, float], base: Dict[str, float],
            threshold: float, mode: str = "throughput") -> List[str]:
    """Failure messages for every regressed metric present in BOTH.

    ``mode``: ``"throughput"`` fails on a >threshold DROP; ``"launches"``
    fails on ANY increase (the dispatch-count contract is exact);
    ``"ceiling"`` fails when the fresh value exceeds 1.5x baseline plus
    a 64-unit absolute slack (memory high-water marks jitter, so the
    ceiling is looser than the throughput gate but still catches an
    O(N) leak reappearing).
    """
    failures = []
    for key, base_v in sorted(base.items()):
        if key not in fresh:
            continue
        fresh_v = fresh[key]
        if mode == "launches":
            if fresh_v > base_v:
                failures.append(
                    f"{key}: {fresh_v} launches vs baseline {base_v} — the "
                    "dispatch-count contract regressed")
        elif mode == "ceiling":
            if fresh_v > 1.5 * base_v + 64.0:
                failures.append(
                    f"{key}: {fresh_v:.1f} vs baseline {base_v:.1f} "
                    f"(ceiling {1.5 * base_v + 64.0:.1f})")
        elif base_v > 0 and fresh_v < (1.0 - threshold) * base_v:
            failures.append(
                f"{key}: {fresh_v:.1f} vs baseline {base_v:.1f} "
                f"({fresh_v / base_v - 1.0:+.1%}, gate -{threshold:.0%})")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-ref", default="HEAD",
                    help="git ref holding the committed baseline files")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max tolerated events/sec drop (fraction)")
    ap.add_argument("--strict", action="store_true",
                    help="fail when a baseline or fresh file is missing "
                         "(default: skip that file with a note)")
    args = ap.parse_args()

    checks = (
        ("BENCH_sim_engine.json", sim_engine_metrics, "throughput"),
        ("BENCH_shard_scale.json", shard_scale_metrics, "throughput"),
        ("BENCH_shard_scale.json", shard_scale_launches, "launches"),
        ("BENCH_serve.json", serve_metrics, "throughput"),
        ("BENCH_transport.json", transport_metrics, "throughput"),
        ("BENCH_population_scale.json", population_metrics, "throughput"),
        ("BENCH_population_scale.json", population_rss, "ceiling"),
        ("BENCH_ring_memory.json", ring_memory_bytes, "ceiling"),
    )
    failures: List[str] = []
    missing = 0
    for name, extract, mode in checks:
        base_doc = load_baseline(name, args.baseline_ref)
        fresh_doc = load_fresh(name)
        if base_doc is None or fresh_doc is None:
            missing += 1
            which = "baseline" if base_doc is None else "fresh"
            print(f"[skip] {name}: no {which} copy "
                  f"({'fails' if args.strict else 'ignored'} "
                  f"under --strict)")
            continue
        mismatch = backend_mismatch(base_doc, fresh_doc)
        if mismatch:
            print(f"[skip] {name}: cross-backend comparison refused — "
                  f"{mismatch}. Absolute throughput is hardware-specific; "
                  "regenerate the committed baseline on this runner class "
                  f"(rerun the bench, commit {name}) to re-arm the gate.")
            continue
        base, fresh = extract(base_doc), extract(fresh_doc)
        errs = compare(fresh, base, args.threshold, mode=mode)
        tag = {"launches": "launches",
               "ceiling": "ceiling"}.get(mode, "events/sec")
        for key in sorted(set(base) & set(fresh)):
            print(f"  {key}: {base[key]:.1f} -> {fresh[key]:.1f}")
        if errs:
            failures.extend(errs)
        else:
            print(f"[ok]   {name} ({tag}): {len(set(base) & set(fresh))} "
                  "metrics within gate")
    if args.strict and missing:
        failures.append(f"{missing} baseline/fresh file(s) missing")
    if failures:
        print("\nREGRESSIONS:")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)
    print("\nno regressions")


if __name__ == "__main__":
    main()

"""Server aggregation pass scalability: time per CA-AFL server round vs
model size and buffer K (the memory-bound hot loop the weighted_agg kernel
targets). Demonstrates O(K*N) streaming cost and the staleness-distance
overhead of eq. (3) relative to plain FedBuff averaging.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn, write_csv
from repro.core.aggregation import aggregate
from repro.core.weighting import contribution_weights, staleness_degree
from repro.utils.pytree import tree_sq_dist


def _fake_params(n, key):
    return {"w": jax.random.normal(key, (n,))}


def run(quick: bool = False):
    key = jax.random.PRNGKey(0)
    sizes = [1 << 16, 1 << 20] if quick else [1 << 16, 1 << 20, 1 << 24]
    rows = []
    for n in sizes:
        for k in (4, 16):
            x = _fake_params(n, key)
            deltas = jax.tree.map(
                lambda l: jnp.stack([l * (i + 1) * 1e-3 for i in range(k)]), x)
            bases = [jax.tree.map(lambda l, i=i: l + 1e-2 * i, x)
                     for i in range(k)]

            @jax.jit
            def fedbuff_round(x, deltas):
                return aggregate(x, deltas, jnp.ones(k), 1.0, k)[0]

            @jax.jit
            def ca_round(x, deltas, bases_stacked, p):
                d = jax.vmap(lambda b: tree_sq_dist(x, b))(bases_stacked)
                s = staleness_degree(d)
                w = contribution_weights("paper", p, s, jnp.zeros(k))
                return aggregate(x, deltas, w, 1.0, k)[0]

            bases_stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *bases)
            p = jnp.abs(jax.random.normal(key, (k,))) + 0.5
            t_fb = time_fn(fedbuff_round, x, deltas, iters=3)
            t_ca = time_fn(ca_round, x, deltas, bases_stacked, p, iters=3)
            overhead = t_ca / t_fb
            rows.append([n, k, round(t_fb, 1), round(t_ca, 1),
                         round(overhead, 3)])
            print(f"  N={n:>9d} K={k:>3d} fedbuff={t_fb:>10.1f}us "
                  f"ca-afl={t_ca:>10.1f}us overhead=x{overhead:.2f}")
    path = write_csv("server_pass.csv",
                     ["params", "K", "fedbuff_us", "ca_afl_us", "overhead"],
                     rows)
    print(f"  wrote {path}")
    return rows


if __name__ == "__main__":
    run()

"""Server aggregation pass: seed looped-host vs device-resident passes.

The seed ``AsyncServer._do_aggregate`` ran a Python loop with a
``float()`` host sync per buffered entry for both the eq. 3 distance and
the eq. 4 probe — O(K) device<->host round-trips plus O(K) dispatches
per round. This benchmark reproduces that path faithfully ("looped") and
compares it against the single jitted server pass
(repro/core/server_pass.py):

  batched : one compiled program; eq. 3 / eq. 5 via the two weighted_agg
            Pallas kernels on TPU, the pure-jnp body elsewhere (Mosaic
            kernels need a TPU; interpret mode is validation-only).
  fused   : the one-launch two-phase kernel (TPU only).

Sweeps K in {4, 8, 16, 32} and model sizes from lenet_fmnist up. Writes
``results/bench/server_pass.csv`` and the acceptance artifact
``BENCH_server_pass.json`` at the repo root.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn, write_bench_json, write_csv
from repro.configs.base import FLConfig
from repro.core.aggregation import aggregate
from repro.core.server_pass import make_server_pass
from repro.core.weighting import (
    contribution_weights,
    staleness_degree,
    statistical_effect,
)
from repro.models.lenet import init_lenet, lenet_loss
from repro.utils.pytree import tree_count_params, tree_sq_dist, tree_stack

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KS = (4, 8, 16, 32)


def _vec_loss(params, batch):
    """Cheap probe loss for the synthetic flat models."""
    x, = batch
    return jnp.mean((params["w"][:256] * x) ** 2), {}


def _models(quick: bool):
    key = jax.random.PRNGKey(0)
    out = [("lenet_fmnist", init_lenet(key), lenet_loss,
            (jnp.zeros((8, 28, 28, 1)), jnp.zeros((8,), jnp.int32)))]
    sizes = [("mlp_1m", 1 << 20)] if quick else [("mlp_1m", 1 << 20),
                                                 ("mlp_16m", 1 << 24)]
    for name, n in sizes:
        out.append((name, {"w": jax.random.normal(key, (n,))}, _vec_loss,
                    (jax.random.normal(key, (256,)),)))
    return out


def _make_case(params, k):
    deltas = [jax.tree.map(
        lambda l, i=i: 1e-3 * (i + 1) * jnp.ones_like(l), params)
        for i in range(k)]
    bases = [jax.tree.map(lambda l, i=i: l + 1e-2 * i, params)
             for i in range(k)]
    sizes = jnp.linspace(10.0, 50.0, k)
    taus = jnp.arange(k, dtype=jnp.float32)
    return deltas, bases, sizes, taus


def _make_looped(fl, loss_fn):
    """The seed hot path: K host syncs for eq. 3 + K for eq. 4 per round."""
    _sq = jax.jit(tree_sq_dist)
    _fresh = jax.jit(lambda p, b: loss_fn(p, b)[0])
    _agg = jax.jit(lambda p, d, w, k: aggregate(p, d, w, fl.global_lr, k),
                   static_argnames=("k",))

    def round_fn(params, deltas, bases, probe, sizes, taus):
        k = len(deltas)
        dists = [float(_sq(params, b)) for b in bases]  # K host syncs
        s = staleness_degree(jnp.asarray(dists, jnp.float32))
        losses = [float(_fresh(params, probe)) for _ in range(k)]  # K more
        p = statistical_effect(jnp.asarray(losses, jnp.float32), sizes)
        w = contribution_weights(fl.weighting, p, s, taus, s_min=fl.s_min,
                                 poly_a=fl.poly_a, normalize=fl.normalize)
        new, _ = _agg(params, tree_stack(deltas), w, k)
        return new

    return round_fn


def run(quick: bool = False):
    backend = jax.default_backend()
    on_tpu = backend == "tpu"
    rows, json_rows = [], []
    for model_name, params, loss_fn, probe in _models(quick):
        n_params = tree_count_params(params)
        for k in KS:
            fl = FLConfig(buffer_size=k, weighting="paper")
            deltas, bases, sizes, taus = _make_case(params, k)
            looped = _make_looped(fl, loss_fn)
            t_looped = time_fn(looped, params, deltas, bases, probe, sizes,
                               taus, iters=3)

            deltas_st, bases_st = tree_stack(deltas), tree_stack(bases)
            probes_st = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), probe)
            mask = jnp.ones(k)

            def timed_pass(mode):
                pass_fn = make_server_pass(fl, lambda p, b: loss_fn(p, b)[0],
                                           mode=mode, interpret=False)
                return time_fn(pass_fn, params, deltas_st, bases_st,
                               probes_st, mask, sizes, taus, iters=3)

            batched_mode = "batched" if on_tpu else "reference"
            t_batched = timed_pass(batched_mode)
            t_fused = timed_pass("fused") if on_tpu else None

            sp_b = t_looped / t_batched
            sp_f = (t_looped / t_fused) if t_fused else None
            rows.append([model_name, n_params, k, round(t_looped, 1),
                         round(t_batched, 1),
                         round(t_fused, 1) if t_fused else "",
                         round(sp_b, 2), round(sp_f, 2) if sp_f else ""])
            json_rows.append({
                "model": model_name, "n_params": n_params, "K": k,
                "looped_us": t_looped, "batched_us": t_batched,
                "batched_mode": batched_mode,  # pure-jnp body off-TPU
                "fused_us": t_fused, "speedup_batched": sp_b,
                "speedup_fused": sp_f,
            })
            fused_str = f" fused={t_fused:>9.1f}us" if t_fused else ""
            print(f"  {model_name:>12s} N={n_params:>9d} K={k:>3d} "
                  f"looped={t_looped:>9.1f}us batched={t_batched:>9.1f}us"
                  f"{fused_str} speedup=x{sp_b:.2f}")

    path = write_csv("server_pass.csv",
                     ["model", "params", "K", "looped_us", "batched_us",
                      "fused_us", "speedup_batched", "speedup_fused"], rows)
    accept = [r for r in json_rows
              if r["model"] == "lenet_fmnist" and r["K"] == 16]
    payload = {
        "meta": {
            "backend": backend,
            "quick": quick,
            "note": ("batched = single jitted server pass (Pallas kernels "
                     "on TPU, XLA body elsewhere); fused = one-launch "
                     "two-phase kernel, TPU only; looped = seed host loop "
                     "with 2K syncs/round"),
        },
        "rows": json_rows,
        "acceptance": {
            "model": "lenet_fmnist", "K": 16,
            "mode": accept[0]["batched_mode"] if accept else None,
            "speedup_batched": accept[0]["speedup_batched"] if accept else None,
            "threshold": 2.0,
            "pass": bool(accept and accept[0]["speedup_batched"] >= 2.0),
        },
    }
    json_path = write_bench_json(
        os.path.join(ROOT, "BENCH_server_pass.json"), payload)
    print(f"  wrote {path}")
    print(f"  wrote {json_path} (K=16 lenet speedup "
          f"x{payload['acceptance']['speedup_batched']:.2f})")
    return rows


if __name__ == "__main__":
    run()

"""Transport-ingress benchmark: the socket path of the serving loop.

Stands up a real ``transport.AggregatorServer`` on loopback (in-process
listener threads, a separate fold thread running ``pump``) and hammers
it with concurrent ``RemoteAggregator`` clients pushing scenario-drawn
uploads, measuring what the §12 ingress is judged on:

* **sustained ingress uploads/sec** — offer rate through encode ->
  socket -> decode -> admission -> ack with the deep ingress queue
  absorbing the burst (folds drained after the measured window), so the
  figure isolates TRANSPORT capacity (the §12 gate: >= 1k/s on CPU
  loopback over framed TCP). The fold side's own wall-clock throughput
  is already gated separately by ``BENCH_serve.json``;
* **end-to-end serving uploads/sec** — the same stream with the fold
  thread running concurrently (acks contend with ``pump`` for the
  controller lock): the honest deployed figure, expected to track the
  in-process BENCH_serve ceiling — recorded, not gated here;
* **p99 offer-to-ack latency** — client-observed milliseconds from
  ``offer()`` entry to the admission ack;
* **rx bytes per upload, f32 vs int8** — the wire-codec payoff (the
  §12 gate: int8 offers >= 3x smaller than f32).

Rows: tcp/f32 ingress (the gated fast path), tcp/int8 ingress (codec
payoff at the same socket), http/f32 ingress (the CI smoke lane's
transport, expected slower — recorded so a collapse is visible, not
gated), tcp/f32 serving (concurrent folds). Results land in
``BENCH_transport.json`` (+ ``results/bench/transport.csv``).
"""
from __future__ import annotations

import os
import threading
import time

import jax
import numpy as np

from benchmarks.bench_sim_engine import logreg_init, logreg_loss
from benchmarks.common import write_bench_json, write_csv
from repro.configs.base import FLConfig
from repro.core.serving import ServeConfig, ServingController
from repro.sim import get_scenario
from repro.sim.arrivals import draw_upload
from repro.transport import wire
from repro.transport.client import RemoteAggregator
from repro.transport.server import AggregatorServer

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _controller(fl: FLConfig) -> ServingController:
    params = logreg_init(jax.random.PRNGKey(0))
    # deep queue + fixed K: the bench measures ingress, not backpressure
    cfg = ServeConfig(queue_capacity=8192, service_time=0.0,
                      adapt_every=0, k_min=2, k_max=64)
    return ServingController(logreg_loss, params, fl, cfg)


def _drive(transport: str, codec: str, clients, fl: FLConfig, *,
           n_client_threads: int, uploads_per_client: int,
           fold_concurrently: bool) -> dict:
    ctrl = _controller(fl)
    # warm the jit cache outside the measured window
    warm = draw_upload(clients[0], 0, fl, base_version=0, t=0.0)
    ctrl.offer(warm, 0.0)
    ctrl.pump(0.0)

    srv = AggregatorServer(ctrl, transport=transport)
    srv.start()
    folder = None
    if fold_concurrently:
        folder = threading.Thread(target=srv.serve,
                                  kwargs={"poll": 0.01}, daemon=True)
        folder.start()

    # pre-draw every payload so the measured loop is pure transport
    payloads = [[draw_upload(clients[c % len(clients)], c, fl,
                             base_version=0, t=0.0, seq=i)
                 for i in range(uploads_per_client)]
                for c in range(n_client_threads)]
    lat_ms = [[] for _ in range(n_client_threads)]
    barrier = threading.Barrier(n_client_threads + 1)

    def one_client(c: int) -> None:
        svc = RemoteAggregator("127.0.0.1", srv.port, transport=transport,
                               codec=codec, seed=c)
        try:
            barrier.wait()
            for up in payloads[c]:
                t0 = time.perf_counter()
                adm = svc.offer(up, 0.0)
                lat_ms[c].append(1e3 * (time.perf_counter() - t0))
                assert adm.accepted, adm
        finally:
            svc.close()

    threads = [threading.Thread(target=one_client, args=(c,))
               for c in range(n_client_threads)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    if folder is None:  # ingress mode: drain the queue off the clock
        t1 = time.perf_counter()
        ctrl.pump(1e18)
        drain = time.perf_counter() - t1
    else:
        drain = 0.0
    srv.shutdown()
    if folder is not None:
        folder.join(timeout=10)

    total = n_client_threads * uploads_per_client
    lat = np.sort(np.concatenate([np.asarray(l) for l in lat_ms]))
    frame = wire.encode_message("offer", *payloads[0][0].to_wire(),
                                codec=codec)
    return {
        "transport": transport, "codec": codec,
        "mode": "serving" if fold_concurrently else "ingress",
        "clients": n_client_threads, "uploads": total,
        "seconds": dt,
        "uploads_per_sec": total / dt,
        "drain_seconds": drain,
        "offer_ack_p50_ms": float(lat[len(lat) // 2]),
        "offer_ack_p99_ms": float(lat[min(len(lat) - 1,
                                          int(0.99 * len(lat)))]),
        "rx_bytes_per_upload": len(frame),
        "folded": ctrl.counters["folded"],
        "rounds": ctrl.counters["rounds"],
    }


def run(quick: bool = False):
    n_threads, per_client = (2, 100) if quick else (4, 400)
    fl = FLConfig(num_clients=8, buffer_size=8, max_staleness=1_000_000,
                  local_steps=1, batch_size=8)
    sc = get_scenario("paper-fig1")
    clients, _ = sc.make_dataset(8, samples_per_client=64, seed=0)

    rows, record = [], {}
    cases = (("tcp", "f32", False), ("tcp", "int8", False),
             ("http", "f32", False), ("tcp", "f32", True))
    for transport, codec, folding in cases:
        r = _drive(transport, codec, clients, fl,
                   n_client_threads=n_threads,
                   uploads_per_client=per_client,
                   fold_concurrently=folding)
        record[f"{transport}_{codec}_{r['mode']}"] = r
        rows.append([transport, codec, r["mode"], r["uploads"],
                     round(r["seconds"], 3),
                     round(r["uploads_per_sec"], 1),
                     round(r["offer_ack_p50_ms"], 3),
                     round(r["offer_ack_p99_ms"], 3),
                     r["rx_bytes_per_upload"]])
        print(f"  {transport}/{codec}/{r['mode']:7s} {r['uploads']} "
              f"uploads in {r['seconds']:.2f}s -> "
              f"{r['uploads_per_sec']:.0f}/s, "
              f"ack p99 {r['offer_ack_p99_ms']:.2f}ms, "
              f"{r['rx_bytes_per_upload']} B/upload")

    ratio = (record["tcp_f32_ingress"]["rx_bytes_per_upload"]
             / record["tcp_int8_ingress"]["rx_bytes_per_upload"])
    print(f"  int8 offer frames {ratio:.2f}x smaller than f32 "
          f"(gate >= 3x); tcp/f32 ingress sustained "
          f"{record['tcp_f32_ingress']['uploads_per_sec']:.0f} uploads/s "
          "(gate >= 1k/s on CPU loopback)")

    out = {
        "bench": "transport",
        "backend": jax.default_backend(),
        "records": record,
        "uploads_per_sec": record["tcp_f32_ingress"]["uploads_per_sec"],
        "serving_uploads_per_sec":
            record["tcp_f32_serving"]["uploads_per_sec"],
        "offer_ack_p99_ms": record["tcp_f32_ingress"]["offer_ack_p99_ms"],
        "f32_over_int8_bytes": ratio,
    }
    path = write_bench_json(os.path.join(ROOT, "BENCH_transport.json"), out)
    write_csv("transport.csv",
              ["transport", "codec", "mode", "uploads", "seconds",
               "uploads_per_sec", "offer_ack_p50_ms", "offer_ack_p99_ms",
               "rx_bytes_per_upload"], rows)
    print(f"  wrote {path}")
    return out


if __name__ == "__main__":
    run()

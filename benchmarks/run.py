"""Benchmark harness entry point — one benchmark per paper table/figure.

  fig1        paper Fig. 1: convergence comparison (THE reproduction)
  ablation    weighting-policy x normalisation table (resolves eq.-5 reading)
  kernels     Pallas kernel microbenches (name,us_per_call,derived CSV)
  server      CA-AFL server-pass scalability vs FedBuff
  sim_engine  simulator throughput: legacy event loop vs vectorized engine
  shard_scale sharded round substrate: device-count sweep (forced-host CPU)
  population_scale  device-resident population engine: N sweep to 1e6 clients
  serve       always-on serving loop: sustained uploads/sec, p99 round latency
  transport   socket ingress: loopback uploads/sec, offer-to-ack p99, wire bytes
  ring_memory compressed version store: codec x model ring-bytes sweep
  roofline    §Roofline table from the dry-run artifacts (analytic terms)

``python -m benchmarks.run`` runs everything in quick mode (CPU-friendly);
``--full`` uses the paper-scale settings; ``--only <name>`` selects one.
"""
from __future__ import annotations

import argparse
import sys
import time


KNOWN = ("fig1", "ablation", "buffer_k", "kernels", "server", "sim_engine",
         "shard_scale", "population_scale", "serve", "transport",
         "ring_memory", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, choices=KNOWN,
                    help="run one benchmark (a typo used to silently "
                         "select NOTHING and exit 0 — now an error)")
    args = ap.parse_args()
    quick = not args.full

    jobs = []
    if args.only in (None, "fig1"):
        from benchmarks import bench_fig1_convergence
        jobs.append(("fig1_convergence (paper Fig. 1)",
                     lambda: bench_fig1_convergence.run(quick=quick)))
    if args.only in (None, "ablation"):
        from benchmarks import bench_weighting_ablation
        jobs.append(("weighting_ablation",
                     lambda: bench_weighting_ablation.run(quick=quick)))
    if args.only in (None, "buffer_k"):
        from benchmarks import bench_buffer_k
        jobs.append(("buffer_k_sweep",
                     lambda: bench_buffer_k.run(quick=quick)))
    if args.only in (None, "kernels"):
        from benchmarks import bench_kernels
        jobs.append(("kernels", lambda: bench_kernels.run(quick=quick)))
    if args.only in (None, "server"):
        from benchmarks import bench_server_pass
        jobs.append(("server_pass", lambda: bench_server_pass.run(quick=quick)))
    if args.only in (None, "sim_engine"):
        from benchmarks import bench_sim_engine
        jobs.append(("sim_engine (legacy loop vs vectorized)",
                     lambda: bench_sim_engine.run(quick=quick)))
    if args.only in (None, "shard_scale"):
        from benchmarks import bench_shard_scale
        jobs.append(("shard_scale (mesh-sharded round substrate)",
                     lambda: bench_shard_scale.run(quick=quick)))
    if args.only in (None, "population_scale"):
        from benchmarks import bench_population_scale
        jobs.append(("population_scale (device event machine vs host walk)",
                     lambda: bench_population_scale.run(quick=quick)))
    if args.only in (None, "serve"):
        from benchmarks import bench_serve
        jobs.append(("serve (always-on serving loop)",
                     lambda: bench_serve.run(quick=quick)))
    if args.only in (None, "transport"):
        from benchmarks import bench_transport
        jobs.append(("transport (socket serving ingress)",
                     lambda: bench_transport.run(quick=quick)))
    if args.only in (None, "ring_memory"):
        from benchmarks import bench_ring_memory
        jobs.append(("ring_memory (compressed version store)",
                     lambda: bench_ring_memory.run(quick=quick)))
    if args.only in (None, "roofline"):
        from benchmarks import roofline
        jobs.append(("roofline", roofline.main))

    failures = []
    for name, fn in jobs:
        print(f"\n=== {name} ===")
        t0 = time.time()
        try:
            fn()
        except SystemExit as e:
            # a sub-benchmark calling sys.exit() must neither abort the
            # remaining benches nor (worse) exit THIS harness with 0
            code = (0 if e.code is None
                    else e.code if isinstance(e.code, int) else 1)
            if code:
                failures.append(name)
                print(f"--- {name} FAILED: sys.exit({e.code})")
            else:
                print(f"--- {name} done in {time.time() - t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"--- {name} FAILED: {type(e).__name__}: {e}")
        else:
            print(f"--- {name} done in {time.time() - t0:.1f}s")
    if failures:
        print(f"\nFAILED benchmarks: {', '.join(failures)}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

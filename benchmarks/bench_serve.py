"""Serving-loop benchmark: sustained ingest of the always-on controller.

Drives ``core/serving.py`` with the ``paper-fig1`` scenario as an
in-process traffic generator (the same seeded per-client timelines the
simulation engines replay) and measures what a deployed aggregation
endpoint is judged on:

* **uploads/sec** — wall-clock rate at which the controller folds
  admitted uploads through the jitted streaming ``contribute`` (the
  serving-side analogue of the engines' events/sec, gated by
  ``check_regression.py``);
* **p99 round latency** — sim-time from the first fold of a round to its
  eq. 5 apply, the quantity the adaptive-K controller steers toward
  ``target_round_latency``;
* **admission counters** — queue-full rejections and staleness drops
  under a deliberately under-provisioned "burst" record, proving the
  backpressure path costs what it should.

One record per weighting policy (paper / fedbuff / the FedAsync
discount family) so a policy-specific slowdown in the weighting branch
shows up here, not in production. Results land in ``BENCH_serve.json``
(+ ``results/bench/serve.csv``).
"""
from __future__ import annotations

import os
import time

import jax

from benchmarks.bench_sim_engine import logreg_init, logreg_loss
from benchmarks.common import write_bench_json, write_csv
from repro.configs.base import FLConfig
from repro.core.serving import ServeConfig, ServingController, serve_stream
from repro.sim import get_scenario
from repro.sim.arrivals import TrafficGenerator

ROOT = os.path.join(os.path.dirname(__file__), "..")

POLICIES = ("paper", "fedbuff", "fedasync_constant", "fedasync_hinge",
            "fedasync_poly")


def _drive(policy: str, clients, sc, *, num_clients: int, rounds: int,
           cfg: ServeConfig, max_staleness: int = 12) -> dict:
    fl = FLConfig(num_clients=num_clients, buffer_size=8,
                  max_staleness=max_staleness, local_steps=1, batch_size=8,
                  weighting=policy)
    params = logreg_init(jax.random.PRNGKey(0))
    # warmup run compiles contribute/apply outside the measured window
    warm = ServingController(logreg_loss, params, fl, cfg)
    serve_stream(warm, TrafficGenerator(clients, sc.behavior(
        num_clients, seed=0), fl), max_rounds=2)

    ctrl = ServingController(logreg_loss, params, fl, cfg)
    gen = TrafficGenerator(clients, sc.behavior(num_clients, seed=0), fl)
    t0 = time.perf_counter()
    out = serve_stream(ctrl, gen, max_rounds=rounds)
    dt = time.perf_counter() - t0
    out["seconds"] = dt
    out["uploads_per_sec"] = out["folded"] / dt
    return out


def run(num_clients: int = 32, rounds: int = 24, samples_per_client: int = 64,
        quick: bool = False):
    if quick:
        num_clients, rounds = 16, 8
    sc = get_scenario("paper-fig1")
    clients, _ = sc.make_dataset(num_clients,
                                 samples_per_client=samples_per_client,
                                 seed=0)

    steady = ServeConfig(queue_capacity=64, service_time=0.0,
                         target_round_latency=2.0, k_min=2, k_max=64,
                         adapt_every=4)
    rows, record = [], {}
    for policy in POLICIES:
        r = _drive(policy, clients, sc, num_clients=num_clients,
                   rounds=rounds, cfg=steady)
        record[policy] = r
        rows.append([policy, num_clients, r["rounds"], r["folded"],
                     round(r["seconds"], 3), round(r["uploads_per_sec"], 1),
                     round(r["round_latency_p99"], 3), r["k"]])
        print(f"  {policy:18s} {r['folded']} uploads in {r['seconds']:.2f}s "
              f"-> {r['uploads_per_sec']:.1f} uploads/s, "
              f"p99 round latency {r['round_latency_p99']:.2f}s "
              f"(sim), K -> {r['k']}")

    # under-provisioned endpoint: service slower than arrivals, tiny queue
    burst_cfg = ServeConfig(queue_capacity=4, service_time=0.4,
                            adapt_every=0, retry_after_min=0.2)
    burst = _drive("paper", clients, sc, num_clients=num_clients,
                   rounds=max(2, rounds // 4), cfg=burst_cfg,
                   max_staleness=4)
    print(f"  burst (under-provisioned): "
          f"{burst['rejected_queue_full']} queue-full rejections, "
          f"{burst['dropped_stale_ingress'] + burst['dropped_stale_queue']} "
          f"staleness drops, queue depth max {burst['queue_depth_max']}")

    out = {
        "bench": "serve",
        "backend": jax.default_backend(),
        "num_clients": num_clients, "rounds": rounds,
        "scenario": sc.name,
        "policies": record,
        "burst": burst,
        "uploads_per_sec": record["paper"]["uploads_per_sec"],
        "round_latency_p99": record["paper"]["round_latency_p99"],
    }
    path = write_bench_json(os.path.join(ROOT, "BENCH_serve.json"), out)
    write_csv("serve.csv",
              ["policy", "num_clients", "rounds", "uploads", "seconds",
               "uploads_per_sec", "round_latency_p99", "k_final"], rows)
    print(f"  wrote {path}")
    return out


if __name__ == "__main__":
    run()

"""Ablation table: weighting policy x normalisation (+ s_min sensitivity).

The paper's eq. (5) ambiguity (DESIGN.md §1.1) is resolved empirically:
ca-afl 'paper' (divide by S) vs 'multiplicative' (multiply by S) vs the
baselines, under any named client-behavior scenario (default the paper's
``paper-fig1``; pass ``--scenario dropout-bernoulli`` etc. — every
variant sees identical per-client timelines). Also ablates the
fresh-loss probe (P_i=1) to isolate each factor's contribution.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import write_csv
from repro.configs.base import FLConfig
from repro.core import run_async
from repro.models.lenet import apply_lenet, init_lenet, lenet_loss
from repro.sim import get_scenario, registry


def run(rounds: int = 25, num_clients: int = 16, quick: bool = False,
        scenario: str = "paper-fig1", engine: str = "vectorized"):
    if quick:
        rounds, num_clients = 10, 8
    sc = get_scenario(scenario)
    clients, (xt, yt) = sc.make_dataset(num_clients, samples_per_client=400,
                                        seed=1, noise=1.2)
    params = init_lenet(jax.random.PRNGKey(1))
    xt, yt = xt[:512], yt[:512]
    ev = jax.jit(lambda p: jnp.mean(
        (jnp.argmax(apply_lenet(p, xt), -1) == yt).astype(jnp.float32)))
    eval_fn = lambda p: {"acc": float(ev(p))}

    variants = []
    for policy in ("paper", "multiplicative", "fedbuff", "polynomial"):
        for norm in ("mean", "none"):
            if policy == "fedbuff" and norm == "none":
                continue
            variants.append((f"{policy}/{norm}", dict(weighting=policy,
                                                      normalize=norm)))
    rows = []
    for name, kw in variants:
        fl = FLConfig(num_clients=num_clients, buffer_size=max(4, num_clients // 3),
                      local_steps=4, local_lr=0.05, batch_size=32, **kw)
        res = run_async(lenet_loss, params, clients, fl, total_rounds=rounds,
                        eval_fn=eval_fn, eval_every=rounds, scenario=sc,
                        seed=1, engine=engine)
        acc = res.history[-1]["acc"]
        rows.append([name, round(acc, 4), res.server_rounds,
                     round(res.sim_time, 2)])
        print(f"  {name:24s} final_acc={acc:.4f}")
    path = write_csv("weighting_ablation.csv",
                     ["variant", "final_acc", "rounds", "sim_time"], rows)
    print(f"  wrote {path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scenario", default="paper-fig1",
                    choices=sorted(registry()))
    ap.add_argument("--engine", default="vectorized",
                    choices=["vectorized", "legacy"])
    a = ap.parse_args()
    run(quick=a.quick, scenario=a.scenario, engine=a.engine)

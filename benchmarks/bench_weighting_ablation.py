"""Ablation table: weighting policy x normalisation (+ s_min sensitivity).

The paper's eq. (5) ambiguity (DESIGN.md §1.1) is resolved empirically:
ca-afl 'paper' (divide by S) vs 'multiplicative' (multiply by S) vs the
baselines, same seeds/latency. Also ablates the fresh-loss probe (P_i=1)
to isolate each factor's contribution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import write_csv
from repro.configs.base import FLConfig
from repro.core import LatencyModel, run_async
from repro.data import make_federated_image_dataset
from repro.models.lenet import apply_lenet, init_lenet, lenet_loss


def run(rounds: int = 25, num_clients: int = 16, quick: bool = False):
    if quick:
        rounds, num_clients = 10, 8
    clients, (xt, yt) = make_federated_image_dataset(
        num_clients=num_clients, samples_per_client=400, alpha=0.2, noise=1.2,
        seed=1)
    params = init_lenet(jax.random.PRNGKey(1))
    xt, yt = xt[:512], yt[:512]
    ev = jax.jit(lambda p: jnp.mean(
        (jnp.argmax(apply_lenet(p, xt), -1) == yt).astype(jnp.float32)))
    eval_fn = lambda p: {"acc": float(ev(p))}
    latency = LatencyModel.heterogeneous(num_clients, max_slowdown=8.0, seed=1)

    variants = []
    for policy in ("paper", "multiplicative", "fedbuff", "polynomial"):
        for norm in ("mean", "none"):
            if policy == "fedbuff" and norm == "none":
                continue
            variants.append((f"{policy}/{norm}", dict(weighting=policy,
                                                      normalize=norm)))
    rows = []
    for name, kw in variants:
        fl = FLConfig(num_clients=num_clients, buffer_size=max(4, num_clients // 3),
                      local_steps=4, local_lr=0.05, batch_size=32, **kw)
        res = run_async(lenet_loss, params, clients, fl, total_rounds=rounds,
                        eval_fn=eval_fn, eval_every=rounds, latency=latency,
                        seed=1)
        acc = res.history[-1]["acc"]
        rows.append([name, round(acc, 4), res.server_rounds,
                     round(res.sim_time, 2)])
        print(f"  {name:24s} final_acc={acc:.4f}")
    path = write_csv("weighting_ablation.csv",
                     ["variant", "final_acc", "rounds", "sim_time"], rows)
    print(f"  wrote {path}")
    return rows


if __name__ == "__main__":
    run()

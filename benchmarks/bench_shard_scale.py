"""Sharded round substrate: device-count sweep on forced-host-device CPU.

Each device count D runs in its OWN subprocess (XLA must see
``--xla_force_host_platform_device_count=D`` before jax imports) and
measures two things on a (data=1, model=D) mesh from
``launch/mesh.make_round_mesh``:

* ``server_pass``: the flat-vector eq. 3+5 round (K buffered updates,
  ~2^20-param vector) as one jitted program — us/round for the sharded
  ``shard_map`` pass vs the single-device pass in the same process, so
  the psum + partition overhead is visible directly.
* ``engine``: ``run_vectorized`` end-to-end with ``mesh=``, reporting
  events/sec and ``num_launches`` — the launch count must stay
  O(T / rounds_per_launch) REGARDLESS of D (scale-out adds devices, not
  dispatches; that's the substrate's contract).

Forced host devices carve one CPU into D slices, so this measures the
SPMD program structure (collective count, launch count, partition
overhead) rather than real speedup — on a TPU pod the same program gets
D memory systems instead of one. Numbers land in
``BENCH_shard_scale.json`` + ``results/bench/shard_scale.csv``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# worker: runs under one forced device count
# ---------------------------------------------------------------------------


def _worker(devices: int, quick: bool) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import time_fn
    from repro.configs.base import FLConfig
    from repro.core.server_pass import (
        apply_server_round,
        flatten_tree,
        make_flat_spec,
    )
    from repro.launch.mesh import make_round_mesh
    from repro.sim import get_scenario
    from repro.sim.engine import run_vectorized

    assert len(jax.devices()) >= devices, (len(jax.devices()), devices)
    mesh = make_round_mesh(data=1, model=devices) if devices > 1 else None
    fl = FLConfig(weighting="paper")
    out = {"devices": devices, "jax_devices": len(jax.devices())}

    # --- flat server pass: K buffered updates on an n-param vector -------
    k, n = 16, (1 << 18 if quick else 1 << 20)
    params = {"w": jnp.zeros((n,), jnp.float32)}
    spec = make_flat_spec(params, 0, mesh=mesh)
    key = jax.random.PRNGKey(0)
    x = flatten_tree(spec, params)
    bases = 0.1 * jax.random.normal(key, (k, spec.n_padded), jnp.float32)
    deltas = 0.01 * jax.random.normal(jax.random.fold_in(key, 1),
                                      (k, spec.n_padded), jnp.float32)
    losses = jnp.linspace(0.5, 2.0, k)
    sizes = jnp.linspace(10.0, 50.0, k)
    taus = jnp.arange(k, dtype=jnp.float32)

    def make_pass(mesh_, block):
        @jax.jit
        def f(x, bases, deltas, losses, sizes, taus):
            new_x, info = apply_server_round(
                x, bases, deltas, losses, sizes, taus, fl,
                mode="reference", block_n=block, interpret=True, mesh=mesh_)
            return new_x, info["weights"]
        return f

    args = (x, bases, deltas, losses, sizes, taus)
    out["server_pass_us"] = time_fn(make_pass(mesh, spec.block_n), *args,
                                    iters=7, warmup=2)
    if mesh is not None:  # in-process single-device baseline for the delta
        out["server_pass_single_us"] = time_fn(
            make_pass(None, spec.block_n), *args, iters=7, warmup=2)

    # --- engine end-to-end: launch count must not grow with D ------------
    sc = get_scenario("paper-fig1")
    clients, _ = sc.make_dataset(32, samples_per_client=64, seed=0)
    efl = FLConfig(num_clients=32, buffer_size=8, local_steps=1,
                   local_lr=0.05, batch_size=8)
    rounds = 4 if quick else 8

    def logreg_loss(p, batch):
        bx, by = batch
        bx = bx.reshape(bx.shape[0], -1)
        logp = jax.nn.log_softmax(bx @ p["w"] + p["b"])
        return -jnp.mean(jnp.take_along_axis(
            logp, by[:, None].astype(jnp.int32), axis=1)), {}

    ep = {"w": jax.random.normal(key, (784, 10)) * 0.05, "b": jnp.zeros(10)}
    import time as _t
    run_vectorized(logreg_loss, ep, clients, efl, total_rounds=rounds,
                   scenario=sc, seed=0, mesh=mesh)  # warmup/compile
    t0 = _t.perf_counter()
    res = run_vectorized(logreg_loss, ep, clients, efl, total_rounds=rounds,
                         scenario=sc, seed=0, mesh=mesh)
    dt = _t.perf_counter() - t0
    out["engine"] = {"rounds": res.server_rounds, "events": res.num_events,
                     "events_per_sec": res.num_events / dt,
                     "num_launches": res.num_launches, "seconds": dt}

    # flat-sharded version ring footprint (DESIGN.md §6) on the
    # server-pass-sized model: R retained versions cost
    # R * n_padded / model_shards floats per device, not R full replicas
    from repro.sim.engine import init_version_ring
    rspec, ring = init_version_ring(params, fl, mesh=mesh)
    per_dev = (max(sh.data.nbytes for sh in ring.addressable_shards)
               if mesh is not None else ring.nbytes)
    out["ring_bytes"] = {
        "per_device": per_dev,
        "replicated_equivalent": (fl.max_staleness + 1) * rspec.n_padded * 4,
    }
    print(json.dumps(out))


# ---------------------------------------------------------------------------
# parent: sweep device counts, one subprocess each
# ---------------------------------------------------------------------------


def run(quick: bool = False, device_counts=(1, 2, 4, 8)):
    from benchmarks.common import write_bench_json, write_csv

    records = {}
    for d in device_counts:
        env = dict(os.environ)
        env.update({
            "JAX_PLATFORMS": "cpu",
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={d}",
            "PYTHONPATH": os.pathsep.join(
                [os.path.join(ROOT, "src"), ROOT,
                 env.get("PYTHONPATH", "")]).rstrip(os.pathsep),
        })
        cmd = [sys.executable, os.path.abspath(__file__), "--worker",
               "--devices", str(d)]
        if quick:
            cmd.append("--quick")
        proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                              timeout=1800)
        if proc.returncode != 0:
            raise RuntimeError(f"worker D={d} failed:\n{proc.stderr[-2000:]}")
        records[str(d)] = json.loads(proc.stdout.strip().splitlines()[-1])
        r = records[str(d)]
        print(f"  D={d}: server_pass {r['server_pass_us']:.0f}us/round, "
              f"engine {r['engine']['events_per_sec']:.1f} events/s, "
              f"{r['engine']['num_launches']} launches")

    base = records[str(device_counts[0])]
    launches = {d: records[str(d)]["engine"]["num_launches"]
                for d in device_counts}
    assert len(set(launches.values())) == 1, launches  # the contract
    rows = [[d, round(records[str(d)]["server_pass_us"], 1),
             round(records[str(d)]["engine"]["events_per_sec"], 1),
             records[str(d)]["engine"]["num_launches"]]
            for d in device_counts]
    out = {
        "bench": "shard_scale",
        "backend": "cpu (forced host devices; measures program structure, "
                   "not speedup)",
        "device_counts": list(device_counts),
        "k": 16, "n_params": (1 << 18) if quick else (1 << 20),
        "records": records,
        "launch_count_invariant": launches[device_counts[0]],
        "ring_bytes_per_device": {
            str(d): records[str(d)]["ring_bytes"]["per_device"]
            for d in device_counts},
        "server_pass_us_vs_single": {
            str(d): records[str(d)]["server_pass_us"]
            / base["server_pass_us"] for d in device_counts},
    }
    path = write_bench_json(os.path.join(ROOT, "BENCH_shard_scale.json"), out)
    write_csv("shard_scale.csv",
              ["devices", "server_pass_us", "engine_events_per_sec",
               "num_launches"], rows)
    print(f"  launch count invariant across D: {launches}")
    print(f"  wrote {path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.worker:
        _worker(args.devices, args.quick)
    else:
        run(quick=args.quick)


if __name__ == "__main__":
    main()

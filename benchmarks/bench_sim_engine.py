"""Simulation-engine throughput: legacy per-event loop vs vectorized engine.

Same workload on both sides — N=64 heterogeneous clients under the
``paper-fig1`` scenario — measuring simulated upload events per
wall-clock second. The legacy loop dispatches one jitted ``local_update``
plus one ``AsyncServer.receive`` per event (O(K) launches and a round-log
sync per round); the engine pre-computes windows on the host and drives
``rounds_per_launch`` whole rounds through one ``lax.scan`` launch,
syncing the log once per run.

The headline workload is softmax regression on the 28x28 synthetic
images — the model scale at which FL *simulation* sweeps (scenarios x
protocols x seeds) actually run, where per-event dispatch overhead
dominates and the engine's O(T/S) launches pay off (gate: >= 3x
events/sec at N=64, recorded in ``BENCH_sim_engine.json``). MLP and
LeNet workloads are recorded alongside for honesty: as per-client
compute grows, the advantage shrinks toward the vmap-vs-sequential
compute ratio (per-client weights keep XLA from merging the K convs
into one big one), so conv workloads land near parity on CPU.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import write_bench_json, write_csv
from repro.configs.base import FLConfig
from repro.core import run_async_legacy, run_vectorized
from repro.models.lenet import init_lenet, lenet_loss
from repro.sim import get_scenario

ROOT = os.path.join(os.path.dirname(__file__), "..")


def logreg_init(key, d=784, c=10):
    return {"w": jax.random.normal(key, (d, c)) * 0.05, "b": jnp.zeros(c)}


def logreg_loss(params, batch):
    x, y = batch
    x = x.reshape(x.shape[0], -1)
    logp = jax.nn.log_softmax(x @ params["w"] + params["b"])
    nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32),
                                        axis=1))
    return nll, {}


def mlp_init(key, d=784, h=64, c=10):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (d, h)) * 0.05,
            "b1": jnp.zeros(h),
            "w2": jax.random.normal(k2, (h, c)) * 0.05,
            "b2": jnp.zeros(c)}


def mlp_loss(params, batch):
    x, y = batch
    x = x.reshape(x.shape[0], -1)
    z = jnp.tanh(x @ params["w1"] + params["b1"]) @ params["w2"] + params["b2"]
    logp = jax.nn.log_softmax(z)
    nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32),
                                        axis=1))
    return nll, {}


def _measure(runner, loss_fn, params, clients, fl, rounds, sc, **kw):
    # warmup at the measured shape compiles local_update / the scan chunk
    runner(loss_fn, params, clients, fl, total_rounds=rounds, scenario=sc,
           seed=0, **kw)
    t0 = time.perf_counter()
    res = runner(loss_fn, params, clients, fl, total_rounds=rounds,
                 scenario=sc, seed=0, **kw)
    dt = time.perf_counter() - t0
    return {"events_per_sec": res.num_events / dt, "seconds": dt,
            "events": res.num_events, "rounds": res.server_rounds}


def run(num_clients: int = 64, buffer_k: int = 16, rounds: int = 16,
        samples_per_client: int = 64, quick: bool = False):
    if quick:
        rounds = 8
    sc = get_scenario("paper-fig1")
    clients, _ = sc.make_dataset(num_clients,
                                 samples_per_client=samples_per_client,
                                 seed=0)
    fl = FLConfig(num_clients=num_clients, buffer_size=buffer_k,
                  local_steps=1, local_lr=0.05, batch_size=8)
    workloads = {
        "logreg": (logreg_loss, logreg_init(jax.random.PRNGKey(0))),
        "mlp": (mlp_loss, mlp_init(jax.random.PRNGKey(0))),
        "lenet": (lenet_loss, init_lenet(jax.random.PRNGKey(0))),
    }
    if quick:
        workloads.pop("lenet")
        workloads.pop("mlp")

    rows, record = [], {}
    for wname, (loss_fn, params) in workloads.items():
        record[wname] = {}
        for ename, runner, kw in (
                ("legacy", run_async_legacy, {}),
                ("vectorized", run_vectorized,
                 {"rounds_per_launch": rounds})):
            r = _measure(runner, loss_fn, params, clients, fl, rounds, sc,
                         **kw)
            record[wname][ename] = r
            rows.append([wname, ename, num_clients, buffer_k, rounds,
                         r["events"], round(r["seconds"], 3),
                         round(r["events_per_sec"], 1)])
            print(f"  {wname:6s} {ename:10s} {r['events']} events in "
                  f"{r['seconds']:.2f}s -> {r['events_per_sec']:.1f} events/s")
        record[wname]["speedup"] = (
            record[wname]["vectorized"]["events_per_sec"]
            / record[wname]["legacy"]["events_per_sec"])
        print(f"  {wname:6s} speedup: {record[wname]['speedup']:.2f}x")

    speedup = record["logreg"]["speedup"]
    print(f"  headline (logreg, dispatch-bound): {speedup:.2f}x "
          "(gate: >= 3x at N=64)")
    out = {
        "bench": "sim_engine",
        "backend": jax.default_backend(),
        "num_clients": num_clients, "buffer_k": buffer_k, "rounds": rounds,
        "local_steps": fl.local_steps, "batch_size": fl.batch_size,
        "scenario": sc.name,
        "workloads": record,
        "legacy": record["logreg"]["legacy"],
        "vectorized": record["logreg"]["vectorized"],
        "speedup": speedup,
    }
    path = write_bench_json(os.path.join(ROOT, "BENCH_sim_engine.json"), out)
    write_csv("sim_engine.csv",
              ["workload", "engine", "num_clients", "buffer_k", "rounds",
               "events", "seconds", "events_per_sec"], rows)
    print(f"  wrote {path}")
    return out


if __name__ == "__main__":
    run()

"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp oracle vs the
model's XLA path. On CPU the interpret-mode timing is NOT a TPU projection —
the derived column reports the analytic HBM bytes each kernel streams,
which is what the TPU roofline uses. CSV: name,us_per_call,derived.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn, write_csv
from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.ssm_scan.ops import selective_scan
from repro.kernels.weighted_agg.ops import sq_dists, weighted_sum


def run(quick: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)

    # --- weighted_agg: K=16 clients, 8M-param shard -----------------------
    k, n = 16, (1 << 20 if quick else 1 << 23)
    d = jax.random.normal(key, (k, n))
    w = jnp.abs(jax.random.normal(key, (k,)))
    for name, fn in [
        ("weighted_sum/xla", lambda: weighted_sum(d, w, use_kernel=False)),
        ("weighted_sum/pallas-interp", lambda: weighted_sum(d, w, interpret=True)),
        ("sq_dists/xla", lambda: sq_dists(d[0], d, use_kernel=False)),
        ("sq_dists/pallas-interp", lambda: sq_dists(d[0], d, interpret=True)),
    ]:
        us = time_fn(fn, iters=3, warmup=1)
        bytes_streamed = k * n * 4
        rows.append([name, round(us, 1), f"hbm_bytes={bytes_streamed}"])

    # --- flash attention --------------------------------------------------
    s = 512 if quick else 1024
    q = jax.random.normal(key, (1, s, 4, 64))
    for name, fn in [
        ("flash_attn/xla-ref", lambda: flash_attention(q, q, q, use_kernel=False)),
        ("flash_attn/pallas-interp", lambda: flash_attention(q, q, q, interpret=True)),
    ]:
        us = time_fn(fn, iters=3, warmup=1)
        flops = 4 * s * s * 4 * 64
        rows.append([name, round(us, 1), f"flops={flops}"])

    # --- ssm scan ----------------------------------------------------------
    b, s2, di, nstate = 2, (256 if quick else 512), 64, 16
    x = jax.random.normal(key, (b, s2, di))
    dt = jax.nn.softplus(jax.random.normal(key, (b, s2, di)))
    bb = jax.random.normal(key, (b, s2, nstate))
    cc = jax.random.normal(key, (b, s2, nstate))
    a = -jnp.exp(jax.random.normal(key, (di, nstate)) * 0.3)
    for name, fn in [
        ("ssm_scan/xla-ref", lambda: selective_scan(x, dt, bb, cc, a, use_kernel=False)),
        ("ssm_scan/pallas-interp", lambda: selective_scan(x, dt, bb, cc, a, interpret=True)),
    ]:
        us = time_fn(fn, iters=3, warmup=1)
        rows.append([name, round(us, 1),
                     f"state_bytes={b * di * nstate * 4}"])

    for r in rows:
        print(f"  {r[0]:28s} {r[1]:>12} us  {r[2]}")
    path = write_csv("kernels.csv", ["name", "us_per_call", "derived"], rows)
    print(f"  wrote {path}")
    return rows


if __name__ == "__main__":
    run()

"""Buffer-size (K) sweep — the protocol's central hyper-parameter.

FedBuff's K trades aggregation noise against server-round frequency; the
paper fixes K=10 without a sweep. We sweep K for both ca-afl and fedbuff:
the hypothesis (from the paper's Problem-1/2 analysis) is that CA weighting
is MOST valuable at larger K, where the buffer mixes updates of very
different staleness/heterogeneity and uniform averaging dilutes the
informative ones.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import write_csv
from repro.configs.base import FLConfig
from repro.core import LatencyModel, run_async
from repro.data import make_federated_image_dataset
from repro.models.lenet import apply_lenet, init_lenet, lenet_loss


def run(num_clients: int = 16, rounds_per_k=240, quick: bool = False):
    if quick:
        num_clients, rounds_per_k = 8, 48
    clients, (xt, yt) = make_federated_image_dataset(
        num_clients=num_clients, samples_per_client=400, alpha=0.2, noise=1.2,
        seed=2)
    params = init_lenet(jax.random.PRNGKey(2))
    xt, yt = xt[:512], yt[:512]
    ev = jax.jit(lambda p: jnp.mean(
        (jnp.argmax(apply_lenet(p, xt), -1) == yt).astype(jnp.float32)))
    eval_fn = lambda p: {"acc": float(ev(p))}
    latency = LatencyModel.heterogeneous(num_clients, max_slowdown=8.0, seed=2)

    rows = []
    for k in (1, 2, 4, 8):
        # equal total client work across K: rounds x K = const
        rounds = max(3, rounds_per_k // k)
        for pol in ("paper", "fedbuff"):
            fl = FLConfig(num_clients=num_clients, buffer_size=k,
                          local_steps=4, local_lr=0.05, batch_size=32,
                          weighting=pol)
            res = run_async(lenet_loss, params, clients, fl,
                            total_rounds=rounds, eval_fn=eval_fn,
                            eval_every=rounds, latency=latency, seed=2)
            acc = res.history[-1]["acc"]
            rows.append([k, pol, rounds, round(acc, 4),
                         round(res.sim_time, 2)])
            print(f"  K={k:2d} {pol:8s} rounds={rounds:3d} acc={acc:.4f} "
                  f"time={res.sim_time:.1f}")
    path = write_csv("buffer_k_sweep.csv",
                     ["K", "policy", "rounds", "final_acc", "sim_time"], rows)
    print(f"  wrote {path}")
    return rows


if __name__ == "__main__":
    run()

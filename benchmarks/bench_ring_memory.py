"""Version-ring memory: codec x model-size sweep (DESIGN.md §11).

Measures what the compressed version store actually buys: the per-device
bytes of the R-deep ring under each codec (``f32`` identity, ``int8``
per-block affine, ``delta`` sparse residual), across model sizes —
REAL allocations for the small models (sum of the ring state's leaf
``nbytes``, cross-checked against ``codec.device_bytes`` to the byte)
and analytic quotes for the large-model registry entries (gemma-7b,
qwen1.5-110b via ``jax.eval_shape`` — no parameters are materialized),
both whole and under 8-way model sharding.

"Smaller" only counts at matched convergence, so the sweep also runs the
quadratic engine workload per codec and pins the final eval metric to
the f32 run within a 5% relative tolerance before asserting the
headline gate: **int8 >= 3x fewer ring bytes than f32 on every model**.

Writes ``BENCH_ring_memory.json`` (nightly regression gate: per-device
ring bytes are gated as a CEILING — a codec regression that re-inflates
the ring turns the lane red — see ``benchmarks/check_regression.py``)
plus a CSV table.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_bench_json, write_csv
from repro.configs.base import FLConfig
from repro.core.server_pass import make_flat_spec
from repro.core.version_store import CODECS

ROOT = os.path.join(os.path.dirname(__file__), "..")

MIN_INT8_REDUCTION = 3.0    # bytes(f32) / bytes(int8) floor, every model
PARITY_RTOL = 0.05          # matched-convergence tolerance vs f32
PARITY_ROUNDS = 8
ANALYTIC_SHARDS = 8         # large-model quotes also under 8-way sharding

FL = FLConfig(num_clients=6, buffer_size=3, local_steps=2, local_lr=0.05,
              batch_size=8, max_staleness=4)


def _fl(codec: str) -> FLConfig:
    return dataclasses.replace(FL, ring_codec=codec)


def _measured_models() -> dict:
    """Small models whose rings are REALLY allocated: name -> params."""
    from repro.configs.base import ModelConfig
    from repro.models.lenet import init_lenet
    from repro.models.model import build_model

    # a real models/ transformer at multi-M params (the fine-tuning
    # workload shape the codec targets, CPU-allocatable)
    cfg = ModelConfig(name="bench-5m", family="dense", num_layers=4,
                      d_model=256, num_heads=4, num_kv_heads=4,
                      d_ff=1024, vocab_size=2048)
    xf = build_model(cfg).init(jax.random.PRNGKey(0))
    return {
        "quad4": {"w": jnp.zeros(4)},
        "lenet": init_lenet(jax.random.PRNGKey(0)),
        "transformer_5m": xf,
    }


def _analytic_models() -> dict:
    """Registry entries quoted via eval_shape: name -> abstract params."""
    from repro.configs.registry import get_arch
    from repro.models.model import build_model
    out = {}
    for aid in ("gemma-7b", "qwen1.5-110b"):
        model = build_model(get_arch(aid).model)
        out[aid] = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
    return out


def _ring_record(params, fl: FLConfig, *, allocate: bool) -> dict:
    """Bytes-per-device for one (model, codec): measured or analytic."""
    from repro.core.version_store import resolve_codec, ring_device_bytes
    from repro.sim.engine import init_version_ring

    spec = make_flat_spec(params, fl.server_pass_block_n)
    depth = fl.max_staleness + 1
    quote = ring_device_bytes(fl, spec)
    rec = {
        "params": int(spec.n),
        "bytes_per_device": int(quote),
        "bytes_per_row": int(quote // depth),
        "bytes_sharded8": int(ring_device_bytes(
            fl, spec, model_shards=ANALYTIC_SHARDS)),
    }
    if allocate:
        _, state = init_version_ring(params, fl)
        got = sum(leaf.nbytes for leaf in jax.tree.leaves(state))
        if got != quote:
            raise RuntimeError(
                f"{resolve_codec(fl).name}: allocated ring is {got} bytes "
                f"but device_bytes quoted {quote}")
        rec["bytes_allocated"] = int(got)
    return rec


def _quad_parity() -> dict:
    """Final quadratic-workload eval per codec, pinned to f32."""
    from repro.data.synthetic import ClientDataset
    from repro.sim.engine import run_vectorized

    def loss(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] - y) ** 2), {}

    def clients(n=6, size=64, d=4, seed=0):
        rng = np.random.default_rng(seed)
        w_true = np.arange(1.0, d + 1.0)
        out = []
        for i in range(n):
            x = rng.normal(size=(size, d)).astype(np.float32)
            y = (x @ w_true + 0.05 * rng.normal(size=size)).astype(np.float32)
            out.append(ClientDataset(x=x, y=y, seed=seed + 10 + i))
        return out

    eval_fn = lambda p: {"wnorm": float(jnp.sum(p["w"] ** 2))}  # noqa: E731
    finals = {}
    for codec in CODECS:
        res = run_vectorized(loss, {"w": jnp.zeros(4)}, clients(),
                             _fl(codec), total_rounds=PARITY_ROUNDS,
                             eval_fn=eval_fn, eval_every=2, seed=0)
        finals[codec] = float(res.history[-1]["wnorm"])
    out = {}
    ref = finals["f32"]
    for codec, v in finals.items():
        rel = abs(v - ref) / max(abs(ref), 1e-12)
        out[codec] = {"final_wnorm": round(v, 6), "rel_err_vs_f32": round(rel, 6)}
        if rel > PARITY_RTOL:
            raise RuntimeError(
                f"codec {codec!r} diverged from f32 at matched settings: "
                f"final wnorm {v:.6f} vs {ref:.6f} "
                f"({rel:.2%} > {PARITY_RTOL:.0%}) — the bytes gate only "
                "counts at matched convergence")
    return out


def run(quick: bool = False) -> None:
    del quick  # eval_shape quotes are cheap; one mode fits CI and laptop
    records: dict = {}
    for name, params in _measured_models().items():
        records[name] = {"kind": "measured"}
        for codec in CODECS:
            records[name][codec] = _ring_record(params, _fl(codec),
                                                allocate=True)
    for name, params in _analytic_models().items():
        records[name] = {"kind": "analytic"}
        for codec in CODECS:
            records[name][codec] = _ring_record(params, _fl(codec),
                                                allocate=False)

    min_ratio = float("inf")
    for name, rec in records.items():
        ratio = rec["f32"]["bytes_per_device"] / rec["int8"]["bytes_per_device"]
        rec["int8_reduction"] = round(ratio, 2)
        min_ratio = min(min_ratio, ratio)
        print(f"  {name:>14s} ({rec['kind']:>8s}): "
              f"{rec['f32']['params']:>13,d} params  "
              f"f32 {rec['f32']['bytes_per_device']:>15,d} B  "
              f"int8 {rec['int8']['bytes_per_device']:>14,d} B  "
              f"delta {rec['delta']['bytes_per_device']:>14,d} B  "
              f"({ratio:.2f}x)")
    print(f"  min int8 reduction: {min_ratio:.2f}x "
          f"(gate >= {MIN_INT8_REDUCTION:.0f}x)")
    if min_ratio < MIN_INT8_REDUCTION:
        raise RuntimeError(
            f"int8 ring only {min_ratio:.2f}x smaller than f32 "
            f"(gate {MIN_INT8_REDUCTION:.0f}x)")

    parity = _quad_parity()
    for codec, rec in parity.items():
        print(f"  parity {codec:>6s}: final wnorm {rec['final_wnorm']:.4f} "
              f"(rel err {rec['rel_err_vs_f32']:.2%})")

    out = {
        "bench": "ring_memory",
        "ring_depth": FL.max_staleness + 1,
        "qblock": FL.ring_qblock,
        "delta_density": FL.ring_delta_density,
        "analytic_shards": ANALYTIC_SHARDS,
        "records": records,
        "parity": parity,
        "min_int8_reduction": round(min_ratio, 2),
        "min_int8_reduction_gate": MIN_INT8_REDUCTION,
    }
    path = write_bench_json(os.path.join(ROOT, "BENCH_ring_memory.json"), out)
    rows = []
    for name in records:
        for codec in CODECS:
            r = records[name][codec]
            rows.append([name, codec, r["params"], r["bytes_per_device"],
                         r["bytes_per_row"], r["bytes_sharded8"]])
    csv = write_csv("ring_memory.csv",
                    ["model", "codec", "params", "bytes_per_device",
                     "bytes_per_row", "bytes_sharded8"], rows)
    print(f"  wrote {os.path.normpath(path)} and {os.path.normpath(csv)}")


if __name__ == "__main__":
    run(quick=os.environ.get("BENCH_QUICK", "") == "1")

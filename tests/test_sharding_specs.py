"""Sharding-rule validation: for every assigned arch, every PartitionSpec
produced by sharding/specs.py must evenly divide the dims it shards on the
production mesh axes — the invariant the dry-run relies on. Runs on the
abstract shapes only (no 512-device init needed: divisibility is static).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import build_model
from repro.sharding import specs as S

AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


class _FakeMesh:
    """Duck-typed mesh carrying only axis names/sizes for the rule code."""

    def __init__(self, axes=("data", "model")):
        self.axis_names = tuple(axes)
        self.devices = np.empty(tuple(AXIS_SIZES[a] for a in axes))


def _check_spec_tree(shape_tree, spec_tree, mesh):
    flat_s, _ = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=lambda x: isinstance(x, type(jax.sharding.PartitionSpec())))
    flat_l, _ = jax.tree_util.tree_flatten_with_path(shape_tree)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    by_path = {jax.tree_util.keystr(p): l for p, l in flat_l}
    bad = []
    for p, spec in flat_s:
        leaf = by_path[jax.tree_util.keystr(p)]
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([sizes[a] for a in axes]))
            if dim % n:
                bad.append((jax.tree_util.keystr(p), leaf.shape, spec))
    return bad


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divisible(arch, fsdp):
    cfg = get_arch(arch).model
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mesh = _FakeMesh()
    pspecs = S.param_pspecs(params_sds, mesh, fsdp=fsdp)
    bad = _check_spec_tree(params_sds, pspecs, mesh)
    assert not bad, bad[:5]


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "falcon-mamba-7b",
                                  "whisper-tiny", "hymba-1.5b"])
def test_cache_specs_divisible(arch):
    cfg = get_arch(arch).model
    model = build_model(cfg)
    cache_sds = jax.eval_shape(lambda: model.init_cache(128, 1024))
    mesh = _FakeMesh()
    cspecs = S.cache_pspecs(cache_sds, mesh, batch_axes=("data",))
    bad = _check_spec_tree(cache_sds, cspecs, mesh)
    assert not bad, bad[:5]


def test_tp_weights_actually_sharded():
    """The rules must shard the big matmul weights, not silently replicate."""
    cfg = get_arch("qwen3-1.7b").model
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = S.param_pspecs(params_sds, _FakeMesh(), fsdp=False)
    layer_specs = pspecs["layers"]
    assert "model" in tuple(layer_specs["attn"]["wq"])
    assert "model" in tuple(layer_specs["mlp"]["w_down"])
    assert "model" in tuple(pspecs["embed"])  # vocab or d sharded

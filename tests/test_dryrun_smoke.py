"""Dry-run smoke: the production lowering path runs end-to-end in a
subprocess with forced host devices (scaled-down mesh semantics are
covered by the full 512-device sweep in results/dryrun)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow  # end-to-end subprocess compile, minutes per arch
@pytest.mark.parametrize("arch,shape", [("qwen3-1.7b", "decode_32k"),
                                        ("falcon-mamba-7b", "long_500k")])
def test_dryrun_pair_compiles(tmp_path, arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.load(open(tmp_path / f"{arch}_{shape}_single.json"))
    assert rec["ok"]
    assert rec["flops"] > 0


def test_sweep_artifacts_complete():
    """The recorded sweep must cover 10 archs x 4 shapes x 2 meshes, all ok."""
    d = os.path.join(ROOT, "results", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("sweep artifacts not present")
    files = [f for f in os.listdir(d) if f.endswith(".json")]
    assert len(files) == 80
    for f in files:
        rec = json.load(open(os.path.join(d, f)))
        assert rec["ok"], f

"""Per-architecture smoke tests (reduced configs) + model-level invariants.

Every assigned arch: instantiate the reduced same-family variant, run one
forward and one train step on CPU, assert output shapes and finiteness.
Decode paths: prefill-by-decode == full-sequence forward (cache coherence).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs, smoke_variant
from repro.core.client import make_local_update_fn
from repro.models import build_model
from repro.utils import tree_isfinite, tree_sq_norm

ARCHS = list_archs()


def _batch(cfg, key, b=2, s=32):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size)}
    if cfg.num_patches:
        batch["patches"] = jax.random.normal(ks[2], (b, cfg.num_patches,
                                                     cfg.d_model))
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(ks[2], (b, cfg.encoder_seq_len,
                                                    cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def smoke_models():
    out = {}
    for a in ARCHS:
        cfg = smoke_variant(get_arch(a).model)
        m = build_model(cfg)
        out[a] = (m, m.init(jax.random.PRNGKey(0)))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, smoke_models):
    m, params = smoke_models[arch]
    cfg = m.cfg
    b, s = 2, 32
    batch = _batch(cfg, jax.random.PRNGKey(1), b, s)
    logits, aux = m.apply(params, batch)
    text = s  # trimming patches happens inside apply
    assert logits.shape == (b, text, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch, smoke_models):
    """One FL local-training step: loss decreases-or-moves, grads finite."""
    m, params = smoke_models[arch]
    batch = _batch(m.cfg, jax.random.PRNGKey(2))
    local = make_local_update_fn(m.loss, local_steps=2, local_lr=1e-2)
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), batch)  # (M=2, ...)
    delta, _ = local(params, stacked)
    assert bool(tree_isfinite(delta))
    assert float(tree_sq_norm(delta)) > 0.0  # parameters actually moved


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_is_reasonable_at_init(arch, smoke_models):
    m, params = smoke_models[arch]
    batch = _batch(m.cfg, jax.random.PRNGKey(3))
    loss, _ = m.loss(params, batch)
    # near-uniform prediction at init: CE ~ ln(V) (within a wide band)
    assert 0.3 * np.log(m.cfg.vocab_size) < float(loss) < 3 * np.log(m.cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, smoke_models):
    """Step-by-step decode logits == full-sequence forward logits."""
    m, params = smoke_models[arch]
    cfg = m.cfg
    if cfg.num_patches:
        pytest.skip("vlm decode starts after a patch prefix; covered below")
    b, s = 2, 12
    batch = _batch(cfg, jax.random.PRNGKey(4), b, s)
    full_logits, _ = m.apply(params, batch)
    cache = m.init_cache(b, s)
    if cfg.is_encdec:
        cache = m.prefill_cross(params, cache, batch["frames"])
    outs = []
    for i in range(s):
        lg, cache = m.decode_step(params, cache, batch["tokens"][:, i:i + 1],
                                  jnp.int32(i))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32), rtol=2e-2, atol=2e-3)


def test_swa_ring_cache_matches_window_forward():
    """Ring-cache decode == full forward with the same sliding window."""
    cfg = smoke_variant(get_arch("qwen3-1.7b").model).replace(attn_window=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, s = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    full_logits, _ = m.apply(params, batch)
    cache = m.init_cache(b, s)  # ring: length = window
    assert cache["kv"]["k"].shape[2] == 8
    outs = []
    for i in range(s):
        lg, cache = m.decode_step(params, cache, toks[:, i:i + 1], jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_gqa_repeat_equivalence():
    """GQA with kv=H behaves like MHA given replicated kv weights."""
    from repro.models.attention import attention_train, init_attention
    cfg_mha = smoke_variant(get_arch("stablelm-12b").model).replace(
        num_heads=4, num_kv_heads=4)
    cfg_gqa = cfg_mha.replace(num_kv_heads=2)
    p = init_attention(jax.random.PRNGKey(0), cfg_gqa)
    # expand kv weights to per-head copies -> MHA params
    hd = cfg_gqa.resolved_head_dim
    wk = p["wk"].reshape(cfg_mha.d_model, 2, hd)
    p_mha = dict(p)
    p_mha["wk"] = jnp.repeat(wk, 2, axis=1).reshape(cfg_mha.d_model, 4 * hd)
    wv = p["wv"].reshape(cfg_mha.d_model, 2, hd)
    p_mha["wv"] = jnp.repeat(wv, 2, axis=1).reshape(cfg_mha.d_model, 4 * hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg_mha.d_model))
    y_gqa = attention_train(cfg_gqa, p, x)
    y_mha = attention_train(cfg_mha, p_mha, x)
    np.testing.assert_allclose(np.asarray(y_gqa), np.asarray(y_mha),
                               rtol=1e-4, atol=1e-5)


def test_chunked_attention_matches_full():
    from repro.models.attention import _chunked_causal_attention, _full_attention
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (2, 512, 2, 32)) for kk in ks)
    full = _full_attention(q, k, v, causal=True)
    chunked = _chunked_causal_attention(q, k, v, q_chunk=128)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_swa_train_matches_masked_full():
    from repro.models.attention import _full_attention, _sliding_window_attention
    import jax.numpy as jnp2
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (1, 256, 2, 32)) for kk in ks)
    win = 32
    swa = _sliding_window_attention(q, k, v, window=win, q_chunk=64)
    # reference: full attention with band mask
    scale = 32 ** -0.5
    s = jnp2.einsum("bqhd,bkhd->bhqk", q * scale, k)
    qp = jnp2.arange(256)[:, None]
    kp = jnp2.arange(256)[None, :]
    mask = (qp >= kp) & (kp > qp - win)
    s = jnp2.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    ref = jnp2.einsum("bhqk,bkhd->bqhd", p, v)
    np.testing.assert_allclose(np.asarray(swa), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_moe_capacity_drops_are_bounded():
    """With generous capacity no token output is zeroed (all dispatched)."""
    from repro.models.moe import init_moe, moe_ffn
    cfg = smoke_variant(get_arch("deepseek-moe-16b").model)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe_ffn(cfg, p, x, capacity_factor=8.0)
    assert y.shape == x.shape
    assert float(aux) >= 0.0
    # with cf=8 every token fits: output magnitude non-trivial everywhere
    norms = jnp.linalg.norm(y, axis=-1)
    assert float(jnp.min(norms)) > 0.0

"""Device-resident server pass: mode parity, padding, and the host-sync
contract (DESIGN.md §3).

The reference mode is itself checked against a hand-computed pure-jnp
oracle built directly from core/weighting, then the Pallas modes
(batched two-kernel, fused one-kernel) are swept against reference in
interpret mode across K, non-lane-multiple N, dtypes, and policies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.server import AsyncServer
from repro.core.server_pass import (
    apply_server_round,
    flatten_stacked,
    flatten_tree,
    make_flat_spec,
    make_server_pass,
    resolve_mode,
    unflatten_like,
)
from repro.core.weighting import (
    contribution_weights,
    staleness_degree,
    statistical_effect,
)


def _flat_case(key, k, n, dtype=jnp.float32):
    kx, kb, kd = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n,), jnp.float32)
    bases = x[None] + 0.1 * jax.random.normal(kb, (k, n), jnp.float32)
    deltas = jax.random.normal(kd, (k, n), jnp.float32).astype(dtype)
    losses = jnp.linspace(0.5, 2.0, k)
    sizes = jnp.linspace(10.0, 50.0, k)
    taus = jnp.arange(k, dtype=jnp.float32)
    return x, bases, deltas, losses, sizes, taus


def _pad(a, npad):
    widths = ((0, npad - a.shape[-1]),)
    if a.ndim == 2:
        widths = ((0, 0),) + widths
    return jnp.pad(a.astype(jnp.float32), widths)


def _oracle(x, bases, deltas, losses, sizes, taus, fl, mask=None):
    """Unpadded pure-jnp eq. 3+4+5 straight from core/weighting."""
    dists = jnp.sum((bases - x[None]) ** 2, axis=1)
    s = staleness_degree(dists, arrival_mask=mask)
    p = statistical_effect(losses, sizes)
    w = contribution_weights(fl.weighting, p, s, taus, s_min=fl.s_min,
                             poly_a=fl.poly_a, hinge_a=fl.hinge_a,
                             hinge_b=fl.hinge_b, normalize=fl.normalize,
                             arrival_mask=mask)
    k_eff = bases.shape[0] if mask is None else float(jnp.sum(mask))
    upd = jnp.einsum("kn,k->n", deltas.astype(jnp.float32),
                     w * (fl.global_lr / max(k_eff, 1.0)))
    return x - upd, dists, w


def _run_mode(mode, x, bases, deltas, losses, sizes, taus, fl, mask=None):
    spec_n = x.shape[0]
    block = 0
    from repro.kernels.weighted_agg.ops import pad_to, pick_block
    block = pick_block(spec_n)
    npad = pad_to(spec_n, block)
    new_x, info = apply_server_round(
        _pad(x, npad), _pad(bases, npad), _pad(deltas, npad), losses,
        sizes, taus, fl, arrival_mask=mask, mode=mode, block_n=block,
        interpret=True)
    return new_x[:spec_n], info


class TestModeParity:
    @pytest.mark.parametrize("k", [1, 3, 8, 32])
    def test_k_sweep(self, k):
        fl = FLConfig(weighting="paper")
        case = _flat_case(jax.random.PRNGKey(k), k, 1000)
        ref, dists, w = _oracle(*case, fl)
        for mode in ("reference", "batched", "fused"):
            got, info = _run_mode(mode, *case, fl)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=1e-4, atol=1e-5, err_msg=mode)
            np.testing.assert_allclose(np.asarray(info["sq_dists"]),
                                       np.asarray(dists), rtol=1e-4,
                                       err_msg=mode)
            np.testing.assert_allclose(np.asarray(info["weights"]),
                                       np.asarray(w), rtol=1e-4,
                                       err_msg=mode)

    @pytest.mark.parametrize("n", [1000, 130 * 1000 + 7])
    def test_non_lane_multiple_n(self, n):
        """Padding must be distance- and sum-neutral at awkward N."""
        fl = FLConfig(weighting="paper")
        case = _flat_case(jax.random.PRNGKey(0), 3, n)
        ref, dists, _ = _oracle(*case, fl)
        for mode in ("batched", "fused"):
            got, info = _run_mode(mode, *case, fl)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=1e-4, atol=1e-5, err_msg=mode)
            np.testing.assert_allclose(np.asarray(info["sq_dists"]),
                                       np.asarray(dists), rtol=1e-3,
                                       err_msg=mode)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_delta_dtypes(self, dtype):
        fl = FLConfig(weighting="paper")
        case = _flat_case(jax.random.PRNGKey(1), 4, 1000, dtype=dtype)
        ref, _, _ = _oracle(*case, fl)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        for mode in ("reference", "batched", "fused"):
            got, _ = _run_mode(mode, *case, fl)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=tol, atol=tol, err_msg=mode)

    @pytest.mark.parametrize("policy", ["paper", "fedbuff", "polynomial",
                                        "fedasync_constant",
                                        "fedasync_hinge", "fedasync_poly"])
    def test_policies_and_mask(self, policy):
        # hinge_b=1.0 puts taus 2..3 past the hinge knee, so the fused
        # kernel's in-kernel reciprocal branch is actually exercised
        fl = FLConfig(weighting=policy, hinge_b=1.0)
        case = _flat_case(jax.random.PRNGKey(2), 4, 520)
        mask = jnp.array([1.0, 0.0, 1.0, 1.0])
        ref, _, w_ref = _oracle(*case, fl, mask=mask)
        for mode in ("reference", "batched", "fused"):
            got, info = _run_mode(mode, *case, fl, mask=mask)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=1e-4, atol=1e-5, err_msg=mode)
            np.testing.assert_allclose(np.asarray(info["weights"]),
                                       np.asarray(w_ref), rtol=1e-4,
                                       atol=1e-6, err_msg=mode)
        assert float(info["weights"][1]) == 0.0


class TestFlatSpecAdapter:
    def test_roundtrip_mixed_shapes_and_dtypes(self):
        tree = {"a": jnp.arange(7.0), "b": {"c": jnp.ones((3, 5), jnp.bfloat16),
                                            "d": jnp.float32(2.0).reshape(())}}
        spec = make_flat_spec(tree)
        vec = flatten_tree(spec, tree)
        assert vec.shape == (spec.n_padded,) and spec.n == 7 + 15 + 1
        assert spec.n_padded % spec.block_n == 0
        back = unflatten_like(spec, vec, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_allclose(np.asarray(a, jnp.float32),
                                       np.asarray(b, jnp.float32))

    def test_flatten_stacked_matches_per_item(self):
        trees = [{"w": jnp.full((2, 3), float(i)), "b": jnp.full((4,), -float(i))}
                 for i in range(3)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        spec = make_flat_spec(trees[0])
        flat = flatten_stacked(spec, stacked)
        for i, t in enumerate(trees):
            np.testing.assert_allclose(np.asarray(flat[i]),
                                       np.asarray(flatten_tree(spec, t)))

    def test_resolve_mode(self):
        mode, interpret = resolve_mode("auto")
        assert mode in ("reference", "fused")
        if jax.default_backend() != "tpu":
            assert mode == "reference" and interpret
        with pytest.raises(ValueError):
            resolve_mode("nope")

    def test_explicit_kernel_mode_off_tpu_warns(self, caplog):
        """Satellite: a non-TPU user asking for the Mosaic kernels gets an
        actionable warning naming the backend, not a silent slowdown.
        Emitted through the standardized logging plane (obs, DESIGN.md
        §9) rather than warnings.warn."""
        if jax.default_backend() == "tpu":
            pytest.skip("kernel modes are native on TPU")
        import logging
        for mode in ("fused", "batched"):
            caplog.clear()
            with caplog.at_level(logging.WARNING,
                                 logger="repro.core.server_pass"):
                got, interpret = resolve_mode(mode)
            assert any("compile only for TPU" in r.getMessage()
                       and r.levelno == logging.WARNING
                       for r in caplog.records), caplog.records
            assert got == mode and interpret

    def test_auto_fallback_is_silent(self, caplog):
        import logging
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("error")
            with caplog.at_level(logging.WARNING,
                                 logger="repro.core.server_pass"):
                resolve_mode("auto")
        assert not [r for r in caplog.records
                    if r.levelno >= logging.WARNING]


def _quad_loss(params, batch):
    x, y = batch
    return jnp.mean((x @ params["w"] - y) ** 2), {}


def _quad_batch(key, n=16, d=4):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (n, d))
    y = x @ jnp.arange(1.0, d + 1.0) + 0.01 * jax.random.normal(k2, (n,))
    return x, y


class TestHostSyncContract:
    """AsyncServer._do_aggregate: at most 2 device->host syncs per round
    (the single round-log readback), with exactly one jitted-pass call."""

    def test_at_most_two_host_syncs(self, monkeypatch):
        fl = FLConfig(buffer_size=3, weighting="paper")
        server = AsyncServer({"w": jnp.zeros(4)}, fl,
                             lambda p, b: _quad_loss(p, b)[0])
        batch = _quad_batch(jax.random.PRNGKey(0))

        sync_calls = []
        orig_get = jax.device_get
        monkeypatch.setattr(
            jax, "device_get",
            lambda tree: (sync_calls.append(1), orig_get(tree))[1])
        pass_calls = []
        orig_pass = server._pass
        server._pass = lambda *a, **kw: (pass_calls.append(1),
                                         orig_pass(*a, **kw))[1]

        d = {"w": jnp.ones(4)}
        assert not server.receive(0, d, 0, 10, lambda: batch)
        assert not server.receive(1, d, 0, 20, lambda: batch)
        assert server.receive(2, d, 0, 30, lambda: batch)

        assert len(pass_calls) == 1  # one jitted pass per round
        assert len(sync_calls) <= 2  # round-log readback only
        assert server.version == 1 and len(server.round_log) == 1

    def test_pass_output_stays_on_device(self):
        fl = FLConfig(buffer_size=2)
        server = AsyncServer({"w": jnp.zeros(4)}, fl,
                             lambda p, b: _quad_loss(p, b)[0])
        batch = _quad_batch(jax.random.PRNGKey(1))
        server.receive(0, {"w": jnp.ones(4)}, 0, 10, lambda: batch)
        server.receive(1, {"w": jnp.ones(4)}, 0, 10, lambda: batch)
        assert isinstance(server.params["w"], jax.Array)

    def test_heterogeneous_probe_shapes(self, monkeypatch):
        """Clients with different probe batch sizes must not crash the
        round (seed behaviour) and must keep the host-sync budget: the
        fallback evaluates K separate jitted losses, all device-side."""
        fl = FLConfig(buffer_size=2, weighting="paper")
        server = AsyncServer({"w": jnp.zeros(4)}, fl,
                             lambda p, b: _quad_loss(p, b)[0])
        big = _quad_batch(jax.random.PRNGKey(0), n=16)
        small = _quad_batch(jax.random.PRNGKey(1), n=8)

        sync_calls = []
        orig_get = jax.device_get
        monkeypatch.setattr(
            jax, "device_get",
            lambda tree: (sync_calls.append(1), orig_get(tree))[1])

        server.receive(0, {"w": jnp.ones(4)}, 0, 10, lambda: big)
        assert server.receive(1, {"w": jnp.ones(4)}, 0, 30, lambda: small)
        assert server.version == 1
        assert len(sync_calls) <= 2
        log = server.round_log[0]
        # probes ran: P_i = N_i * loss_i, not the size-only fallback
        assert log["stat_effect"][0] != 10.0 or log["stat_effect"][1] != 30.0

    def test_missing_probe_falls_back_to_size_weighting(self):
        fl = FLConfig(buffer_size=2, weighting="paper")
        server = AsyncServer({"w": jnp.zeros(4)}, fl,
                             lambda p, b: _quad_loss(p, b)[0])
        server.receive(0, {"w": jnp.ones(4)}, 0, 10)
        server.receive(1, {"w": jnp.ones(4)}, 0, 30)
        log = server.round_log[0]
        # no probes anywhere: losses default to 1 => P_i = N_i
        np.testing.assert_allclose(log["stat_effect"], [10.0, 30.0],
                                   rtol=1e-6)


class TestServerPassJit:
    def test_make_server_pass_end_to_end(self):
        fl = FLConfig(buffer_size=2, weighting="paper", global_lr=1.0)
        params = {"w": jnp.array([1.0, -1.0, 0.5, 2.0])}
        pass_fn = make_server_pass(fl, lambda p, b: _quad_loss(p, b)[0])
        key = jax.random.PRNGKey(0)
        deltas = [{"w": 0.1 * jnp.arange(4.0)}, {"w": -0.1 * jnp.ones(4)}]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
        bases = jax.tree.map(lambda x: jnp.stack([x, x]), params)
        probes = jax.tree.map(lambda *xs: jnp.stack(xs),
                              _quad_batch(key), _quad_batch(key))
        new_params, info = pass_fn(params, stacked, bases, probes,
                                   jnp.ones(2), jnp.array([10.0, 30.0]),
                                   jnp.zeros(2))
        # both fresh => S = 1; paper weights proportional to N_i * loss
        assert float(info["weights"][1]) > float(info["weights"][0])
        ref, _, _ = _oracle(
            jnp.asarray(params["w"]),
            jnp.stack([params["w"], params["w"]]),
            jnp.stack([d["w"] for d in deltas]),
            info["fresh_loss"], jnp.array([10.0, 30.0]), jnp.zeros(2), fl)
        np.testing.assert_allclose(np.asarray(new_params["w"]),
                                   np.asarray(ref), rtol=1e-5)

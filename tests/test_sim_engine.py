"""Vectorized simulation engine: parity with the legacy loop, scenario
registry coverage, trace record/replay, fair-RNG and partial-participation
fixes, telemetry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import run_async, run_async_legacy, run_sync, run_vectorized
from repro.data.synthetic import ClientDataset
from repro.sim import (
    ClientBehavior,
    EventTrace,
    LatencyModel,
    Scenario,
    get_scenario,
    metrics,
    registry,
)


def _quad_loss(params, batch):
    x, y = batch
    pred = x @ params["w"]
    return jnp.mean((pred - y) ** 2), {}


def _quad_clients(n=6, size=64, d=4, seed=0):
    rng = np.random.default_rng(seed)
    w_true = np.arange(1.0, d + 1.0)
    out = []
    for i in range(n):
        x = rng.normal(size=(size, d)).astype(np.float32)
        y = (x @ w_true + 0.05 * rng.normal(size=size)).astype(np.float32)
        out.append(ClientDataset(x=x, y=y, seed=seed + 10 + i))
    return out


def _params(d=4):
    return {"w": jnp.zeros(d)}


FL = FLConfig(num_clients=6, buffer_size=3, local_steps=2, local_lr=0.05,
              batch_size=8, max_staleness=4)


def _eval_fn(params):
    return {"wnorm": float(jnp.sum(params["w"] ** 2))}


class TestEngineParity:
    """The vectorized engine must reproduce the legacy heapq loop's round
    log event-for-event on a fixed seed (the ISSUE-2 acceptance gate)."""

    @pytest.mark.parametrize("weighting", ["paper", "fedbuff"])
    def test_round_log_event_for_event(self, weighting):
        fl = dataclasses.replace(FL, weighting=weighting)
        res_v = run_vectorized(_quad_loss, _params(), _quad_clients(), fl,
                               total_rounds=10, eval_fn=_eval_fn, seed=0)
        res_l = run_async_legacy(_quad_loss, _params(), _quad_clients(), fl,
                                 total_rounds=10, eval_fn=_eval_fn, seed=0)
        assert res_v.server_rounds == res_l.server_rounds == 10
        assert res_v.num_events == res_l.num_events
        assert res_v.sim_time == res_l.sim_time
        for lv, ll in zip(res_v.round_log, res_l.round_log):
            assert lv["version"] == ll["version"]
            assert lv["clients"] == ll["clients"]  # same uploads, same order
            assert lv["tau"] == ll["tau"]
            np.testing.assert_allclose(lv["weights"], ll["weights"],
                                       rtol=1e-4, atol=1e-6)
            np.testing.assert_allclose(lv["sq_dists"], ll["sq_dists"],
                                       rtol=1e-4, atol=1e-6)
        # eval cadence and timestamps identical too
        assert [(h["round"], h["time"]) for h in res_v.history] == \
               [(h["round"], h["time"]) for h in res_l.history]
        for hv, hl in zip(res_v.history, res_l.history):
            np.testing.assert_allclose(hv["wnorm"], hl["wnorm"], rtol=1e-4)

    def test_parity_exercises_stale_ring_fallback(self):
        """max_staleness=1 forces base versions out of the ring, hitting
        the resync path on both sides — they must still agree."""
        fl = dataclasses.replace(FL, max_staleness=1, buffer_size=2)
        res_v = run_vectorized(_quad_loss, _params(), _quad_clients(), fl,
                               total_rounds=8, seed=1)
        res_l = run_async_legacy(_quad_loss, _params(), _quad_clients(), fl,
                                 total_rounds=8, seed=1)
        for lv, ll in zip(res_v.round_log, res_l.round_log):
            assert lv["clients"] == ll["clients"]
            assert lv["tau"] == ll["tau"]

    def test_run_async_dispatches_engines(self):
        r = run_async(_quad_loss, _params(), _quad_clients(), FL,
                      total_rounds=2, seed=0, engine="vectorized")
        assert r.server_rounds == 2
        with pytest.raises(ValueError):
            run_async(_quad_loss, _params(), _quad_clients(), FL,
                      total_rounds=1, engine="nope")


class TestEvalCadence:
    """Satellite fix: the trailing ``maybe_eval(force=True)`` must not
    duplicate the final history row when total_rounds % eval_every == 0."""

    def test_no_duplicate_final_eval(self):
        for runner in (run_vectorized, run_async_legacy):
            res = runner(_quad_loss, _params(), _quad_clients(), FL,
                         total_rounds=4, eval_fn=_eval_fn, eval_every=2,
                         seed=0)
            assert [h["round"] for h in res.history] == [0, 2, 4]

    def test_final_eval_still_forced_on_odd_horizon(self):
        res = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=5, eval_fn=_eval_fn, eval_every=2,
                             seed=0)
        assert [h["round"] for h in res.history] == [0, 2, 4, 5]

    def test_num_launches_counted(self):
        res = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=10, seed=0, rounds_per_launch=4)
        assert res.num_launches == 3  # ceil(10 / 4), no eval clipping


class TestScenarioRegistry:
    def test_registry_has_at_least_six(self):
        reg = registry()
        assert len(reg) >= 6
        for name, sc in reg.items():
            assert sc.name == name and sc.description

    @pytest.mark.parametrize("name", sorted(registry()))
    def test_every_scenario_runs(self, name):
        """Each named scenario drives the engine for a couple of rounds."""
        sc = get_scenario(name)
        res = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=2, scenario=sc, seed=0)
        assert res.server_rounds == 2
        assert len(res.round_log) == 2
        assert np.isfinite(res.sim_time)

    def test_alpha_wiring_to_partition(self):
        """Scenario alpha reaches the Dirichlet partitioner: extreme skew
        concentrates labels, the IID scenario does not."""
        skew, _ = get_scenario("dirichlet-extreme").make_dataset(
            6, samples_per_client=200, seed=0)
        iid, _ = get_scenario("iid-uniform").make_dataset(
            6, samples_per_client=200, seed=0)
        seen_skew = np.median([np.unique(c.y).size for c in skew])
        seen_iid = np.median([np.unique(c.y).size for c in iid])
        assert seen_skew < seen_iid

    def test_diurnal_gating(self):
        sc = get_scenario("diurnal-phones")
        beh = sc.behavior(4, seed=0)
        period, on = sc.diurnal_period, sc.diurnal_duty * sc.diurnal_period
        for cid in range(4):
            for t in (0.0, 5.0, 13.7, 23.9, 42.0):
                start = beh.next_start(cid, t)
                assert start >= t
                local = (start - beh.phase[cid]) % period
                assert local < on or np.isclose(local % period, 0.0)

    def test_bernoulli_dropout_loses_uploads(self):
        sc = get_scenario("dropout-bernoulli")
        res = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=4, scenario=sc, seed=0,
                             record_trace=True)
        # dropped uploads consumed events beyond the 4*K accepted ones
        assert res.num_events > 4 * FL.buffer_size or res.trace.drops == []
        assert len(res.trace.drops) == res.num_events - 4 * FL.buffer_size

    def test_upload_index_api_and_stream(self):
        """The public ClientBehavior upload API: ``upload_index`` peeks,
        ``next_upload`` consumes atomically, and dropped uploads consume
        an index too (the stream identifies every ATTEMPT)."""
        sc = dataclasses.replace(
            get_scenario("iid-uniform"), name="drop-k1",
            dropout_trace=((0, 1),))  # client 0 loses its second upload
        beh = sc.behavior(2, seed=0)
        assert beh.upload_index(0) == 0
        assert beh.next_upload(0) == (0, False)
        assert beh.upload_index(0) == 1  # peek does not consume
        assert beh.upload_index(0) == 1
        assert beh.next_upload(0) == (1, True)  # the traced drop
        assert beh.next_upload(0) == (2, False)
        assert beh.next_upload(1) == (0, False)  # streams are per-client

    def test_replay_pins_index_stream_across_drops(self):
        """Trace replay re-issues the SAME (cid, k) upload stream: the
        recorded event log's indices skip the dropped ks identically on
        record and replay (the regression the public API guards — the
        engine used to sample the index separately from the drop check)."""
        sc = dataclasses.replace(  # drops that land inside a short run
            get_scenario("dropout-trace"), name="drop-early",
            dropout_trace=((0, 0), (1, 1), (3, 0), (3, 2)))
        rec = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=4, scenario=sc, seed=0,
                             record_trace=True)
        assert len(rec.trace.drops) > 0  # the scenario actually drops
        rep = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=4, trace=rec.trace, seed=99,
                             record_trace=True)
        # byte-identical (t, cid, k, round) streams, drops included
        assert rep.trace.events == rec.trace.events
        assert rep.trace.drops == rec.trace.drops
        # accepted events never reuse a dropped (cid, k); every dropped k
        # is still consumed (absent from events, present in the k-stream)
        dropped = set(map(tuple, rec.trace.drops))
        seen = {}
        for _, cid, k, _ in rec.trace.events:
            assert (cid, k) not in dropped
            ks = seen.setdefault(cid, [])
            ks.append(k)
        for cid, ks in seen.items():
            assert ks == sorted(ks)  # per-client indices strictly advance
            skipped = set(range(ks[-1] + 1)) - set(ks)
            assert skipped <= {k for c, k in dropped if c == cid}

    def test_trace_dropout_is_deterministic(self):
        sc = get_scenario("dropout-trace")
        r1 = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                            total_rounds=3, scenario=sc, seed=0,
                            record_trace=True)
        r2 = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                            total_rounds=3, scenario=sc, seed=0,
                            record_trace=True)
        assert r1.trace.drops == r2.trace.drops
        assert r1.sim_time == r2.sim_time

    def test_straggler_burst_slows_hit_clients(self):
        sc = get_scenario("straggler-burst")
        beh = sc.behavior(8, seed=0)
        # inside a burst window the hit client's multiplier applies
        assert beh._burst_mult(0, 0.5) == sc.burst_factor
        assert beh._burst_mult(1, 0.5) == 1.0
        assert beh._burst_mult(0, sc.burst_len + 0.5) == 1.0  # burst over
        # bursts rotate: next burst index shifts the hit set
        assert beh._burst_mult(3, sc.burst_every + 0.5) == sc.burst_factor

    def test_bandwidth_tiers_assign_comm(self):
        sc = get_scenario("bandwidth-tiers")
        beh = sc.behavior(32, seed=0)
        assert set(np.unique(beh.comm)) <= set(sc.comm_tiers)
        assert np.unique(beh.comm).size > 1  # population actually spans tiers


class TestFairRNG:
    """Satellite: one seeded duration stream per client, shared by
    sync/async/engine — draw k of client i never depends on the protocol."""

    def test_sync_and_async_see_identical_durations(self):
        lat = LatencyModel.heterogeneous(4, seed=0)
        a = ClientBehavior.from_latency(lat, 4, seed=5)
        b = ClientBehavior.from_latency(lat, 4, seed=5)
        # async consumption order (interleaved) vs sync order (per round)
        async_draws = [a.duration(0, 0), a.duration(1, 0), a.duration(0, 0)]
        sync_first = [b.duration(0, 0), b.duration(1, 0)]
        assert async_draws[0] == sync_first[0]
        assert async_draws[1] == sync_first[1]
        assert async_draws[2] == b.duration(0, 0)  # draw 1 of client 0

    def test_protocols_share_timeline(self):
        """K=1 and K=3 runs see the same upload times (timeline is
        protocol-independent), so wall-clock comparisons are fair."""
        fl1 = dataclasses.replace(FL, buffer_size=1)
        r3 = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                            total_rounds=4, seed=3, record_trace=True)
        r1 = run_vectorized(_quad_loss, _params(), _quad_clients(), fl1,
                            total_rounds=12, seed=3, record_trace=True)
        t3 = [(t, c) for t, c, _, _ in r3.trace.events]
        t1 = [(t, c) for t, c, _, _ in r1.trace.events]
        assert t3 == t1[:len(t3)]


class TestTraces:
    def test_save_load_roundtrip(self, tmp_path):
        res = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=3, seed=0, record_trace=True)
        p = str(tmp_path / "trace.json")
        res.trace.save(p)
        tr = EventTrace.load(p)
        assert tr.num_clients == res.trace.num_clients
        assert tr.durations == res.trace.durations
        assert tr.drops == res.trace.drops
        assert tr.events == res.trace.events

    def test_replay_reproduces_run_exactly(self):
        res = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=3, seed=0, record_trace=True)
        replay = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                                total_rounds=3, trace=res.trace, seed=99)
        assert replay.sim_time == res.sim_time
        assert [l["clients"] for l in replay.round_log] == \
               [l["clients"] for l in res.round_log]

    def test_replay_works_across_engines(self):
        res = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=3, seed=0, record_trace=True)
        replay = run_async_legacy(_quad_loss, _params(), _quad_clients(), FL,
                                  total_rounds=3, trace=res.trace)
        assert replay.sim_time == res.sim_time

    def test_replay_recovers_registered_scenario_gating(self):
        """A trace recorded under a registry scenario replays its
        deterministic parts (diurnal gating) without re-passing it."""
        sc = get_scenario("diurnal-phones")
        res = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=3, scenario=sc, seed=0,
                             record_trace=True)
        replay = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                                total_rounds=3, trace=res.trace)
        assert replay.sim_time == res.sim_time
        assert [l["clients"] for l in replay.round_log] == \
               [l["clients"] for l in res.round_log]

    def test_exhausted_trace_raises_clearly(self):
        res = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=2, seed=0, record_trace=True)
        with pytest.raises(RuntimeError, match="trace exhausted"):
            run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                           total_rounds=10, trace=res.trace)


class TestSyncPartialParticipation:
    def test_partial_participation_counts(self):
        fl = dataclasses.replace(FL, clients_per_round=2)
        res = run_sync(_quad_loss, _params(), _quad_clients(), fl,
                       total_rounds=3, eval_fn=_eval_fn, eval_every=1)
        assert res.server_rounds == 3
        assert res.num_events == 6  # 2 clients x 3 rounds

    def test_partial_faster_than_full(self):
        """Waiting on a uniform subset is never slower than on all N."""
        full = run_sync(_quad_loss, _params(), _quad_clients(), FL,
                        total_rounds=3, seed=0)
        part = run_sync(_quad_loss, _params(),
                        _quad_clients(),
                        dataclasses.replace(FL, clients_per_round=2),
                        total_rounds=3, seed=0)
        assert part.sim_time <= full.sim_time

    def test_zero_means_all(self):
        res = run_sync(_quad_loss, _params(), _quad_clients(), FL,
                       total_rounds=2)
        assert res.num_events == 2 * len(_quad_clients())


class TestTelemetry:
    def test_summarize_fields(self):
        res = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=5, seed=0)
        s = metrics.summarize(res.round_log, 6)
        assert s["rounds"] == 5
        assert 0.0 <= s["participation_gini"] < 1.0
        assert s["tau_max"] >= 0
        assert 0.0 < s["staleness_deg_mean"] <= 1.0
        assert s["weight_entropy_mean"] <= s["weight_entropy_uniform"] + 1e-9

    def test_uniform_weights_hit_max_entropy(self):
        fl = dataclasses.replace(FL, weighting="fedbuff")
        res = run_vectorized(_quad_loss, _params(), _quad_clients(), fl,
                             total_rounds=3, seed=0)
        s = metrics.summarize(res.round_log, 6)
        np.testing.assert_allclose(s["weight_entropy_mean"],
                                   np.log2(FL.buffer_size), rtol=1e-5)

    def test_empty_round_log(self):
        assert metrics.summarize([], 4) == {"rounds": 0}


class TestImportOrder:
    def test_repro_sim_imports_standalone(self):
        """``import repro.sim`` before any repro.core import must not
        trip the core.simulator <-> sim.engine cycle."""
        import subprocess
        import sys
        r = subprocess.run(
            [sys.executable, "-c", "import repro.sim; import repro.core; "
             "print(repro.sim.SimResult is repro.core.SimResult)"],
            capture_output=True, text=True)
        assert r.returncode == 0, r.stderr
        assert r.stdout.strip() == "True"


class TestScenarioComposability:
    def test_replace_composes_new_scenario(self):
        base = get_scenario("compute-tiers")
        composed = dataclasses.replace(base, name="tiers+dropout",
                                       dropout_p=0.3)
        res = run_vectorized(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=2, scenario=composed, seed=0,
                             record_trace=True)
        assert res.server_rounds == 2

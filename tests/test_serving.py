"""Always-on serving loop (core/serving.py + sim/arrivals.py, DESIGN.md §8):
admission-control semantics under bursts, adaptive-K settling, FedAsync
staleness-discount parity, and the acceptance gate — the serving loop's
aggregate pinned against the exact ``apply_server_round`` path on the
same seeded upload stream for EVERY weighting policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.client import make_local_update_fn
from repro.core.serving import (
    ADMITTED,
    DROP_MAX_STALENESS,
    REJECT_QUEUE_FULL,
    ServeConfig,
    ServingController,
    Upload,
    serve_stream,
)
from repro.core.server_pass import (
    apply_server_round,
    flatten_stacked,
    flatten_tree,
    make_flat_spec,
    unflatten_like,
)
from repro.core.weighting import (
    FEDASYNC_POLICIES,
    POLICIES,
    contribution_weights,
    fedasync_discount,
)
from repro.sim.arrivals import TrafficGenerator
from repro.sim.scenarios import get_scenario


def _quad_loss(params, batch):
    x, y = batch
    return jnp.mean((x @ params["w"] - y) ** 2), {}


def _quad_batch(key, n=8, d=4):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (n, d))
    y = x @ jnp.arange(1.0, d + 1.0) + 0.01 * jax.random.normal(k2, (n,))
    return x, y


PARAMS = {"w": jnp.array([1.0, -1.0, 0.5, 2.0])}


def _upload(ctrl, i, tau=0, t=0.0, size=10.0):
    """One deterministic quad-problem upload, staleness ``tau`` rounds."""
    key = jax.random.PRNGKey(0)
    b = _quad_batch(jax.random.fold_in(key, i))
    return Upload(client_id=i, base_version=ctrl.version - tau,
                  data_size=size,
                  batch=jax.tree.map(lambda x: x[None], b),
                  probe=_quad_batch(jax.random.fold_in(key, 100 + i)),
                  sent_at=t)


class TestAdmissionControl:
    def _ctrl(self, **kw):
        fl = FLConfig(buffer_size=4, local_steps=1, local_lr=0.1,
                      max_staleness=4)
        return ServingController(_quad_loss, PARAMS, fl,
                                 ServeConfig(**kw)), fl

    def test_backpressure_rejects_under_burst(self):
        """A burst beyond queue capacity at a busy endpoint: capacity
        admitted, the rest rejected with a positive retry-after hint —
        and every outcome accounted for in a counter."""
        ctrl, _ = self._ctrl(queue_capacity=4, service_time=0.25,
                             adapt_every=0, retry_after_min=0.1)
        outcomes = []
        for i in range(12):  # simultaneous burst: service can't drain
            outcomes.append(ctrl.offer(_upload(ctrl, i, t=0.0), now=0.0))
            ctrl.pump(0.0)
        rejected = [a for a in outcomes if not a.accepted]
        assert ctrl.counters["admitted"] == 4
        assert ctrl.counters["rejected_queue_full"] == len(rejected) == 8
        assert all(a.reason == REJECT_QUEUE_FULL for a in rejected)
        assert all(a.retry_after >= 0.1 for a in rejected)
        # the hint scales with the modeled drain time of the full queue
        assert rejected[0].retry_after == pytest.approx(4 * 0.25)
        # once the service catches up, the queued uploads fold
        ctrl.pump(4 * 0.25)
        assert ctrl.counters["folded"] == 4
        assert ctrl.counters["rounds"] == 1

    def test_stale_uploads_dropped_with_counters(self):
        """Ingress drop when staleness > max_staleness; queued entries
        that out-age while waiting are evicted oldest-first."""
        ctrl, fl = self._ctrl(queue_capacity=8, service_time=0.0,
                              adapt_every=0)
        ctrl.version = 10
        adm = ctrl.offer(_upload(ctrl, 0, tau=fl.max_staleness + 1), now=0.0)
        assert not adm.accepted and adm.reason == DROP_MAX_STALENESS
        assert adm.retry_after == 0.0
        assert ctrl.counters["dropped_stale_ingress"] == 1
        # a queued upload at the staleness edge out-ages when the version
        # advances before it is serviced
        assert ctrl.offer(_upload(ctrl, 1, tau=fl.max_staleness),
                          now=0.0).accepted
        ctrl.version += 1  # round applied elsewhere; queue head now too old
        ctrl.offer(_upload(ctrl, 2, tau=0), now=0.1)
        assert ctrl.counters["dropped_stale_queue"] == 1
        assert len(ctrl.queue) == 1

    def test_queue_capacity_validated(self):
        with pytest.raises(ValueError, match="queue_capacity"):
            self._ctrl(queue_capacity=0)
        with pytest.raises(ValueError, match="k_min"):
            self._ctrl(k_min=4, k_max=2)

    def test_retry_after_clamped_to_max(self):
        """A deep queue with slow service would hint hours of backoff;
        the Admission contract clamps it to retry_after_max so clients
        re-probe on a bounded cadence."""
        ctrl, _ = self._ctrl(queue_capacity=8, service_time=10.0,
                             adapt_every=0, retry_after_max=5.0)
        for i in range(8):
            assert ctrl.offer(_upload(ctrl, i, t=0.0), now=0.0).accepted
        adm = ctrl.offer(_upload(ctrl, 9, t=0.0), now=0.0)
        assert not adm.accepted and adm.reason == REJECT_QUEUE_FULL
        # unclamped hint would be 8 * 10.0 = 80s of modeled drain
        assert adm.retry_after == 5.0


class TestAdaptiveK:
    def test_k_settles_to_arrival_rate_times_target(self):
        """Uniform arrivals at rate lambda: K converges to the fixed point
        lambda * target_round_latency and round cadence lands on target."""
        fl = FLConfig(buffer_size=4, local_steps=1, local_lr=0.1,
                      max_staleness=4)
        cfg = ServeConfig(queue_capacity=8, service_time=0.0,
                          target_round_latency=2.0, k_min=2, k_max=64,
                          adapt_every=2, adapt_gain=0.5, arrival_ewma=0.5)
        ctrl = ServingController(_quad_loss, PARAMS, fl, cfg)
        assert ctrl.k == 4
        gap = 0.125  # lambda = 8/s  ->  K* = 16
        for i in range(200):
            t = i * gap
            ctrl.offer(_upload(ctrl, i % 8, t=t), now=t)
            ctrl.pump(t)
        assert ctrl.k == 16
        assert ctrl.arrival_rate() == pytest.approx(8.0, rel=1e-3)
        # once settled, cadence == K / lambda == the latency target
        cadence = np.diff(ctrl.round_times[-4:])
        np.testing.assert_allclose(cadence, 2.0, atol=0.15)
        # the trajectory is recorded for telemetry
        assert ctrl.k_history[0] == (0, 4)
        assert ctrl.k_history[-1][1] == 16

    def test_fixed_k_when_adaptation_disabled(self):
        fl = FLConfig(buffer_size=4, local_steps=1, local_lr=0.1,
                      max_staleness=4)
        ctrl = ServingController(_quad_loss, PARAMS, fl,
                                 ServeConfig(adapt_every=0))
        for i in range(64):
            t = i * 0.01  # fast arrivals would push K up if enabled
            ctrl.offer(_upload(ctrl, i % 8, t=t), now=t)
            ctrl.pump(t)
        assert ctrl.k == 4 and ctrl.k_history == [(0, 4)]


class TestFedAsyncPolicies:
    def test_discount_family_shapes(self):
        tau = jnp.arange(0.0, 12.0)
        const = fedasync_discount("constant", tau)
        hinge = fedasync_discount("hinge", tau, hinge_a=10.0, hinge_b=6.0)
        poly = fedasync_discount("poly", tau, poly_a=0.5)
        np.testing.assert_allclose(const, 1.0)
        np.testing.assert_allclose(hinge[:7], 1.0)  # flat through tau == b
        np.testing.assert_allclose(hinge[7], 1.0 / 10.0)  # 1/(a*(tau-b))
        assert np.all(np.diff(poly) < 0)  # strictly decreasing
        np.testing.assert_allclose(poly, (1.0 + np.arange(12.0)) ** -0.5,
                                   rtol=1e-6)

    def test_all_discounts_are_one_at_tau_zero(self):
        """At tau=0 every FedAsync policy reduces to FedBuff's uniform
        weight — pinned through contribution_weights itself."""
        p = jnp.array([1.0, 2.0, 3.0])
        s = jnp.ones(3)
        tau = jnp.zeros(3)
        fb = contribution_weights("fedbuff", p, s, tau, normalize="none")
        for policy in FEDASYNC_POLICIES:
            w = contribution_weights(policy, p, s, tau, normalize="none")
            np.testing.assert_allclose(np.asarray(w), np.asarray(fb),
                                       rtol=1e-6, err_msg=policy)

    @pytest.mark.parametrize("policy", list(FEDASYNC_POLICIES))
    def test_serving_loop_parity_with_fedbuff_at_tau_zero(self, policy):
        """All-fresh traffic: the served model under each FedAsync
        discount is bit-comparable to FedBuff on the same stream."""
        def run(weighting):
            fl = FLConfig(buffer_size=3, local_steps=1, local_lr=0.1,
                          max_staleness=4, weighting=weighting,
                          normalize="none")
            ctrl = ServingController(_quad_loss, PARAMS, fl,
                                     ServeConfig(adapt_every=0))
            for i in range(9):
                ctrl.offer(_upload(ctrl, i, t=float(i)), now=float(i))
                ctrl.pump(float(i))
            assert ctrl.counters["rounds"] == 3
            return np.asarray(ctrl.params["w"])

        np.testing.assert_allclose(run(policy), run("fedbuff"),
                                   rtol=1e-6, atol=1e-7)


class TestServingParity:
    """The acceptance gate: serving-loop aggregate == apply_server_round
    on the same seeded upload stream, every weighting policy, f32 tol."""

    @pytest.mark.parametrize("policy", list(POLICIES))
    def test_serving_matches_apply_server_round(self, policy):
        k = 4
        fl = FLConfig(buffer_size=k, local_steps=1, local_lr=0.1,
                      weighting=policy, normalize="mean", global_lr=1.0,
                      max_staleness=k)
        ctrl = ServingController(_quad_loss, PARAMS, fl,
                                 ServeConfig(adapt_every=0, k_max=8))
        # seed the eq.-3 ring so staleness distances are non-trivial from
        # round one (afterwards it evolves with the real update norms)
        ctrl.update_norm_ring = jnp.array([0.3, 0.2, 0.1, 0.05])
        local_update = make_local_update_fn(_quad_loss, fl.local_steps,
                                            fl.local_lr, fl.local_momentum)
        taus = [0, 1, 2, 3]  # tau=0 present: pinned reference is exact
        sizes = [10.0, 20.0, 30.0, 40.0]
        key = jax.random.PRNGKey(0)
        t = 0.0
        for rnd in range(3):
            x_tree = ctrl.params
            ring = ctrl.update_norm_ring
            deltas, losses, batches = [], [], []
            for i in range(k):
                b = _quad_batch(jax.random.fold_in(key, 10 * rnd + i))
                pb = _quad_batch(jax.random.fold_in(key, 900 + 10 * rnd + i))
                stacked = jax.tree.map(lambda x: x[None], b)
                deltas.append(local_update(x_tree, stacked)[0])
                losses.append(_quad_loss(x_tree, pb)[0])
                batches.append((stacked, pb))
            for i, (stacked, pb) in enumerate(batches):
                t += 0.1
                up = Upload(client_id=i, base_version=ctrl.version - taus[i],
                            data_size=sizes[i], batch=stacked, probe=pb,
                            sent_at=t)
                assert ctrl.offer(up, now=t).reason == ADMITTED
                ctrl.pump(t)
            assert ctrl.version == rnd + 1  # the K-th fold applied eq. 5

            # exact path on the SAME stream: bases whose eq. 3 distances
            # equal the pre-round ring distances the streaming form used
            dists = np.array([float(jnp.sum(ring[:tt])) for tt in taus])
            spec = make_flat_spec(x_tree, fl.server_pass_block_n)
            x = flatten_tree(spec, x_tree)
            onehot = jnp.eye(spec.n_padded)[:k]
            bases = x[None] - jnp.sqrt(
                jnp.asarray(dists, jnp.float32))[:, None] * onehot
            deltas_flat = flatten_stacked(
                spec, jax.tree.map(lambda *xs: jnp.stack(xs), *deltas))
            new_x, _ = apply_server_round(
                x, bases, deltas_flat, jnp.asarray(losses, jnp.float32),
                jnp.asarray(sizes, jnp.float32),
                jnp.asarray(taus, jnp.float32), fl,
                mode="reference", block_n=spec.block_n)
            expect = unflatten_like(spec, new_x, x_tree)
            np.testing.assert_allclose(
                np.asarray(ctrl.params["w"]), np.asarray(expect["w"]),
                rtol=2e-5, atol=1e-6,
                err_msg=f"policy={policy} round={rnd}")


class TestServeStream:
    """End-to-end: scenario traffic through serve_stream, deterministic
    under a seed, with loss/retry accounting surfaced in the metrics."""

    def _run(self, scenario="dropout-bernoulli", seed=0, rounds=3):
        sc = get_scenario(scenario)
        n = 6
        clients, _ = sc.make_dataset(n, samples_per_client=16, seed=seed)
        fl = FLConfig(num_clients=n, buffer_size=3, max_staleness=6,
                      local_steps=1, batch_size=4)

        def loss(params, batch):
            x, y = batch
            x = x.reshape(x.shape[0], -1)
            return jnp.mean((x @ params["w"] - y) ** 2), {}

        params = {"w": jnp.zeros(784) }
        ctrl = ServingController(loss, params, fl,
                                 ServeConfig(queue_capacity=8))
        gen = TrafficGenerator(clients, sc.behavior(n, seed=seed), fl)
        out = serve_stream(ctrl, gen, max_rounds=rounds)
        return out, np.asarray(ctrl.params["w"])

    def test_deterministic_under_seed(self):
        out1, w1 = self._run(seed=0)
        out2, w2 = self._run(seed=0)
        assert out1["folded"] == out2["folded"]
        assert out1["rounds"] == out2["rounds"]
        assert out1["lost_in_transit"] == out2["lost_in_transit"]
        np.testing.assert_array_equal(w1, w2)

    def test_dropouts_are_counted_not_folded(self):
        out, _ = self._run(scenario="dropout-bernoulli", rounds=4)
        assert out["lost_in_transit"] > 0
        assert out["folded"] + out["lost_in_transit"] <= out["events"]
        assert out["rounds"] == 4

    def test_requires_a_bound(self):
        fl = FLConfig(buffer_size=2, max_staleness=4)
        ctrl = ServingController(_quad_loss, PARAMS, fl, ServeConfig())
        with pytest.raises(ValueError, match="max_rounds"):
            serve_stream(ctrl, object())


class TestOverloadRetry:
    """Backpressure end to end through TrafficGenerator: every
    queue_full rejection is re-offered after EXACTLY the hinted delay
    with the SAME (now staler) payload, and every offer lands in exactly
    one admission counter."""

    def test_rejections_reoffered_at_hint_with_same_payload(self):
        sc = get_scenario("paper-fig1")
        n = 4
        clients, _ = sc.make_dataset(n, samples_per_client=16, seed=0)
        fl = FLConfig(num_clients=n, buffer_size=2, max_staleness=100,
                      local_steps=1, batch_size=4)

        def loss(params, batch):
            x, y = batch
            x = x.reshape(x.shape[0], -1)
            return jnp.mean((x @ params["w"] - y) ** 2), {}

        # one queue slot + slow modeled service: arrivals outpace the
        # fold drain, so the generator's retry path gets exercised hard
        ctrl = ServingController(
            loss, {"w": jnp.zeros(784)}, fl,
            ServeConfig(queue_capacity=1, service_time=0.9,
                        adapt_every=0, retry_after_min=0.05))
        gen = TrafficGenerator(clients, sc.behavior(n, seed=0), fl)
        horizon = 40.0
        log = []  # (t, cid, upload, admission) for every real offer
        while not gen.empty():
            t, cid = gen.pop()
            if t > horizon:
                break
            up = gen.realize(cid, t, ctrl.version)
            if up is None:
                continue
            adm = ctrl.offer(up, t)
            ctrl.pump(t)
            log.append((t, cid, up, adm))
            gen.settle(cid, t, adm, ctrl.version, up)

        rejections = [(i, e) for i, e in enumerate(log)
                      if e[3].reason == REJECT_QUEUE_FULL]
        assert len(rejections) >= 3, "config failed to provoke overload"
        for i, (t, cid, up, adm) in rejections:
            if t + adm.retry_after > horizon:
                continue  # retry scheduled past the cut
            later = [e for e in log[i + 1:] if e[1] == cid]
            assert later, f"rejection at t={t} never re-offered"
            rt, _, rup, _ = later[0]
            assert rup is up  # SAME payload object, held in gen.pending
            assert rt == t + adm.retry_after  # exact heap arithmetic
            assert rup.base_version == up.base_version  # staler, not redrawn
        # reconciliation: offered == admitted + rejected + dropped
        c = ctrl.counters
        assert len(log) == (c["admitted"] + c["rejected_queue_full"]
                            + c["dropped_stale_ingress"])
        assert gen.retries == len(rejections)

"""Observability plane (src/repro/obs, DESIGN.md §9): registry semantics,
histogram-quantile accuracy vs numpy, Chrome-trace schema + coverage,
sink gating, and the migration contracts — ServingController.metrics()
parity with the registry, the engine's dispatch counter backing
SimResult.num_launches, and bench provenance compatibility checks."""
import json
import logging
import math
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.serving import ServeConfig, ServingController, serve_stream
from repro.obs import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    InMemorySink,
    JsonlSink,
    MetricsRegistry,
    Tracer,
    configure_logging,
    emit_snapshot,
    merge_snapshots,
)
from repro.obs.trace import (
    NULL_TRACER,
    SPAN_APPLY,
    SPAN_COLLECT,
    SPAN_NAMES,
    _NULL_SPAN,
    span_coverage,
    validate_trace,
)
from repro.sim.arrivals import TrafficGenerator
from repro.sim.engine import run_vectorized
from repro.sim.scenarios import get_scenario

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:  # benchmarks/ is a repo-root namespace package
    sys.path.insert(0, ROOT)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_get_or_create_returns_same_instrument(self):
        reg = MetricsRegistry()
        a = reg.counter("x", route="fold")
        b = reg.counter("x", route="fold")
        assert a is b
        a.inc(2)
        assert b.value == 2.0

    def test_label_order_is_canonical(self):
        reg = MetricsRegistry()
        assert reg.counter("x", b=1, a=2) is reg.counter("x", a=2, b=1)
        assert reg.counter("x", a=2, b=1).key == "x{a=2,b=1}"

    def test_type_conflict_rejected(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("x")

    def test_counter_is_monotonic(self):
        c = Counter("c")
        with pytest.raises(ValueError, match="cannot decrease"):
            c.inc(-1)

    def test_gauge_last_write_wins(self):
        g = Gauge("g")
        g.set(3)
        g.inc(2)
        assert g.value == 5.0

    def test_snapshot_is_flat_and_sorted(self):
        reg = MetricsRegistry()
        reg.counter("b_total").inc(3)
        reg.gauge("a_depth").set(7)
        h = reg.histogram("lat", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        h.observe(5.0)  # +inf overflow
        snap = reg.snapshot()
        assert list(snap) == sorted(snap)
        assert snap["b_total"] == 3.0 and snap["a_depth"] == 7.0
        # cumulative le buckets + overflow into +Inf
        assert snap["lat_bucket{le=0.1}"] == 1.0
        assert snap["lat_bucket{le=1.0}"] == 2.0
        assert snap["lat_bucket{le=+Inf}"] == 3.0
        assert snap["lat_count"] == 3.0
        assert snap["lat_sum"] == pytest.approx(5.55)
        assert all(isinstance(v, float) for v in snap.values())

    def test_merge_sums_counters_and_keeps_last_gauge(self):
        regs = []
        for pid in range(3):
            reg = MetricsRegistry()
            reg.counter("folds_total").inc(10 * (pid + 1))
            reg.gauge("queue_depth").set(pid)
            regs.append(reg)
        merged = merge_snapshots([r.snapshot() for r in regs],
                                 gauge_keys=regs[0].gauge_keys())
        assert merged["folds_total"] == 60.0
        assert merged["queue_depth"] == 2.0  # last process's read, not sum

    def test_histogram_rejects_bad_edges(self):
        with pytest.raises(ValueError, match="strictly increasing"):
            Histogram("h", buckets=(1.0, 1.0, 2.0))
        with pytest.raises(ValueError, match="strictly increasing"):
            Histogram("h", buckets=())


class TestHistogramQuantiles:
    def test_quantiles_track_numpy_within_bucket_width(self):
        """Linear interpolation inside the winning bucket: the error
        bound is that bucket's width, checked against exact numpy
        percentiles on a seeded latency-like sample."""
        rng = np.random.default_rng(0)
        samples = rng.lognormal(mean=-3.0, sigma=1.2, size=4000)
        samples = samples[samples < DEFAULT_BUCKETS[-1]]
        h = Histogram("lat")
        for x in samples:
            h.observe(float(x))
        edges = (0.0,) + DEFAULT_BUCKETS
        for q in (0.1, 0.5, 0.9, 0.99):
            exact = float(np.percentile(samples, 100 * q))
            approx = h.quantile(q)
            i = int(np.searchsorted(DEFAULT_BUCKETS, exact))
            width = edges[i + 1] - edges[i]
            assert abs(approx - exact) <= width, (q, exact, approx)

    def test_edge_cases(self):
        h = Histogram("h", buckets=(1.0, 2.0))
        assert math.isnan(h.quantile(0.5))
        h.observe(10.0)  # only the overflow bucket populated
        assert h.quantile(0.5) == 2.0  # top finite edge: no upper bound
        with pytest.raises(ValueError, match="outside"):
            h.quantile(1.5)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


class TestTracer:
    def test_span_records_valid_chrome_trace(self):
        tr = Tracer(annotate=False)
        with tr.span(SPAN_APPLY, version=3):
            pass
        tr.instant("marker")
        doc = tr.to_json()
        assert validate_trace(doc) == 2
        ev = doc["traceEvents"][0]
        assert ev["name"] == SPAN_APPLY and ev["ph"] == "X"
        assert ev["dur"] >= 0 and ev["args"] == {"version": 3}
        assert ev["pid"] == os.getpid()

    def test_disabled_tracer_is_free(self):
        tr = Tracer(enabled=False)
        assert tr.span("x") is _NULL_SPAN  # the ONE shared no-op context
        assert NULL_TRACER.span("y") is _NULL_SPAN
        with tr.span("x"):
            pass
        tr.complete("x", 0.0, 1.0)
        tr.instant("x")
        assert tr.events == []

    def test_retroactive_complete(self):
        tr = Tracer(annotate=False)
        t0 = tr.now()
        tr.complete(SPAN_COLLECT, t0, 0.25)
        (ev,) = tr.events
        assert ev["dur"] == pytest.approx(0.25e6)

    def test_write_and_validate_roundtrip(self, tmp_path):
        tr = Tracer(annotate=False)
        with tr.span(SPAN_APPLY):
            pass
        path = tr.write(str(tmp_path / "t.json"))
        with open(path) as f:
            doc = json.load(f)
        assert validate_trace(doc) == 1
        assert doc["displayTimeUnit"] == "ms"

    def test_validate_rejects_malformed(self):
        with pytest.raises(ValueError, match="traceEvents"):
            validate_trace({"events": []})
        with pytest.raises(ValueError, match="missing"):
            validate_trace({"traceEvents": [{"ph": "X"}]})
        bad = {"traceEvents": [{"name": "a", "ph": "X", "ts": 0,
                                "pid": 1, "tid": 0, "dur": -1}]}
        with pytest.raises(ValueError, match="non-negative dur"):
            validate_trace(bad)

    def test_span_coverage_union(self):
        def ev(name, ts, dur):
            return {"name": name, "cat": "round", "ph": "X", "ts": ts,
                    "dur": dur, "pid": 1, "tid": 0}

        # [0, 40) covered out of [0, 50): overlap must not double-count
        doc = {"traceEvents": [ev(SPAN_COLLECT, 0, 30),
                               ev(SPAN_APPLY, 20, 20),
                               ev(SPAN_APPLY, 45, 5),
                               ev("other", 0, 50)]}
        assert span_coverage(doc) == pytest.approx(0.9)
        assert span_coverage({"traceEvents": []}) == 0.0


# ---------------------------------------------------------------------------
# sinks + logging
# ---------------------------------------------------------------------------


class TestSinks:
    def test_jsonl_sink_writes_lines(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        sink = JsonlSink(path, gate=lambda: True)
        sink.emit({"event": "a", "n": 1})
        sink.emit({"event": "b"})
        sink.close()
        lines = [json.loads(ln) for ln in open(path)]
        assert [ln["event"] for ln in lines] == ["a", "b"]
        assert all("t" in ln for ln in lines)  # wall-clock stamp

    def test_gated_out_sink_never_creates_the_file(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        sink = JsonlSink(path, gate=lambda: False)
        sink.emit({"event": "a"})
        sink.flush()
        sink.close()
        assert not os.path.exists(path)  # lazy open: no create, no truncate

    def test_emit_snapshot_shape(self):
        reg = MetricsRegistry()
        reg.counter("x_total").inc(4)
        sink = InMemorySink()
        emit_snapshot(sink, reg, version=7)
        (ev,) = sink.events
        assert ev["event"] == "metrics_snapshot" and ev["version"] == 7
        assert ev["metrics"] == {"x_total": 4.0}

    def test_configure_logging_idempotent(self):
        root = logging.getLogger()
        configure_logging("info")
        n = len(root.handlers)
        configure_logging("debug")
        assert len(root.handlers) == n  # later calls only move the level
        assert root.level == logging.DEBUG
        configure_logging("warning")
        with pytest.raises(ValueError, match="unknown log level"):
            configure_logging("loud")


# ---------------------------------------------------------------------------
# serving migration: metrics() parity + trace coverage
# ---------------------------------------------------------------------------


def _quad_loss(params, batch):
    x, y = batch
    return jnp.mean((x @ params["w"] - y) ** 2), {}


def _quad_batch(key, n=8, d=4):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (n, d))
    y = x @ jnp.arange(1.0, d + 1.0) + 0.01 * jax.random.normal(k2, (n,))
    return x, y


PARAMS = {"w": jnp.array([1.0, -1.0, 0.5, 2.0])}


def _upload(ctrl, i, tau=0, t=0.0):
    from repro.core.serving import Upload

    key = jax.random.PRNGKey(0)
    b = _quad_batch(jax.random.fold_in(key, i))
    return Upload(client_id=i, base_version=ctrl.version - tau,
                  data_size=10.0,
                  batch=jax.tree.map(lambda x: x[None], b),
                  probe=_quad_batch(jax.random.fold_in(key, 100 + i)),
                  sent_at=t)


class TestServingRegistryParity:
    """The counters moved onto an obs registry; the historical
    ``metrics()`` dict shape (what bench_serve.py gates on) must be
    unchanged, and the registry snapshot must mirror every counter."""

    SERIES = {
        "admitted": "serve_admitted_total",
        "rejected_queue_full": "serve_rejected_total{reason=queue_full}",
        "dropped_stale_ingress": "serve_dropped_total{reason=stale_ingress}",
        "dropped_stale_queue": "serve_dropped_total{reason=stale_queue}",
        "folded": "serve_folded_total",
        "rounds": "serve_rounds_total",
    }

    def _exercised_controller(self):
        """A seeded stream that hits EVERY admission outcome: admit,
        queue-full reject, stale-at-ingress drop, stale-in-queue drop."""
        fl = FLConfig(buffer_size=4, local_steps=1, local_lr=0.1,
                      max_staleness=4)
        reg = MetricsRegistry()
        ctrl = ServingController(
            _quad_loss, PARAMS, fl,
            ServeConfig(queue_capacity=4, service_time=0.25, adapt_every=0,
                        retry_after_min=0.1),
            registry=reg)
        expect = dict.fromkeys(self.SERIES, 0)
        for i in range(8):  # burst past capacity: 4 admitted, 4 rejected
            adm = ctrl.offer(_upload(ctrl, i, t=0.0), now=0.0)
            expect["admitted" if adm.accepted
                   else "rejected_queue_full"] += 1
            ctrl.pump(0.0)
        ctrl.pump(4 * 0.25)  # drain: one full round folds + applies
        expect["folded"] += 4
        expect["rounds"] += 1
        adm = ctrl.offer(_upload(ctrl, 0, tau=fl.max_staleness + 1), now=2.0)
        assert not adm.accepted
        expect["dropped_stale_ingress"] += 1
        assert ctrl.offer(_upload(ctrl, 1, tau=fl.max_staleness),
                          now=2.0).accepted
        expect["admitted"] += 1
        ctrl.version += 1  # queue head out-ages before service
        ctrl.offer(_upload(ctrl, 2, tau=0), now=2.1)
        expect["admitted"] += 1
        expect["dropped_stale_queue"] += 1
        assert all(v > 0 for v in expect.values()), expect
        return ctrl, reg, expect

    def test_counters_match_independent_accounting(self):
        ctrl, reg, expect = self._exercised_controller()
        assert ctrl.counters == expect
        snap = reg.snapshot()
        for dict_key, series in self.SERIES.items():
            assert snap[series] == float(expect[dict_key]), series

    def test_metrics_dict_shape_unchanged(self):
        ctrl, _, expect = self._exercised_controller()
        m = ctrl.metrics()
        for key in (*expect, "k", "k_history", "version", "arrival_rate",
                    "round_latency_p50", "round_latency_p99",
                    "round_cadence_mean", "queue_depth_now",
                    "queue_depth_max"):
            assert key in m, key
        assert m["admitted"] == expect["admitted"]
        assert isinstance(m["admitted"], int)  # not a float counter leak

    def test_gauges_and_latency_histogram_populated(self):
        ctrl, reg, _ = self._exercised_controller()
        snap = reg.snapshot()
        assert snap["serve_k"] == float(ctrl.k)
        assert snap["serve_queue_depth"] == float(len(ctrl.queue))
        assert snap["serve_round_latency_seconds_count"] == float(
            len(ctrl.round_latencies))

    def test_private_registries_do_not_alias(self):
        fl = FLConfig(buffer_size=4, local_steps=1, local_lr=0.1,
                      max_staleness=4)
        a = ServingController(_quad_loss, PARAMS, fl, ServeConfig())
        b = ServingController(_quad_loss, PARAMS, fl, ServeConfig())
        a.offer(_upload(a, 0), now=0.0)
        assert a.counters["admitted"] == 1
        assert b.counters["admitted"] == 0


class TestServeTraceCoverage:
    def test_round_spans_cover_measured_walltime(self):
        """The acceptance gate for serve_fl --trace-out, in-process:
        collect_window/apply spans tile >= 95% of the round window."""
        sc = get_scenario("paper-fig1")
        n = 8
        clients, _ = sc.make_dataset(n, samples_per_client=16, seed=0)
        fl = FLConfig(num_clients=n, buffer_size=3, max_staleness=6,
                      local_steps=1, batch_size=4)

        def loss(params, batch):
            x, y = batch
            x = x.reshape(x.shape[0], -1)
            return jnp.mean((x @ params["w"] - y) ** 2), {}

        tracer = Tracer(annotate=False)
        ctrl = ServingController(loss, {"w": jnp.zeros(784)}, fl,
                                 ServeConfig(queue_capacity=8),
                                 tracer=tracer)
        gen = TrafficGenerator(clients, sc.behavior(n, seed=0), fl)
        hook_versions = []
        serve_stream(ctrl, gen, max_rounds=4,
                     round_hook=hook_versions.append)
        assert hook_versions == [1, 2, 3, 4]  # once per applied round
        doc = tracer.to_json()
        validate_trace(doc)
        names = {ev["name"] for ev in doc["traceEvents"]}
        assert names <= set(SPAN_NAMES)
        assert {"collect_window", "contribute", "apply"} <= names
        assert span_coverage(doc) >= 0.95


# ---------------------------------------------------------------------------
# engine migration: dispatch counter backs num_launches
# ---------------------------------------------------------------------------


def _quad_clients(n=6, size=64, d=4, seed=0):
    from repro.data.synthetic import ClientDataset

    rng = np.random.default_rng(seed)
    w_true = np.arange(1.0, d + 1.0)
    out = []
    for i in range(n):
        x = rng.normal(size=(size, d)).astype(np.float32)
        y = (x @ w_true + 0.05 * rng.normal(size=size)).astype(np.float32)
        out.append(ClientDataset(x=x, y=y, seed=seed + 10 + i))
    return out


class TestEngineDispatchCounter:
    FL = FLConfig(num_clients=6, buffer_size=3, local_steps=2,
                  local_lr=0.05, batch_size=8, max_staleness=4)

    def test_num_launches_is_a_registry_view(self):
        reg = MetricsRegistry()
        res = run_vectorized(_quad_loss, {"w": jnp.zeros(4)},
                             _quad_clients(), self.FL, total_rounds=10,
                             seed=0, rounds_per_launch=4, registry=reg)
        snap = reg.snapshot()
        assert res.num_launches == 3  # ceil(10 / 4)
        assert snap["engine_dispatches_total"] == 3.0
        assert snap["engine_launch_seconds_count"] == 3.0
        assert snap["engine_host_syncs_total"] >= 1.0  # the round-log fetch

    def test_counter_accumulates_but_result_delta_does_not(self):
        """Two runs on one registry: the counter keeps global totals,
        each SimResult reports only its own dispatches."""
        reg = MetricsRegistry()
        for _ in range(2):
            res = run_vectorized(_quad_loss, {"w": jnp.zeros(4)},
                                 _quad_clients(), self.FL, total_rounds=8,
                                 seed=0, rounds_per_launch=4, registry=reg)
            assert res.num_launches == 2
        assert reg.snapshot()["engine_dispatches_total"] == 4.0

    def test_engine_emits_round_spans(self):
        tracer = Tracer(annotate=False)
        run_vectorized(_quad_loss, {"w": jnp.zeros(4)}, _quad_clients(),
                       self.FL, total_rounds=8, seed=0, rounds_per_launch=4,
                       registry=MetricsRegistry(), tracer=tracer)
        doc = tracer.to_json()
        validate_trace(doc)
        by_name = {}
        for ev in doc["traceEvents"]:
            by_name.setdefault(ev["name"], []).append(ev)
        assert len(by_name[SPAN_APPLY]) == 2  # one per dispatch
        assert SPAN_COLLECT in by_name and "host_sync" in by_name


# ---------------------------------------------------------------------------
# bench provenance
# ---------------------------------------------------------------------------


class TestBenchProvenance:
    def test_run_metadata_keys(self):
        from benchmarks.common import run_metadata

        meta = run_metadata()
        for key in ("jax_version", "backend", "device_kind", "device_count",
                    "process_count", "git_sha", "timestamp_utc"):
            assert key in meta, key
        assert meta["backend"] == jax.default_backend()
        assert meta["device_count"] >= 1 and meta["process_count"] >= 1

    def test_write_bench_json_stamps_and_merges_meta(self, tmp_path):
        from benchmarks.common import write_bench_json

        path = write_bench_json(str(tmp_path / "b.json"),
                                {"x": 1, "meta": {"note": "kept"}})
        doc = json.load(open(path))
        assert doc["x"] == 1
        assert doc["meta"]["note"] == "kept"  # bench-specific keys win
        assert doc["meta"]["backend"] == jax.default_backend()

    def test_cross_backend_comparison_refused(self):
        from benchmarks.check_regression import backend_mismatch

        tpu = {"meta": {"backend": "tpu", "device_kind": "TPU v4"}}
        cpu = {"meta": {"backend": "cpu", "device_kind": "cpu"}}
        assert "backend" in backend_mismatch(tpu, cpu)
        assert backend_mismatch(cpu, cpu) is None
        # device-kind delta within one backend is also a hardware delta
        v5 = {"meta": {"backend": "tpu", "device_kind": "TPU v5e"}}
        assert "device_kind" in backend_mismatch(tpu, v5)

    def test_legacy_docs_compare_on_normalized_backend(self):
        from benchmarks.check_regression import backend_mismatch

        legacy = {"backend": "cpu (forced host devices; measures program "
                             "structure, not speedup)"}
        stamped = {"meta": {"backend": "cpu", "device_kind": "cpu"}}
        assert backend_mismatch(legacy, stamped) is None  # no bogus skip
        assert backend_mismatch(legacy, {"meta": {"backend": "tpu"}})
        assert backend_mismatch({}, stamped) is None  # nothing to compare

"""Aggregation (eq. 5) properties + Pallas-fused equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.aggregation import aggregate, aggregate_fused
from repro.utils.pytree import (
    tree_flatten_to_vector,
    tree_stack,
    tree_sub,
    tree_weighted_sum,
)


def _params(key, scale=1.0):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(k1, (8, 16)) * scale,
        "b": jax.random.normal(k2, (16,)) * scale,
        "nested": {"v": jax.random.normal(k3, (4, 4, 2)) * scale},
    }


class TestAggregate:
    def test_fedbuff_equivalence(self):
        """Uniform weights reproduce FedBuff's plain average (eq. 2)."""
        key = jax.random.PRNGKey(0)
        x = _params(key)
        deltas = [_params(jax.random.PRNGKey(i + 1)) for i in range(4)]
        stacked = tree_stack(deltas)
        new, _ = aggregate(x, stacked, jnp.ones(4), eta_g=1.0, k=4)
        mean = jax.tree.map(lambda *ds: sum(ds) / 4.0, *deltas)
        expect = tree_sub(x, mean)
        np.testing.assert_allclose(tree_flatten_to_vector(new),
                                   tree_flatten_to_vector(expect), rtol=1e-5)

    def test_permutation_invariance(self):
        key = jax.random.PRNGKey(0)
        x = _params(key)
        deltas = [_params(jax.random.PRNGKey(i + 1)) for i in range(5)]
        w = jnp.array([0.5, 1.5, 1.0, 0.7, 1.3])
        perm = [3, 1, 4, 0, 2]
        a1, _ = aggregate(x, tree_stack(deltas), w, 1.0, 5)
        a2, _ = aggregate(x, tree_stack([deltas[i] for i in perm]),
                          w[jnp.array(perm)], 1.0, 5)
        np.testing.assert_allclose(tree_flatten_to_vector(a1),
                                   tree_flatten_to_vector(a2), rtol=1e-5)

    def test_zero_weights_no_update(self):
        key = jax.random.PRNGKey(0)
        x = _params(key)
        stacked = tree_stack([_params(jax.random.PRNGKey(7))] * 3)
        new, _ = aggregate(x, stacked, jnp.zeros(3), eta_g=1.0, k=3)
        np.testing.assert_allclose(tree_flatten_to_vector(new),
                                   tree_flatten_to_vector(x), rtol=1e-6)

    @given(st.floats(min_value=0.1, max_value=2.0),
           st.integers(min_value=1, max_value=6))
    @settings(max_examples=20, deadline=None)
    def test_eta_scaling(self, eta, k):
        """Update scales linearly in eta_g (pytree = flat-vector equiv)."""
        key = jax.random.PRNGKey(0)
        x = _params(key)
        deltas = [_params(jax.random.PRNGKey(i + 1)) for i in range(k)]
        w = jnp.ones(k)
        _, upd1 = aggregate(x, tree_stack(deltas), w, 1.0, k)
        _, upd2 = aggregate(x, tree_stack(deltas), w, float(eta), k)
        np.testing.assert_allclose(tree_flatten_to_vector(upd2),
                                   tree_flatten_to_vector(upd1) * eta,
                                   rtol=1e-4)

    def test_pytree_equals_flat_vector(self):
        """Aggregating leaf-wise == aggregating the flattened vector."""
        key = jax.random.PRNGKey(3)
        x = _params(key)
        deltas = [_params(jax.random.PRNGKey(i + 10)) for i in range(3)]
        w = jnp.array([0.2, 1.1, 1.7])
        _, upd = aggregate(x, tree_stack(deltas), w, 1.0, 3)
        flat_deltas = jnp.stack([tree_flatten_to_vector(d) for d in deltas])
        flat_upd = (w / 3.0) @ flat_deltas
        # atol absorbs f32 accumulation-order noise (leaf-wise vs flat sum)
        np.testing.assert_allclose(tree_flatten_to_vector(upd), flat_upd,
                                   rtol=1e-5, atol=1e-6)


class TestFusedAggregate:
    def test_matches_xla_path(self):
        key = jax.random.PRNGKey(0)
        x = _params(key)
        deltas = [_params(jax.random.PRNGKey(i + 1)) for i in range(4)]
        w = jnp.array([0.5, 2.0, 1.0, 0.5])
        stacked = tree_stack(deltas)
        a1, u1 = aggregate(x, stacked, w, 0.7, 4)
        a2, u2 = aggregate_fused(x, stacked, w, 0.7, 4, interpret=True)
        np.testing.assert_allclose(tree_flatten_to_vector(a1),
                                   tree_flatten_to_vector(a2), rtol=1e-4,
                                   atol=1e-6)

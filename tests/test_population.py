"""Device-resident population engine (sim/population.py, DESIGN.md §10).

Parity contract: at small N the device event machine — counter-based
threefry draws, vmapped behavior kernel, top-k window selection — must
reproduce the host event walk EVENT FOR EVENT, and ``run_population``
must match ``run_vectorized`` driven by the counter twins
(``CounterBehavior`` / ``CounterDataset``) round for round. Checkpoints
are plain integer counters: resume must be bit-identical.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import run_async
from repro.data.synthetic import ClientDataset
from repro.sim import get_scenario
from repro.sim.engine import run_vectorized
from repro.sim.population import (
    CounterBehavior,
    CounterDataset,
    DevicePool,
    collect_windows,
    host_walk_windows,
    make_counter_clients,
    population_state_from_tree,
    population_state_to_tree,
    run_population,
)
from repro.sim.scenarios import LatencyModel


def _quad_loss(params, batch):
    x, y = batch
    return jnp.mean((x @ params["w"] - y) ** 2), {}


def _quad_clients(n=6, size=64, d=4, seed=0):
    rng = np.random.default_rng(seed)
    w_true = np.arange(1.0, d + 1.0)
    out = []
    for i in range(n):
        x = rng.normal(size=(size, d)).astype(np.float32)
        y = (x @ w_true + 0.05 * rng.normal(size=size)).astype(np.float32)
        out.append(ClientDataset(x=x, y=y, seed=seed + 10 + i))
    return out


def _params(d=4):
    return {"w": jnp.zeros(d)}


def _eval_fn(params):
    return {"wnorm": float(jnp.sum(params["w"] ** 2))}


FL = FLConfig(num_clients=6, buffer_size=3, local_steps=2, local_lr=0.05,
              batch_size=8, max_staleness=4)


def _fl(n, k):
    return FLConfig(num_clients=n, buffer_size=k, local_steps=2,
                    local_lr=0.05, batch_size=8, max_staleness=4)


def _assert_windows_equal(dev, host):
    np.testing.assert_array_equal(dev["clients"], host["clients"])
    np.testing.assert_array_equal(dev["tau"], host["tau"])
    np.testing.assert_array_equal(dev["slots"], host["slots"])
    np.testing.assert_allclose(dev["t"], host["t"], rtol=1e-5, atol=1e-5)
    assert dev["num_events"] == host["num_events"]


class TestEventParity:
    """Device top-k windows == host heapq walk on the same counter
    streams, across behavior models (drops, traces, diurnal gates,
    bursts, tiers)."""

    @pytest.mark.parametrize("preset", [
        "paper-fig1", "diurnal-phones", "dropout-bernoulli",
        "straggler-burst", "dropout-trace", "bandwidth-tiers"])
    def test_presets(self, preset):
        sc = get_scenario(preset)
        n, k, t, seed = 8, 3, 12, 3
        fl = _fl(n, k)
        dev = collect_windows(sc, n, fl, t, seed=seed)
        host = host_walk_windows(CounterBehavior(sc, n, seed=seed), fl, t)
        _assert_windows_equal(dev, host)

    @pytest.mark.parametrize("preset", ["paper-fig1", "dropout-bernoulli"])
    def test_reentry_windows(self, preset):
        # n barely above K: clients re-enter windows, forcing the exact
        # while_loop fallback — must still match the heap walk
        sc = get_scenario(preset)
        n, k, t, seed = 4, 3, 12, 5
        fl = _fl(n, k)
        dev = collect_windows(sc, n, fl, t, seed=seed)
        host = host_walk_windows(CounterBehavior(sc, n, seed=seed), fl, t)
        _assert_windows_equal(dev, host)

    def test_k_exceeds_n_forced_exact(self):
        sc = get_scenario("paper-fig1")
        n, k, t, seed = 3, 5, 6, 1
        fl = _fl(n, k)
        dev = collect_windows(sc, n, fl, t, seed=seed)
        host = host_walk_windows(CounterBehavior(sc, n, seed=seed), fl, t)
        _assert_windows_equal(dev, host)


class TestEngineParity:
    """run_population == run_vectorized over the counter twins: same
    windows, same training rounds, same eval history."""

    @pytest.mark.parametrize("preset", [
        "paper-fig1", "dropout-bernoulli", "diurnal-phones"])
    def test_full_run(self, preset):
        sc = get_scenario(preset)
        clients = _quad_clients()
        res_p = run_population(_quad_loss, _params(), clients, FL,
                               total_rounds=10, eval_fn=_eval_fn,
                               eval_every=5, scenario=sc, seed=3)
        res_v = run_vectorized(_quad_loss, _params(),
                               make_counter_clients(_quad_clients(), seed=3),
                               FL, total_rounds=10, eval_fn=_eval_fn,
                               eval_every=5,
                               behavior=CounterBehavior(sc, 6, seed=3),
                               seed=3)
        assert res_p.num_events == res_v.num_events
        assert res_p.server_rounds == res_v.server_rounds == 10
        assert np.isclose(res_p.sim_time, res_v.sim_time, rtol=1e-6)
        assert len(res_p.round_log) == len(res_v.round_log) == 10
        for lp, lv in zip(res_p.round_log, res_v.round_log):
            assert lp["clients"] == lv["clients"]
            assert lp["tau"] == lv["tau"]
            assert lp["version"] == lv["version"]
            assert lp["k"] == lv["k"]
            np.testing.assert_allclose(lp["weights"], lv["weights"],
                                       rtol=1e-4, atol=1e-6)
            np.testing.assert_allclose(lp["sq_dists"], lv["sq_dists"],
                                       rtol=1e-4, atol=1e-6)
        assert [h["round"] for h in res_p.history] == \
               [h["round"] for h in res_v.history]
        for hp, hv in zip(res_p.history, res_v.history):
            assert np.isclose(hp["time"], hv["time"], rtol=1e-6, atol=1e-6)
            assert np.isclose(hp["wnorm"], hv["wnorm"], rtol=1e-4, atol=1e-6)

    def test_single_launch_per_chunk(self):
        sc = get_scenario("paper-fig1")
        res = run_population(_quad_loss, _params(), _quad_clients(), FL,
                             total_rounds=8, scenario=sc, seed=0,
                             rounds_per_launch=8)
        assert res.server_rounds == 8
        assert res.num_launches <= 2  # init + one scan chunk

    def test_latency_model_rejected(self):
        with pytest.raises(ValueError, match="LatencyModel"):
            run_population(_quad_loss, _params(), _quad_clients(), FL,
                           total_rounds=2,
                           scenario=get_scenario("paper-fig1"),
                           latency=LatencyModel(speed_factors=[1.0] * 6),
                           seed=0)

    def test_run_async_dispatch(self):
        res = run_async(_quad_loss, _params(), _quad_clients(), FL,
                        total_rounds=4, engine="population",
                        scenario=get_scenario("paper-fig1"), seed=0)
        assert res.server_rounds == 4
        assert len(res.round_log) == 4


class TestCheckpointResume:
    """Counter checkpoints: plain integer arrays, bit-identical resume."""

    def test_resume_bit_identical(self):
        sc = get_scenario("dropout-bernoulli")
        kw = dict(eval_fn=_eval_fn, eval_every=4, scenario=sc, seed=9)
        full = run_population(_quad_loss, _params(), _quad_clients(), FL,
                              total_rounds=12, **kw)
        half = run_population(_quad_loss, _params(), _quad_clients(), FL,
                              total_rounds=6, capture_state=True, **kw)
        resumed = run_population(_quad_loss, _params(), _quad_clients(), FL,
                                 total_rounds=12,
                                 init_state=half.final_state, **kw)
        assert resumed.num_events == full.num_events
        assert np.isclose(resumed.sim_time, full.sim_time)
        assert len(resumed.round_log) == len(full.round_log)
        for lr, lf in zip(resumed.round_log, full.round_log):
            assert lr["clients"] == lf["clients"]
            assert lr["tau"] == lf["tau"]
            np.testing.assert_array_equal(np.asarray(lr["weights"]),
                                          np.asarray(lf["weights"]))
        assert [(h["round"], h["time"], h["wnorm"])
                for h in resumed.history] == \
               [(h["round"], h["time"], h["wnorm"]) for h in full.history]

    def test_state_tree_round_trip(self):
        sc = get_scenario("dropout-bernoulli")
        kw = dict(eval_fn=_eval_fn, eval_every=4, scenario=sc, seed=9)
        half = run_population(_quad_loss, _params(), _quad_clients(), FL,
                              total_rounds=6, capture_state=True, **kw)
        tree = population_state_to_tree(half.final_state)
        state2 = population_state_from_tree(tree)
        res_a = run_population(_quad_loss, _params(), _quad_clients(), FL,
                               total_rounds=12,
                               init_state=half.final_state, **kw)
        res_b = run_population(_quad_loss, _params(), _quad_clients(), FL,
                               total_rounds=12, init_state=state2, **kw)
        for la, lb in zip(res_a.round_log, res_b.round_log):
            np.testing.assert_array_equal(np.asarray(la["weights"]),
                                          np.asarray(lb["weights"]))


class TestCounterTwins:
    """CounterBehavior / CounterDataset: the host-side consumers of the
    device counter streams."""

    def test_behavior_counter_checkpoint(self):
        sc = get_scenario("dropout-bernoulli")
        beh = CounterBehavior(sc, 4, seed=7)
        for cid in range(4):
            beh.duration(cid, 1.0)
            beh.next_upload(cid)
        snap = beh.get_state()
        a = [beh.duration(cid, 2.0) for cid in range(4)]
        beh2 = CounterBehavior(sc, 4, seed=7)
        beh2.set_state(snap)
        b = [beh2.duration(cid, 2.0) for cid in range(4)]
        assert a == b

    def test_dataset_counter_draws(self):
        base = _quad_clients(n=2)[0]
        ds = CounterDataset(x=base.x, y=base.y, seed=base.seed, cid=0,
                            stream_seed=3)
        ds2 = CounterDataset(x=base.x, y=base.y, seed=base.seed, cid=0,
                             stream_seed=3)
        a = ds.batches(8, 2)
        row = ds2.rng_state()
        b = ds2.batches(8, 2)
        assert all(np.array_equal(xa, xb) and np.array_equal(ya, yb)
                   for (xa, ya), (xb, yb) in zip(a, b))
        # counters restore: replaying from the snapshot repeats the draws
        ds2.set_rng_state(row)
        c = ds2.batches(8, 2)
        assert all(np.array_equal(xb, xc)
                   for (xb, _), (xc, _) in zip(b, c))
        # probe stream is independent of the train stream
        pa = ds.batch(8)
        ds_fresh = CounterDataset(x=base.x, y=base.y, seed=base.seed, cid=0,
                                  stream_seed=3)
        pb = ds_fresh.batch(8)
        assert np.array_equal(pa[0], pb[0])

    def test_batch_indices_not_supported(self):
        base = _quad_clients(n=1)[0]
        ds = CounterDataset(x=base.x, y=base.y, seed=base.seed, cid=0,
                            stream_seed=0)
        with pytest.raises(NotImplementedError):
            ds.batch_indices(8)


class TestDevicePool:
    def test_from_clients(self):
        clients = _quad_clients(n=3, size=16)
        pool = DevicePool.from_clients(clients)
        assert pool.num_clients == 3
        assert pool.x.shape[0] == 48
        np.testing.assert_array_equal(np.asarray(pool.sizes), [16, 16, 16])
        np.testing.assert_array_equal(np.asarray(pool.offsets), [0, 16, 32])

    def test_shared_pool(self):
        x = np.arange(100, dtype=np.float32).reshape(100, 1)
        y = np.zeros(100, np.float32)
        pool = DevicePool.shared(x, y, num_clients=10, samples_per_client=30)
        assert pool.num_clients == 10
        assert pool.x.shape[0] == 100  # O(pool), not O(clients x samples)
        sizes = np.asarray(pool.sizes)
        offs = np.asarray(pool.offsets)
        assert (sizes == 30).all()
        assert (offs + sizes <= 100).all()

    def test_run_population_accepts_pool(self):
        clients = _quad_clients()
        pool = DevicePool.from_clients(clients)
        res_pool = run_population(_quad_loss, _params(), pool, FL,
                                  total_rounds=4,
                                  scenario=get_scenario("paper-fig1"),
                                  seed=0)
        res_list = run_population(_quad_loss, _params(), clients, FL,
                                  total_rounds=4,
                                  scenario=get_scenario("paper-fig1"),
                                  seed=0)
        for lp, ll in zip(res_pool.round_log, res_list.round_log):
            np.testing.assert_array_equal(np.asarray(lp["weights"]),
                                          np.asarray(ll["weights"]))

"""Secure aggregation (mask cancellation) + FedProx local-training tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.client import make_local_update_fn
from repro.core.secure_agg import mask_update, secure_sum
from repro.utils.pytree import tree_flatten_to_vector, tree_sq_dist


def _update(i):
    key = jax.random.PRNGKey(100 + i)
    return {"w": jax.random.normal(key, (6, 4)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (4,))}


class TestSecureAggregation:
    def test_masks_cancel_exactly(self):
        ids = [3, 7, 11, 20]
        updates = [_update(i) for i in ids]
        rk = jax.random.PRNGKey(0)
        masked = [mask_update(rk, u, i, ids) for u, i in zip(updates, ids)]
        raw_sum = secure_sum(updates)
        sec_sum = secure_sum(masked)
        np.testing.assert_allclose(tree_flatten_to_vector(sec_sum),
                                   tree_flatten_to_vector(raw_sum),
                                   rtol=1e-5, atol=1e-5)

    def test_individual_updates_are_hidden(self):
        ids = [0, 1, 2]
        u = _update(0)
        masked = mask_update(jax.random.PRNGKey(0), u, 0, ids, scale=10.0)
        # the masked upload is far from the raw update
        assert float(tree_sq_dist(masked, u)) > 10.0

    def test_weighted_secure_sum_matches_eq5(self):
        """Clients upload w_i * Delta_i + mask; server sum == weighted agg."""
        ids = [1, 2, 3]
        updates = [_update(i) for i in ids]
        w = [0.5, 2.0, 0.7]
        rk = jax.random.PRNGKey(9)
        masked = [mask_update(rk, jax.tree.map(lambda x: wi * x, u), i, ids)
                  for u, i, wi in zip(updates, ids, w)]
        sec = secure_sum(masked)
        expect = jax.tree.map(
            lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *updates)
        np.testing.assert_allclose(tree_flatten_to_vector(sec),
                                   tree_flatten_to_vector(expect),
                                   rtol=1e-5, atol=1e-5)

    def test_empty_cohort_raises_value_error(self):
        """An empty buffer drain is a protocol error, not an IndexError."""
        with pytest.raises(ValueError, match="at least one"):
            secure_sum([])


class TestFedProx:
    def _loss(self, params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] - y) ** 2), {}

    def test_prox_shrinks_drift(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (32, 4))
        y = x @ jnp.arange(1.0, 5.0)
        batches = (jnp.stack([x] * 4), jnp.stack([y] * 4))
        base = {"w": jnp.zeros(4)}
        plain = make_local_update_fn(self._loss, 4, 0.05)
        prox = make_local_update_fn(self._loss, 4, 0.05, prox_mu=1.0)
        d0, _ = plain(base, batches)
        d1, _ = prox(base, batches)
        # proximal term pulls the iterate toward base => smaller delta
        assert float(tree_sq_dist(d1, {"w": jnp.zeros(4)})) < \
            float(tree_sq_dist(d0, {"w": jnp.zeros(4)}))

    def test_prox_zero_is_plain_sgd(self):
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (16, 4))
        y = x @ jnp.ones(4)
        batches = (jnp.stack([x] * 2), jnp.stack([y] * 2))
        base = {"w": jnp.ones(4) * 0.1}
        d0, _ = make_local_update_fn(self._loss, 2, 0.1)(base, batches)
        d1, _ = make_local_update_fn(self._loss, 2, 0.1, prox_mu=0.0)(base, batches)
        np.testing.assert_allclose(np.asarray(d0["w"]), np.asarray(d1["w"]),
                                   rtol=1e-7)

"""Optional-``hypothesis`` shim.

Property-based tests import ``given`` / ``settings`` / ``st`` from here.
With ``hypothesis`` installed (see requirements-dev.txt) they run as
usual; without it they are skipped with a clear reason while every
deterministic test in the same module keeps running — the seed tree
failed *collection* of three whole modules on this import.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised on bare images
    import pytest

    HAVE_HYPOTHESIS = False
    _SKIP = pytest.mark.skip(
        reason="hypothesis not installed (pip install -r requirements-dev.txt)")

    def given(*_args, **_kwargs):
        def deco(fn):
            return _SKIP(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Accepts any strategy construction; values are never drawn."""

        def __getattr__(self, _name):
            def strategy(*_args, **_kwargs):
                return None
            return strategy

    st = _StrategyStub()

"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import attention_ref
from repro.kernels.ssm_scan.ops import selective_scan
from repro.kernels.ssm_scan.ref import selective_scan_ref
from repro.kernels.weighted_agg.ops import sq_dists, weighted_sum
from repro.kernels.weighted_agg.ref import sq_dists_ref, weighted_sum_ref


class TestWeightedAggKernel:
    @pytest.mark.parametrize("k,n", [(1, 128), (4, 1000), (8, 16384),
                                     (16, 40000), (3, 127), (32, 4096)])
    def test_weighted_sum_shapes(self, k, n):
        key = jax.random.PRNGKey(k * 1000 + n)
        d = jax.random.normal(key, (k, n))
        w = jax.random.normal(jax.random.fold_in(key, 1), (k,))
        np.testing.assert_allclose(weighted_sum(d, w, interpret=True),
                                   weighted_sum_ref(d, w), rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_weighted_sum_dtypes(self, dtype):
        key = jax.random.PRNGKey(0)
        d = jax.random.normal(key, (4, 512)).astype(dtype)
        w = jnp.array([0.5, 1.0, -1.0, 2.0], jnp.float32)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(weighted_sum(d, w, interpret=True),
                                   weighted_sum_ref(d, w), rtol=tol, atol=tol)

    @pytest.mark.parametrize("k,n", [(2, 256), (8, 10000), (5, 131)])
    def test_sq_dists(self, k, n):
        key = jax.random.PRNGKey(7)
        x = jax.random.normal(key, (n,))
        b = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
        np.testing.assert_allclose(sq_dists(x, b, interpret=True),
                                   sq_dists_ref(x, b), rtol=2e-4)

    def test_sq_dist_zero(self):
        x = jnp.ones(300)
        b = jnp.stack([x, x + 1.0])
        d = np.asarray(sq_dists(x, b, interpret=True))
        assert d[0] == pytest.approx(0.0, abs=1e-6)
        assert d[1] == pytest.approx(300.0, rel=1e-5)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("b,s,h,d", [(2, 256, 4, 64), (1, 128, 2, 32),
                                         (1, 512, 1, 128), (2, 200, 2, 64)])
    def test_causal_shapes(self, b, s, h, d):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q, k, v = (jax.random.normal(kk, (b, s, h, d)) for kk in ks)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        ref = flash_attention(q, k, v, causal=True, use_kernel=False)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [32, 64, 128])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q, k, v = (jax.random.normal(kk, (1, 256, 2, 32)) for kk in ks)
        out = flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
        ref = flash_attention(q, k, v, causal=True, window=window,
                              use_kernel=False)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_bidirectional(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q, k, v = (jax.random.normal(kk, (2, 128, 2, 32)) for kk in ks)
        out = flash_attention(q, k, v, causal=False, interpret=True)
        ref = flash_attention(q, k, v, causal=False, use_kernel=False)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q, k, v = (jax.random.normal(kk, (1, 128, 2, 64)).astype(jnp.bfloat16)
                   for kk in ks)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        ref = flash_attention(q, k, v, causal=True, use_kernel=False)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), rtol=5e-2,
                                   atol=5e-2)

    def test_matches_model_reference_attention(self):
        """Kernel agrees with the model's chunked-XLA attention path."""
        from repro.models.attention import _chunked_causal_attention
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q, k, v = (jax.random.normal(kk, (1, 1024, 2, 64)) for kk in ks)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        ref = _chunked_causal_attention(q, k, v, q_chunk=256)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


class TestSsmScanKernel:
    @pytest.mark.parametrize("b,s,di,n", [(2, 64, 32, 8), (1, 128, 48, 16),
                                          (2, 100, 30, 4), (1, 256, 16, 16)])
    def test_shapes(self, b, s, di, n):
        ks = jax.random.split(jax.random.PRNGKey(b * 100 + s), 5)
        x = jax.random.normal(ks[0], (b, s, di))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)) - 1.0)
        bb = jax.random.normal(ks[2], (b, s, n))
        c = jax.random.normal(ks[3], (b, s, n))
        a = -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.5)
        out = selective_scan(x, dt, bb, c, a, interpret=True, chunk=32,
                             block_d=16)
        ref = selective_scan_ref(x, dt, bb, c, a)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_matches_model_ssm_block(self):
        """Kernel recurrence == the model's chunked associative-scan path."""
        from repro.configs.base import ModelConfig
        from repro.models.ssm import init_ssm, ssm_train
        import repro.models.ssm as ssm_mod

        cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                          num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=16,
                          ssm_state=8)
        p = init_ssm(jax.random.PRNGKey(0), cfg)
        u = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
        y_model = ssm_train(cfg, p, u)

        # recompute through the kernel with the same pre/post processing
        import jax.numpy as jnp2
        xz = u @ p["in_proj"]
        x, z = jnp2.split(xz, 2, axis=-1)
        x = jax.nn.silu(ssm_mod._causal_conv(x, p["conv_w"], p["conv_b"]))
        dt, b_, c_ = ssm_mod._ssm_inputs(cfg, p, x)
        a = -jnp2.exp(p["A_log"])
        y = selective_scan(x, dt, b_, c_, a, interpret=True, chunk=16,
                           block_d=32)
        y = y + x.astype(jnp2.float32) * p["D"]
        y = (y * jax.nn.silu(z.astype(jnp2.float32)))
        y_kernel = y @ p["out_proj"]
        np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                                   rtol=2e-4, atol=2e-4)

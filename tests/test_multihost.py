"""Multi-host round substrate (DESIGN.md §7): 2 processes x 4 devices
== 1 process x 8 devices, bit for bit.

Launches tests/_multihost_worker.py three times (one single-process
reference with 8 forced host devices; two jax.distributed processes with
4 each, joined over a localhost coordinator) and compares the JSON
reports for EXACT equality: the full round log, the eval history, and
the final params/ring across >= 2 weighting policies. The multi-process
workers also monkeypatch ``jax.device_get`` to reject non-addressable
arrays, so a pass proves the engine's multi-process round-log fetch uses
process-local addressable shards only.
"""
import json
import os
import socket
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WORKER = os.path.join(ROOT, "tests", "_multihost_worker.py")
POLICIES = ("paper", "fedbuff")


def _worker_env():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # the worker pins its own device count
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), os.path.join(ROOT, "tests"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    return env


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _parse_report(stdout: str) -> dict:
    return json.loads(stdout.strip().splitlines()[-1])


@pytest.mark.multihost
def test_two_process_mesh_matches_single_process(tmp_path):
    env = _worker_env()
    sink_dir = str(tmp_path / "sinks")
    os.makedirs(sink_dir)
    common = ["--rounds", "6", "--policies", ",".join(POLICIES)]

    ref = subprocess.run(
        [sys.executable, WORKER, "--mode", "single"] + common,
        capture_output=True, text=True, env=env, timeout=900)
    assert ref.returncode == 0, ref.stderr[-4000:]
    ref_report = _parse_report(ref.stdout)
    assert ref_report["devices"] == 8
    assert ref_report["process_count"] == 1

    coordinator = f"127.0.0.1:{_free_port()}"
    procs = [subprocess.Popen(
        [sys.executable, WORKER, "--mode", "multi",
         "--process-id", str(i), "--num-processes", "2",
         "--coordinator", coordinator, "--sink-dir", sink_dir] + common,
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env)
        for i in range(2)]
    outs = []
    for p in procs:
        out, err = p.communicate(timeout=900)
        assert p.returncode == 0, err[-4000:]
        outs.append(out)

    # only the coordinator (process 0) emits — the same gate ckpt IO uses
    assert outs[0].strip(), "coordinator emitted no report"
    assert not outs[1].strip(), "non-coordinator emitted output"

    # and the same gate governs the JSONL metrics sink: every process
    # emitted a snapshot, only process 0's lazy-open sink touched disk
    assert sorted(os.listdir(sink_dir)) == ["metrics_p0.jsonl"]
    with open(os.path.join(sink_dir, "metrics_p0.jsonl")) as f:
        (snap,) = [json.loads(ln) for ln in f]
    assert snap["event"] == "metrics_snapshot" and snap["process"] == 0
    assert snap["metrics"]["engine_dispatches_total"] >= 1.0
    multi_report = _parse_report(outs[0])
    assert multi_report["devices"] == 8  # global device count
    assert multi_report["process_count"] == 2

    for policy in POLICIES:
        ref_p, got_p = ref_report[policy], multi_report[policy]
        assert got_p["server_rounds"] == ref_p["server_rounds"]
        assert got_p["num_events"] == ref_p["num_events"]
        # bit-identity: JSON floats round-trip f32/f64 exactly, so ==
        # on the parsed structures is bitwise comparison
        assert got_p["round_log"] == ref_p["round_log"], policy
        assert got_p["history"] == ref_p["history"], policy
        assert got_p["final_params"] == ref_p["final_params"], policy
        assert got_p["final_ring_row0"] == ref_p["final_ring_row0"], policy

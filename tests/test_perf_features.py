"""Equivalence tests for the §Perf optimisations: every beyond-paper knob
must be bit-compatible (up to float tolerance) with the baseline path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_variant
from repro.models import build_model
from repro.models.moe import init_moe, moe_ffn
from repro.utils import tree_flatten_to_vector


@pytest.fixture(scope="module")
def qwen_smoke():
    cfg = smoke_variant(get_arch("qwen3-1.7b").model).replace(num_layers=4)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 20), 0,
                                     cfg.vocab_size),
    }
    return cfg, params, batch


class TestChunkedCE:
    @pytest.mark.parametrize("chunk", [7, 8, 40, 1000])
    def test_loss_and_grads_match(self, qwen_smoke, chunk):
        cfg, params, batch = qwen_smoke
        m0 = build_model(cfg)
        m1 = build_model(cfg.replace(ce_chunk=chunk))
        l0, _ = m0.loss(params, batch)
        l1, _ = m1.loss(params, batch)
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
        g0 = jax.grad(lambda p: m0.loss(p, batch)[0])(params)
        g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
        np.testing.assert_allclose(tree_flatten_to_vector(g0),
                                   tree_flatten_to_vector(g1), rtol=3e-3,
                                   atol=1e-5)


class TestSqrtRemat:
    @pytest.mark.parametrize("block", [2, 4])
    def test_grads_match_per_layer_remat(self, qwen_smoke, block):
        cfg, params, batch = qwen_smoke
        m0 = build_model(cfg)
        m1 = build_model(cfg.replace(remat_block=block))
        l0, _ = m0.loss(params, batch)
        l1, _ = m1.loss(params, batch)
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
        g0 = jax.grad(lambda p: m0.loss(p, batch)[0])(params)
        g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
        np.testing.assert_allclose(tree_flatten_to_vector(g0),
                                   tree_flatten_to_vector(g1), rtol=1e-4,
                                   atol=1e-7)

    def test_non_divisor_falls_back(self, qwen_smoke):
        cfg, params, batch = qwen_smoke  # 4 layers; block=3 doesn't divide
        m1 = build_model(cfg.replace(remat_block=3))
        l1, _ = m1.loss(params, batch)
        assert np.isfinite(float(l1))


class TestGroupedMoE:
    def test_grouped_equals_global_when_dropless(self):
        cfg1 = smoke_variant(get_arch("deepseek-moe-16b").model)
        cfg2 = cfg1.replace(moe_groups=2)
        p = init_moe(jax.random.PRNGKey(0), cfg1)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg1.d_model))
        y1, _ = moe_ffn(cfg1, p, x, capacity_factor=8.0)
        y2, _ = moe_ffn(cfg2, p, x, capacity_factor=8.0)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                                   atol=1e-5)

    def test_group_fallback_on_indivisible(self):
        cfg = smoke_variant(get_arch("deepseek-moe-16b").model).replace(
            moe_groups=7)  # 7 does not divide 2*16 tokens
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        y, _ = moe_ffn(cfg, p, x)
        assert y.shape == x.shape

    def test_grads_flow_through_router(self):
        cfg = smoke_variant(get_arch("deepseek-moe-16b").model).replace(
            moe_groups=2)
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))

        def f(p):
            y, aux = moe_ffn(cfg, p, x)
            return jnp.sum(y ** 2) + aux

        g = jax.grad(f)(p)
        assert float(jnp.sum(jnp.abs(g["router"]))) > 0.0


class TestDistAccumDtype:
    def test_bf16_accumulator_close_to_f32(self):
        from repro.configs.base import FLConfig
        from repro.core import init_dist_state, make_dist_step

        def quad_loss(params, batch):
            x, y = batch
            return jnp.mean((x @ params["w"] - y) ** 2), {}

        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (16, 4))
        y = x @ jnp.arange(1.0, 5.0)
        batch = {"local": (x[None], y[None]), "probe": (x, y),
                 "tau": jnp.int32(0), "data_size": jnp.float32(10.0)}
        params = {"w": jnp.zeros(4)}
        outs = {}
        for dt in ("float32", "bfloat16"):
            fl = FLConfig(buffer_size=2, local_steps=1, local_lr=0.1,
                          accum_dtype=dt)
            step = jax.jit(make_dist_step(quad_loss, fl))
            st = init_dist_state(params, fl)
            for _ in range(2):
                st, _ = step(st, batch)
            outs[dt] = np.asarray(st.global_params["w"])
        np.testing.assert_allclose(outs["bfloat16"], outs["float32"],
                                   rtol=2e-2, atol=1e-3)

"""Real-transport serving ingress (transport/ + launch/, DESIGN.md §12):
wire-schema roundtrips and versioning, the AggregatorService protocol
over real loopback sockets, the §12 acceptance gate — byte-identical
served params between the in-process twin and the socket path on the
same seeded stream — journal-replay parity for CONCURRENT client
fleets, the controller's thread-safety contract, and the shared
launcher flag surface (no drift between serve_fl and client_fl)."""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.serving import (
    AggregatorService,
    Admission,
    ServeConfig,
    ServingController,
    Upload,
    tree_from_wire,
    tree_to_wire,
)
from repro.sim.arrivals import draw_upload
from repro.transport import wire
from repro.transport.client import RemoteAggregator, run_client
from repro.transport.server import AggregatorServer


def _quad_loss(params, batch):
    x, y = batch
    return jnp.mean((x @ params["w"] - y) ** 2), {}


PARAMS = {"w": jnp.array([1.0, -1.0, 0.5, 2.0])}


class QuadDataset:
    """Seeded sequential-draw dataset speaking the ClientDataset batch
    protocol for the quad problem — re-creatable from (cid,), which is
    what the journal replay and the parity twin rely on."""

    def __init__(self, cid: int, size: int = 16):
        self.size = size
        self._rng = np.random.default_rng(1234 + cid)

    def batch(self, b):
        x = self._rng.normal(size=(b, 4)).astype(np.float32)
        y = (x @ np.arange(1.0, 5.0)).astype(np.float32)
        return jnp.asarray(x), jnp.asarray(y)

    def batches(self, b, m):
        xs, ys = zip(*[self.batch(b) for _ in range(m)])
        return jnp.stack(xs), jnp.stack(ys)


def _fl(**kw):
    kw.setdefault("buffer_size", 2)
    kw.setdefault("local_steps", 1)
    kw.setdefault("local_lr", 0.1)
    kw.setdefault("max_staleness", 8)
    kw.setdefault("batch_size", 4)
    return FLConfig(**kw)


def _ctrl(fl=None, **kw):
    kw.setdefault("adapt_every", 0)
    kw.setdefault("service_time", 0.0)
    return ServingController(_quad_loss, PARAMS, fl or _fl(),
                             ServeConfig(**kw))


class TestWireSchema:
    def _tensors(self):
        rng = np.random.default_rng(0)
        return {"a": rng.normal(size=(3, 5)).astype(np.float32),
                "b": np.arange(7, dtype=np.int64),
                "c": rng.normal(size=(4096,)).astype(np.float32)}

    def test_f32_roundtrip_bit_exact(self):
        meta = {"kind_detail": {"nested": [1, 2.5, "x"], "ok": True}}
        frame = wire.encode_message("offer", meta, self._tensors())
        kind, m2, t2 = wire.decode_message(frame)
        assert kind == "offer" and m2 == meta
        for name, arr in self._tensors().items():
            assert t2[name].dtype == arr.dtype
            np.testing.assert_array_equal(t2[name], arr)

    def test_int8_bounded_error_and_3x_smaller(self):
        tensors = self._tensors()
        f32 = wire.encode_message("offer", {}, tensors, codec="f32")
        i8 = wire.encode_message("offer", {}, tensors, codec="int8")
        assert len(f32) >= 3 * len(i8) - 200  # the §12 size gate (headers
        # dominate tiny tensors, hence the small slack)
        _, _, t2 = wire.decode_message(i8)
        span = tensors["c"].max() - tensors["c"].min()
        # per-block affine on 256-wide blocks: error << global-span / 255
        assert np.abs(t2["c"] - tensors["c"]).max() <= span / 255.0
        # non-float32 tensors always travel raw, codec notwithstanding
        np.testing.assert_array_equal(t2["b"], tensors["b"])

    def test_schema_version_mismatch_rejected(self):
        frame = bytearray(wire.encode_message("offer", {}))
        frame[2:4] = (wire.SCHEMA_VERSION + 1).to_bytes(2, "big")
        with pytest.raises(wire.WireError, match="schema"):
            wire.decode_message(bytes(frame))

    def test_bad_magic_and_truncation_rejected(self):
        with pytest.raises(wire.WireError):
            wire.decode_message(b"XX" + b"\x00" * 32)
        frame = wire.encode_message("offer", {}, self._tensors())
        with pytest.raises(wire.WireError):
            wire.decode_message(frame[: len(frame) // 2])

    def test_upload_and_admission_wire_roundtrip(self):
        ds = QuadDataset(0)
        up = draw_upload(ds, 0, _fl(), base_version=3, t=1.5, seq=7)
        meta_w, tensors_w = up.to_wire()
        frame = wire.encode_message("offer", meta_w, tensors_w)
        _, meta, tensors = wire.decode_message(frame)
        up2 = Upload.from_wire(meta, tensors)
        assert (up2.client_id, up2.base_version, up2.seq) == (0, 3, 7)
        assert up2.data_size == up.data_size
        assert wire.payload_sha256(up2) == wire.payload_sha256(up)
        for a, b in zip(jax.tree_util.tree_leaves(up.batch),
                        jax.tree_util.tree_leaves(up2.batch)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        adm = Admission(accepted=False, reason="queue_full",
                        retry_after=1.25)
        assert Admission.from_wire(adm.to_wire()) == adm

    def test_tree_wire_preserves_tuple_vs_dict(self):
        tree = {"w": np.ones(3, np.float32),
                "pair": (np.zeros(2, np.float32), np.ones(2, np.float32))}
        tensors = {}
        skel = tree_to_wire("t", tree, tensors)
        back = tree_from_wire(skel, tensors)
        assert isinstance(back["pair"], tuple)
        np.testing.assert_array_equal(back["w"], tree["w"])


class TestLoopbackParity:
    """The §12 acceptance gate: same seeded stream through the
    in-process controller and through a real socket -> byte-identical
    served params. Sequential client, the TEST thread owns pump (the
    single-aggregator-thread contract), so fold order is deterministic
    on both paths."""

    def _drive(self, service: AggregatorService, pump, ds, fl,
               uploads=6):
        for seq in range(uploads):
            version, _params = service.pull()
            up = draw_upload(ds, 0, fl, base_version=version,
                             t=float(seq), seq=seq)
            adm = service.offer(up, float(seq))
            assert adm.accepted, adm
            pump()
        return service.pull()

    @pytest.mark.parametrize("transport", ["tcp", "http"])
    def test_socket_params_byte_identical_to_twin(self, transport):
        fl = _fl()
        twin = _ctrl(fl)
        v_twin, p_twin = self._drive(twin, lambda: twin.pump(1e9),
                                     QuadDataset(0), fl)

        ctrl = _ctrl(fl)
        srv = AggregatorServer(ctrl, transport=transport)
        srv.start()
        try:
            client = RemoteAggregator("127.0.0.1", srv.port,
                                      transport=transport, codec="f32")
            v_sock, p_sock = self._drive(
                client, lambda: ctrl.pump(srv.clock()), QuadDataset(0), fl)
            client.close()
        finally:
            srv.shutdown()

        assert v_sock == v_twin == 3  # 6 uploads / K=2
        for a, b in zip(jax.tree_util.tree_leaves(p_twin),
                        jax.tree_util.tree_leaves(p_sock)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        assert wire.params_sha256(v_sock, p_sock) == \
            wire.params_sha256(v_twin, p_twin)

    def test_remote_snapshot_matches_controller(self):
        ctrl = _ctrl()
        srv = AggregatorServer(ctrl, transport="tcp")
        srv.start()
        try:
            client = RemoteAggregator("127.0.0.1", srv.port)
            snap = client.snapshot()
            client.close()
        finally:
            srv.shutdown()
        assert snap["version"] == 0 and snap["k"] == ctrl.k


class TestJournalReplayParity:
    """Concurrent fleets are racy (pull races fold), so live socket runs
    aren't bit-reproducible run to run — but the fold JOURNAL is the
    ground truth: replaying it in-process from the seeded datasets must
    land on the live run's exact params digest."""

    def test_concurrent_clients_replay_to_same_digest(self, tmp_path):
        from repro.launch.serve_fl import _attach_journal, replay_journal

        fl = _fl(max_staleness=100)
        rounds, n_clients = 3, 3
        ctrl = _ctrl(fl, queue_capacity=64)
        journal = tmp_path / "folds.jsonl"
        f = open(journal, "w")
        _attach_journal(ctrl, f)
        srv = AggregatorServer(ctrl, transport="tcp")
        srv.start()
        folder = threading.Thread(
            target=srv.serve,
            kwargs={"stop": lambda: ctrl.version >= rounds, "poll": 0.01},
            daemon=True)
        folder.start()

        def one_client(cid):
            svc = RemoteAggregator("127.0.0.1", srv.port, seed=cid)
            try:
                run_client(svc, QuadDataset(cid), cid, fl, uploads=8,
                           stop_at_version=rounds, seed=cid)
            finally:
                svc.close()

        threads = [threading.Thread(target=one_client, args=(c,))
                   for c in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        folder.join(timeout=60)
        srv.shutdown()
        f.close()
        assert not folder.is_alive() and ctrl.version >= rounds
        entries = [json.loads(line) for line in journal.open()]
        assert len(entries) >= rounds * fl.buffer_size

        replay = _ctrl(fl, queue_capacity=64)
        folded = replay_journal(str(journal), replay,
                                [QuadDataset(c) for c in range(n_clients)],
                                fl)
        assert folded == len(entries)
        assert wire.params_sha256(*replay.pull()) == \
            wire.params_sha256(*ctrl.pull())

    def test_replay_detects_wrong_seed(self, tmp_path):
        from repro.launch.serve_fl import _attach_journal, replay_journal

        fl = _fl()
        ctrl = _ctrl(fl)
        journal = tmp_path / "folds.jsonl"
        with open(journal, "w") as f:
            _attach_journal(ctrl, f)
            up = draw_upload(QuadDataset(0), 0, fl, base_version=0, t=0.0,
                             seq=0)
            assert ctrl.offer(up, 0.0).accepted
            ctrl.pump(0.0)
        with pytest.raises(ValueError, match="sha mismatch"):
            replay_journal(str(journal), _ctrl(fl), [QuadDataset(99)], fl)


class TestThreadSafety:
    def test_concurrent_offers_reconcile(self):
        """The documented contract: offer/pull/snapshot from many
        threads while ONE thread pumps; every offer lands in exactly one
        counter and the served (version, params) pair stays coherent."""
        fl = _fl(max_staleness=1000)
        ctrl = _ctrl(fl, queue_capacity=16)
        per_thread, n_threads = 30, 4
        errors = []

        def hammer(tid):
            ds = QuadDataset(tid)
            try:
                for i in range(per_thread):
                    up = draw_upload(ds, tid, fl, base_version=0, t=0.0,
                                     seq=i)
                    ctrl.offer(up, 0.0)
                    v, p = ctrl.pull()
                    assert v >= 0 and p is not None
                    ctrl.snapshot()
            except Exception as e:  # pragma: no cover - failure path
                errors.append(e)

        threads = [threading.Thread(target=hammer, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        while any(t.is_alive() for t in threads):
            ctrl.pump(0.0)
        for t in threads:
            t.join()
        ctrl.pump(0.0)
        assert not errors
        c = ctrl.counters
        assert c["admitted"] + c["rejected_queue_full"] \
            + c["dropped_stale_ingress"] == per_thread * n_threads
        assert c["folded"] == c["admitted"]  # queue fully drained
        assert ctrl.version == c["folded"] // ctrl.k


class TestLauncherFlagSurface:
    def test_shared_flags_cannot_drift(self):
        """serve_fl and client_fl build their parsers from launch/cli.py;
        the shared option strings must exist on both with equal
        defaults."""
        from repro.launch import client_fl, serve_fl

        sp = serve_fl.build_parser()._option_string_actions
        cp = client_fl.build_parser()._option_string_actions
        shared = ("--scenario", "--clients", "--samples-per-client",
                  "--seed", "--log-level", "--trace-out", "--metrics-out",
                  "--flush-every", "--profile-dir", "--profile-every",
                  "--profile-window")
        for opt in shared:
            assert opt in sp, f"serve_fl lost {opt}"
            assert opt in cp, f"client_fl lost {opt}"
            assert sp[opt].default == cp[opt].default, opt

    def test_ring_codec_choices_shared(self):
        from repro.launch import serve_fl

        act = serve_fl.build_parser()._option_string_actions["--ring-codec"]
        assert tuple(act.choices) == ("f32", "int8", "delta")

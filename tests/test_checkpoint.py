"""checkpoint/ckpt.py: engine-state save/restore round trip + the
coordinator gate (DESIGN.md §7).

The headline test: run the vectorized engine T rounds, checkpoint the
EngineState (version ring + round log + every host RNG stream) to disk
at T/2 through ``save_checkpoint``/``load_checkpoint``, resume, and pin
the resumed run BIT-identical to the uninterrupted one — round log,
history, final params, final ring.
"""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt, load_checkpoint, save_checkpoint
from repro.configs.base import FLConfig
from repro.sim import get_scenario
from repro.sim.engine import (
    engine_state_from_tree,
    engine_state_to_tree,
    run_vectorized,
)

from _shard_worker import _quad_clients, _quad_loss

FL = FLConfig(num_clients=6, buffer_size=2, local_steps=2, local_lr=0.05,
              batch_size=8, max_staleness=4)


def _eval(p):
    return {"wnorm": float(jnp.sum(p["w"] ** 2))}


def _run(clients, total_rounds, **kw):
    return run_vectorized(_quad_loss, {"w": jnp.zeros(4)}, clients, FL,
                          total_rounds=total_rounds, eval_fn=_eval,
                          eval_every=2, seed=0, **kw)


class TestEngineStateRoundTrip:
    def test_resume_is_bit_identical(self, tmp_path):
        """Save at round 4 of 8, restore from DISK, resume: round log,
        history, params and ring all match the uninterrupted run
        exactly."""
        full = _run(_quad_clients(), 8, capture_state=True)
        half = _run(_quad_clients(), 4, capture_state=True)

        tree = engine_state_to_tree(half.final_state)
        path = str(tmp_path / "engine.npz")
        save_checkpoint(path, tree, step=half.final_state.version)
        loaded, step = load_checkpoint(path, like=tree)
        assert step == 4

        clients = _quad_clients()  # fresh datasets; RNG restored by state
        resumed = _run(clients, 8, init_state=engine_state_from_tree(loaded),
                       capture_state=True)
        assert resumed.round_log == full.round_log
        assert resumed.history == full.history
        assert resumed.num_events == full.num_events
        assert resumed.server_rounds == 8
        np.testing.assert_array_equal(np.asarray(resumed.final_state.ring),
                                      np.asarray(full.final_state.ring))
        for a, b in zip(jax.tree.leaves(resumed.final_state.params),
                        jax.tree.leaves(full.final_state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_resume_off_eval_cadence_is_bit_identical(self):
        """Checkpoint at a round OFF the eval cadence (3 with
        eval_every=2): the snapshot's trailing forced eval must not leak
        an extra history row into the resumed run."""
        full = _run(_quad_clients(), 8, capture_state=True)
        half = _run(_quad_clients(), 3, capture_state=True)
        assert half.history[-1]["round"] == 3  # the forced capture eval
        state = engine_state_from_tree(engine_state_to_tree(
            half.final_state))
        resumed = _run(_quad_clients(), 8, init_state=state,
                       capture_state=True)
        assert resumed.history == full.history
        assert resumed.round_log == full.round_log
        np.testing.assert_array_equal(np.asarray(resumed.final_state.ring),
                                      np.asarray(full.final_state.ring))

    def test_resume_with_dropout_scenario(self, tmp_path):
        """The dropout RNG stream is part of the state: a scenario that
        consumes it resumes bit-identically too."""
        sc = get_scenario("dropout-bernoulli")

        def mk():
            clients, _ = sc.make_dataset(6, samples_per_client=32, seed=0)
            return clients

        def loss(p, b):
            x, y = b
            x = x.reshape(x.shape[0], -1)
            lp = jax.nn.log_softmax(x @ p["w"])
            return -jnp.mean(jnp.take_along_axis(
                lp, y[:, None].astype(jnp.int32), axis=1)), {}

        p0 = {"w": jnp.zeros((784, 10))}
        full = run_vectorized(loss, p0, mk(), FL, total_rounds=6,
                              scenario=sc, seed=3, capture_state=True)
        half = run_vectorized(loss, p0, mk(), FL, total_rounds=3,
                              scenario=sc, seed=3, capture_state=True)
        path = str(tmp_path / "engine.npz")
        tree = engine_state_to_tree(half.final_state)
        save_checkpoint(path, tree)
        loaded, _ = load_checkpoint(path, like=tree)
        resumed = run_vectorized(loss, p0, mk(), FL, total_rounds=6,
                                 scenario=sc, seed=3,
                                 init_state=engine_state_from_tree(loaded))
        assert resumed.round_log == full.round_log
        assert resumed.num_events == full.num_events

    def test_round_log_survives_in_checkpoint(self):
        """The serialized state embeds the round log itself (not a
        digest): restoring reproduces the exact per-round dicts."""
        half = _run(_quad_clients(), 4, capture_state=True)
        state = engine_state_from_tree(engine_state_to_tree(half.final_state))
        assert state.round_log == half.round_log
        assert state.history == half.history
        assert state.version == 4

    def test_resume_refuses_record_trace(self):
        half = _run(_quad_clients(), 2, capture_state=True)
        with pytest.raises(ValueError, match="record_trace"):
            _run(_quad_clients(), 4, init_state=half.final_state,
                 record_trace=True)

    def test_resume_refuses_client_count_mismatch(self):
        half = _run(_quad_clients(), 2, capture_state=True)
        with pytest.raises(ValueError, match="clients"):
            run_vectorized(_quad_loss, {"w": jnp.zeros(4)},
                           _quad_clients(n=4), FL, total_rounds=4, seed=0,
                           init_state=half.final_state)


class TestCoordinatorGate:
    def test_non_coordinator_process_writes_nothing(self, tmp_path,
                                                    monkeypatch):
        """Every process calls save_checkpoint; only process 0 touches
        the filesystem (multi-host IO contract, DESIGN.md §7)."""
        path = str(tmp_path / "ckpt.npz")
        monkeypatch.setattr(ckpt, "_is_coordinator", lambda: False)
        save_checkpoint(path, {"w": np.zeros(3)})
        assert not os.path.exists(path)
        assert not glob.glob(str(tmp_path / "*"))  # no tmp litter either

        monkeypatch.setattr(ckpt, "_is_coordinator", lambda: True)
        save_checkpoint(path, {"w": np.zeros(3)})
        assert os.path.exists(path)

    def test_gate_can_be_disabled_for_private_paths(self, tmp_path,
                                                    monkeypatch):
        path = str(tmp_path / "private.npz")
        monkeypatch.setattr(ckpt, "_is_coordinator", lambda: False)
        save_checkpoint(path, {"w": np.zeros(3)}, coordinator_only=False)
        assert os.path.exists(path)

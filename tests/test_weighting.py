"""Unit + property tests for the paper's weighting equations (core/weighting)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.weighting import (
    POLICIES,
    contribution_weights,
    staleness_degree,
    statistical_effect,
)

finite_pos = st.floats(min_value=1e-6, max_value=1e6, allow_nan=False,
                       allow_infinity=False)


class TestStalenessDegree:
    def test_freshest_client_gets_one(self):
        d = jnp.array([4.0, 1.0, 9.0])
        s = staleness_degree(d)
        assert float(s[1]) == pytest.approx(1.0, rel=1e-5)
        assert float(s[0]) == pytest.approx(0.25, rel=1e-4)
        assert float(s[2]) == pytest.approx(1.0 / 9.0, rel=1e-4)

    def test_all_zero_distances(self):
        # round 0: nobody stale -> everyone fully fresh
        s = staleness_degree(jnp.zeros(4))
        np.testing.assert_allclose(np.asarray(s), 1.0, rtol=1e-5)

    def test_zero_min_with_stale_others(self):
        s = staleness_degree(jnp.array([0.0, 5.0]))
        assert float(s[0]) == pytest.approx(1.0)
        assert float(s[1]) < 1e-6

    def test_min_reference_over_arrived_slots_only(self):
        # slot 1 is absent but holds the freshest base: eq. 3's min is
        # over BUFFERED clients, so the reference comes from slot 2
        d = jnp.array([4.0, 0.0, 1.0])
        mask = jnp.array([1.0, 0.0, 1.0])
        s = staleness_degree(d, arrival_mask=mask)
        assert float(s[2]) == pytest.approx(1.0, rel=1e-5)
        assert float(s[0]) == pytest.approx(0.25, rel=1e-4)
        # unmasked: the absent slot would have shrunk both ratios
        s_bad = staleness_degree(d)
        assert float(s_bad[2]) < 1e-6

    def test_pinned_reference(self):
        # the streaming form's convention: reference = the current model
        s = staleness_degree(jnp.array([0.0, 5.0]), ref_sq_dist=0.0)
        assert float(s[0]) == pytest.approx(1.0)
        assert float(s[1]) < 1e-6

    @given(st.lists(finite_pos, min_size=2, max_size=16))
    @settings(max_examples=50, deadline=None)
    def test_range_and_argmin_property(self, dists):
        d = jnp.asarray(dists, jnp.float32)
        s = np.asarray(staleness_degree(d))
        assert (s > 0).all() and (s <= 1.0 + 1e-6).all()
        assert s[int(np.argmin(dists))] == pytest.approx(1.0, rel=1e-4)

    @given(st.lists(finite_pos, min_size=2, max_size=8),
           st.floats(min_value=0.5, max_value=100.0))
    @settings(max_examples=30, deadline=None)
    def test_scale_invariance(self, dists, scale):
        # eq. 3 is a ratio: rescaling all distances leaves S unchanged
        d = jnp.asarray(dists, jnp.float32)
        s1 = np.asarray(staleness_degree(d))
        s2 = np.asarray(staleness_degree(d * scale))
        np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-5)


class TestStatisticalEffect:
    def test_eq4_product(self):
        p = statistical_effect(jnp.array([0.5, 2.0]), jnp.array([100.0, 10.0]))
        np.testing.assert_allclose(np.asarray(p), [50.0, 20.0], rtol=1e-6)

    def test_higher_loss_higher_weight(self):
        p = statistical_effect(jnp.array([1.0, 3.0]), jnp.array([10.0, 10.0]))
        assert float(p[1]) > float(p[0])


class TestContributionWeights:
    def test_paper_policy_divides_by_staleness(self):
        p = jnp.array([1.0, 1.0])
        s = jnp.array([1.0, 0.5])
        tau = jnp.zeros(2)
        w = contribution_weights("paper", p, s, tau, normalize="none")
        # literal eq. 5: w = P / S
        np.testing.assert_allclose(np.asarray(w), [1.0, 2.0], rtol=1e-6)

    def test_paper_s_min_floor(self):
        p = jnp.ones(2)
        s = jnp.array([1.0, 1e-9])
        w = contribution_weights("paper", p, s, jnp.zeros(2), s_min=1e-3,
                                 normalize="none")
        assert float(w[1]) == pytest.approx(1e3, rel=1e-4)

    def test_fedbuff_uniform(self):
        w = contribution_weights("fedbuff", jnp.array([5.0, 1.0]),
                                 jnp.array([0.1, 1.0]), jnp.zeros(2))
        np.testing.assert_allclose(np.asarray(w), 1.0, rtol=1e-6)

    def test_polynomial_matches_cited_form(self):
        tau = jnp.array([0.0, 3.0])
        w = contribution_weights("polynomial", jnp.ones(2), jnp.ones(2), tau,
                                 poly_a=0.5, normalize="none")
        np.testing.assert_allclose(np.asarray(w), [1.0, 0.5], rtol=1e-6)

    @given(st.lists(finite_pos, min_size=2, max_size=8),
           st.lists(st.floats(min_value=1e-3, max_value=1.0), min_size=2,
                    max_size=8))
    @settings(max_examples=40, deadline=None)
    def test_mean_normalization(self, ps, ss):
        n = min(len(ps), len(ss))
        p, s = jnp.asarray(ps[:n]), jnp.asarray(ss[:n])
        w = np.asarray(contribution_weights("paper", p, s, jnp.zeros(n),
                                            normalize="mean"))
        assert np.mean(w) == pytest.approx(1.0, rel=1e-3)

    def test_arrival_mask_zeroes_and_renormalizes(self):
        p = jnp.ones(4)
        s = jnp.ones(4)
        mask = jnp.array([1.0, 1.0, 0.0, 1.0])
        w = np.asarray(contribution_weights("paper", p, s, jnp.zeros(4),
                                            arrival_mask=mask))
        assert w[2] == 0.0
        assert np.sum(w) == pytest.approx(3.0, rel=1e-4)  # mean 1 over arrived

    def test_unknown_policy_raises(self):
        with pytest.raises(ValueError):
            contribution_weights("nope", jnp.ones(2), jnp.ones(2), jnp.zeros(2))

    def test_all_policies_finite(self):
        for pol in POLICIES:
            w = contribution_weights(pol, jnp.array([1.0, 2.0]),
                                     jnp.array([0.5, 1.0]), jnp.array([1.0, 0.0]))
            assert np.isfinite(np.asarray(w)).all()

"""Multi-host parity worker: 2 CPU processes x 4 forced host devices.

Extends tests/_shard_worker.py to the PROCESS-spanning substrate
(DESIGN.md §7). The parent test (tests/test_multihost.py) runs this file
three times:

  --mode single                 one process, 8 forced host devices — the
                                reference run on a (data=2, model=4) mesh
  --mode multi --process-id I   two processes, 4 forced host devices
                                each, joined via jax.distributed into the
                                SAME logical (data=2, model=4) mesh (one
                                data row per process, model axis
                                intra-process)

and pins BIT-identity of the full round log, eval history, and final
params across >= 2 weighting policies. In multi mode ``jax.device_get``
is monkeypatched to reject any non-fully-addressable array, proving the
engine's multi-process path reads the round log exclusively from
process-local addressable shards. Only the coordinator prints the JSON
report (the same coordinator-gating the checkpoint path uses).
"""
import argparse
import json
import os
import sys


def run_parity(mesh, rounds, policies):
    """One engine run per weighting policy; everything host-comparable."""
    import jax
    import numpy as np

    from repro.configs.base import FLConfig
    from repro.launch.multihost import fetch_replicated
    from repro.sim.engine import run_vectorized
    from _shard_worker import _quad_clients, _quad_loss

    def eval_fn(params):
        w = np.asarray(fetch_replicated(params["w"]), np.float64)
        return {"wnorm": float(np.sum(w * w))}

    report = {"devices": len(jax.devices()),
              "process_count": jax.process_count()}
    for policy in policies:
        fl = FLConfig(num_clients=6, buffer_size=2, local_steps=2,
                      local_lr=0.05, batch_size=8, max_staleness=4,
                      weighting=policy)
        res = run_vectorized(
            _quad_loss, {"w": jax.numpy.zeros(4)}, _quad_clients(), fl,
            total_rounds=rounds, eval_fn=eval_fn, eval_every=2, seed=0,
            mesh=mesh, capture_state=True)
        report[policy] = {
            "round_log": res.round_log,
            "history": res.history,
            "final_params": {
                "w": np.asarray(res.final_state.params["w"],
                                np.float64).tolist()},
            "final_ring_row0": np.asarray(res.final_state.ring[0],
                                          np.float64).tolist(),
            "num_events": res.num_events,
            "server_rounds": res.server_rounds,
        }
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["single", "multi"], required=True)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--num-processes", type=int, default=2)
    ap.add_argument("--coordinator", default="127.0.0.1:0")
    ap.add_argument("--local-devices", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--policies", default="paper,fedbuff")
    ap.add_argument("--sink-dir", default=None,
                    help="every process points a default-gated JsonlSink "
                         "at <dir>/metrics_p<idx>.jsonl and emits one "
                         "snapshot; only the coordinator's file may exist")
    args = ap.parse_args()

    os.environ["JAX_PLATFORMS"] = "cpu"
    total = args.num_processes * args.local_devices
    count = total if args.mode == "single" else args.local_devices
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={count}"

    import jax

    if args.mode == "multi":
        from repro.launch import multihost
        multihost.initialize(args.coordinator, args.num_processes,
                             args.process_id)
        assert jax.process_count() == args.num_processes
        # the acceptance gate: the multi-process path must never
        # device_get a non-addressable array — make any attempt fatal
        real_device_get = jax.device_get

        def guarded_device_get(x):
            for leaf in jax.tree.leaves(x):
                if isinstance(leaf, jax.Array) \
                        and not leaf.is_fully_addressable:
                    raise AssertionError(
                        "jax.device_get on a non-addressable array on the "
                        "multi-process path")
            return real_device_get(x)

        jax.device_get = guarded_device_get
        mesh = multihost.make_round_mesh(data=args.num_processes,
                                         model=args.local_devices)
        emit = multihost.is_coordinator()
    else:
        from repro.launch.mesh import make_round_mesh
        assert len(jax.devices()) == total, len(jax.devices())
        mesh = make_round_mesh(data=args.num_processes,
                               model=args.local_devices)
        emit = True

    report = run_parity(mesh, args.rounds,
                        [p for p in args.policies.split(",") if p])

    if args.sink_dir:
        # EVERY process emits through the default coordinator gate — the
        # lazy-open JsonlSink must never create the non-coordinator files
        from repro.obs import JsonlSink, emit_snapshot
        from repro.obs.metrics import default_registry

        sink = JsonlSink(os.path.join(
            args.sink_dir, f"metrics_p{jax.process_index()}.jsonl"))
        emit_snapshot(sink, default_registry(), mode=args.mode,
                      process=jax.process_index())
        sink.close()

    if emit:
        print(json.dumps(report))


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    main()

"""Substrate tests: pytree utils (property), optimizers, schedules,
checkpointing, data pipeline / partitioners, cohort round telemetry."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.base import FLConfig
from repro.core.cohort import init_cohort_state, make_cohort_step

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import dirichlet_partition, make_federated_image_dataset, shard_partition
from repro.data.synthetic import ClientDataset, make_lm_token_stream
from repro.optim import adamw, constant_schedule, sgd, warmup_cosine_schedule
from repro.optim.optimizers import apply_updates
from repro.utils import (
    tree_dot,
    tree_flatten_to_vector,
    tree_sq_dist,
    tree_sq_norm,
    tree_stack,
    tree_unstack,
    tree_weighted_sum,
)

small_floats = st.floats(min_value=-100, max_value=100, allow_nan=False)


class TestPytreeUtils:
    @given(st.lists(small_floats, min_size=1, max_size=10))
    @settings(max_examples=30, deadline=None)
    def test_sq_norm_matches_numpy(self, xs):
        t = {"a": jnp.asarray(xs, jnp.float32),
             "b": {"c": jnp.asarray(xs[::-1], jnp.float32)}}
        expect = 2 * np.sum(np.asarray(xs, np.float32) ** 2)
        assert float(tree_sq_norm(t)) == pytest.approx(expect, rel=1e-4)

    def test_sq_dist_triangle_zero(self):
        t = {"a": jnp.arange(4.0)}
        assert float(tree_sq_dist(t, t)) == 0.0

    def test_stack_unstack_roundtrip(self):
        trees = [{"w": jnp.full((2, 2), i), "b": jnp.full((3,), -i)}
                 for i in range(3)]
        stacked = tree_stack(trees)
        assert jax.tree.leaves(stacked)[0].shape[0] == 3
        back = tree_unstack(stacked, 3)
        for a, b in zip(trees, back):
            np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))

    @given(st.integers(2, 5))
    @settings(max_examples=10, deadline=None)
    def test_weighted_sum_linear_in_weights(self, k):
        key = jax.random.PRNGKey(k)
        trees = [{"w": jax.random.normal(jax.random.fold_in(key, i), (4, 3))}
                 for i in range(k)]
        stacked = tree_stack(trees)
        w = jnp.arange(1.0, k + 1.0)
        y1 = tree_weighted_sum(stacked, w)
        y2 = tree_weighted_sum(stacked, 2 * w)
        np.testing.assert_allclose(np.asarray(y2["w"]),
                                   2 * np.asarray(y1["w"]), rtol=1e-5)

    def test_tree_dot_symmetry(self):
        key = jax.random.PRNGKey(0)
        a = {"x": jax.random.normal(key, (5,))}
        b = {"x": jax.random.normal(jax.random.fold_in(key, 1), (5,))}
        assert float(tree_dot(a, b)) == pytest.approx(float(tree_dot(b, a)),
                                                      rel=1e-6)
        assert float(tree_dot(a, a)) == pytest.approx(float(tree_sq_norm(a)),
                                                      rel=1e-5)


class TestOptimizers:
    def test_sgd_closed_form(self):
        opt = sgd(0.1)
        p = {"w": jnp.array([1.0, 2.0])}
        g = {"w": jnp.array([0.5, -0.5])}
        st_ = opt.init(p)
        upd, _ = opt.update(g, st_, p)
        np.testing.assert_allclose(np.asarray(upd["w"]), [-0.05, 0.05],
                                   rtol=1e-6)

    def test_sgd_momentum_accumulates(self):
        opt = sgd(1.0, momentum=0.9)
        p = {"w": jnp.zeros(1)}
        g = {"w": jnp.ones(1)}
        s = opt.init(p)
        u1, s = opt.update(g, s, p)
        u2, s = opt.update(g, s, p)
        assert float(u2["w"][0]) == pytest.approx(-1.9, rel=1e-6)

    def test_adamw_converges_on_quadratic(self):
        opt = adamw(0.1)
        p = {"w": jnp.array([5.0, -3.0])}
        s = opt.init(p)
        for _ in range(200):
            g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
            u, s = opt.update(g, s, p)
            p = apply_updates(p, u)
        assert float(jnp.max(jnp.abs(p["w"]))) < 1e-2

    def test_schedules(self):
        sch = warmup_cosine_schedule(1.0, 10, 100)
        assert float(sch(jnp.int32(0))) == pytest.approx(0.0)
        assert float(sch(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
        assert float(sch(jnp.int32(100))) < 0.1
        assert float(constant_schedule(0.3)(jnp.int32(7))) == pytest.approx(0.3)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"layers": {"w": jnp.arange(6.0).reshape(2, 3)},
                "step_vec": jnp.array([1, 2, 3])}
        path = os.path.join(tmp_path, "ck.npz")
        save_checkpoint(path, tree, step=42)
        back, step = load_checkpoint(path, like=tree)
        assert step == 42
        np.testing.assert_array_equal(np.asarray(back["layers"]["w"]),
                                      np.asarray(tree["layers"]["w"]))

    def test_shape_mismatch_raises(self, tmp_path):
        path = os.path.join(tmp_path, "ck.npz")
        save_checkpoint(path, {"w": jnp.zeros(3)})
        with pytest.raises(ValueError):
            load_checkpoint(path, like={"w": jnp.zeros(4)})


class TestData:
    def test_dirichlet_partition_covers_all(self):
        labels = np.repeat(np.arange(10), 100)
        parts = dirichlet_partition(labels, 8, alpha=0.3, seed=0)
        all_idx = np.sort(np.concatenate(parts))
        np.testing.assert_array_equal(all_idx, np.arange(1000))

    def test_dirichlet_skew_increases_as_alpha_drops(self):
        labels = np.repeat(np.arange(10), 200)

        def skew(alpha):
            parts = dirichlet_partition(labels, 10, alpha=alpha, seed=1)
            # mean per-client entropy of label histogram (low = skewed)
            ents = []
            for idx in parts:
                h = np.bincount(labels[idx], minlength=10) / len(idx)
                h = h[h > 0]
                ents.append(-(h * np.log(h)).sum())
            return np.mean(ents)

        assert skew(0.1) < skew(100.0)

    def test_shard_partition(self):
        labels = np.repeat(np.arange(10), 50)
        parts = shard_partition(labels, 5, shards_per_client=2, seed=0)
        assert sum(len(p) for p in parts) == 500
        # pathological split: each client sees few classes
        classes = [len(np.unique(labels[p])) for p in parts]
        assert max(classes) <= 4

    def test_federated_image_dataset_shapes(self):
        clients, (xt, yt) = make_federated_image_dataset(
            num_clients=4, samples_per_client=50, seed=0)
        assert len(clients) == 4
        assert all(c.size == 50 for c in clients)
        bx, by = clients[0].batch(8)
        assert bx.shape == (8, 28, 28, 1) and by.shape == (8,)

    def test_client_batches_match_sequential_stream(self):
        """The vectorized multi-batch gather must draw the exact index
        stream of sequential .batch() calls (legacy/engine parity hangs
        on this), and leave the RNG in the same state afterwards."""
        def ds():
            rng = np.random.default_rng(3)
            return ClientDataset(x=rng.normal(size=(40, 5)).astype(np.float32),
                                 y=rng.integers(0, 4, 40), seed=7)

        a, b = ds(), ds()
        seq = [a.batch(8) for _ in range(3)]
        xs, ys = b.batches(8, 3)
        assert xs.shape == (3, 8, 5) and ys.shape == (3, 8)
        for i in range(3):
            np.testing.assert_array_equal(seq[i][0], xs[i])
            np.testing.assert_array_equal(seq[i][1], ys[i])
        # streams stay in lockstep after the bulk draw
        np.testing.assert_array_equal(a.batch(8)[0], b.batch(8)[0])

    def test_lm_stream_learnable_structure(self):
        toks = make_lm_token_stream(64, 32, 100, seed=0)
        assert toks.shape == (100, 33)
        assert toks.min() >= 0 and toks.max() < 64
        # bigram structure: successor entropy < unigram entropy
        from collections import Counter
        uni = Counter(toks[:, :-1].ravel().tolist())
        pairs = Counter(zip(toks[:, :-1].ravel().tolist(),
                            toks[:, 1:].ravel().tolist()))
        # most common successor of the most common token dominates
        top_tok = uni.most_common(1)[0][0]
        succ = [(b, c) for (a, b), c in pairs.items() if a == top_tok]
        succ.sort(key=lambda t: -t[1])
        top_frac = succ[0][1] / sum(c for _, c in succ)
        assert top_frac > 0.15  # far above uniform 1/64


class TestCohortMetricsMasking:
    """Cohort round telemetry must reflect ARRIVED slots only: zero-weight
    non-arrival (straggler) slots used to pollute staleness_min /
    weights_max / fresh_loss_mean."""

    @staticmethod
    def _quad_loss(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] - y) ** 2), {}

    def _batch(self, cohort, key, probe_scale):
        def draw(k_, scale=1.0):
            k1, k2 = jax.random.split(k_)
            x = jax.random.normal(k1, (8, 4))
            y = scale * (x @ jnp.arange(1.0, 5.0)
                         + 0.01 * jax.random.normal(k2, (8,)))
            return x, y

        return {
            "local": jax.tree.map(
                lambda *xs: jnp.stack(xs)[:, None],
                *[draw(jax.random.fold_in(key, i)) for i in range(cohort)]),
            "probe": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[draw(jax.random.fold_in(key, 100 + i), probe_scale[i])
                  for i in range(cohort)]),
            "arrival": jnp.array([1.0, 1.0, 0.0]),  # slot 2 is a straggler
            "data_sizes": jnp.array([10.0, 20.0, 30.0]),
        }

    def test_metrics_ignore_non_arrival_slots(self):
        fl = FLConfig(buffer_size=3, local_steps=1, local_lr=0.05,
                      weighting="paper")
        params = {"w": jnp.zeros(4)}
        step = jax.jit(make_cohort_step(self._quad_loss, fl))
        state = init_cohort_state(params, 3)
        # round 1: all slots still fresh; slot 2 stays behind and goes stale
        batch = self._batch(3, jax.random.PRNGKey(0),
                            probe_scale=(1.0, 1.0, 100.0))
        state, _ = step(state, batch)
        x_t = state.global_params  # round-2 global: the eq. 4 probe target
        state, mets = step(state, batch)

        # the straggler's huge probe loss must not leak into the mean:
        # fresh_loss_mean == mean over the TWO arrived slots' probes only
        arrived_fresh = np.mean([float(self._quad_loss(
            x_t, jax.tree.map(lambda p: p[i], batch["probe"]))[0])
            for i in range(2)])
        np.testing.assert_allclose(float(mets["fresh_loss_mean"]),
                                   arrived_fresh, rtol=1e-5)
        assert float(mets["fresh_loss_mean"]) < 50.0  # 100x probe excluded
        # slot 2 is the ONLY stale slot (staleness < 1): with it masked the
        # min over arrived slots is exactly 1.0
        np.testing.assert_allclose(float(mets["staleness_min"]), 1.0,
                                   rtol=1e-6)
        assert float(mets["weights_max"]) > 0.0

    def test_no_arrivals_reports_neutral_zeros(self):
        fl = FLConfig(buffer_size=3, local_steps=1, local_lr=0.05,
                      weighting="paper")
        step = jax.jit(make_cohort_step(self._quad_loss, fl))
        state = init_cohort_state({"w": jnp.zeros(4)}, 3)
        batch = self._batch(3, jax.random.PRNGKey(1),
                            probe_scale=(1.0, 1.0, 1.0))
        batch["arrival"] = jnp.zeros(3)
        _, mets = step(state, batch)
        for key in ("fresh_loss_mean", "staleness_min", "weights_max"):
            assert np.isfinite(float(mets[key]))
            np.testing.assert_allclose(float(mets[key]), 0.0, atol=1e-6)

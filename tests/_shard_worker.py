"""Multi-device parity checks for the sharded round substrate.

Importable check functions (used in-process by tests/test_round_body.py
when the session already has >= 8 devices, e.g. the CI multi-device job)
plus a __main__ that runs them all and prints a JSON error report — the
subprocess entry point the single-device test suite uses with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the shard_map
paths are exercised everywhere.

Every check compares the mesh-sharded pass against the single-device
pass on identical inputs; differences come only from the eq. 3 psum
summation order, so errors must sit at f32 rounding level.
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import FLConfig  # noqa: E402
from repro.core.cohort import init_cohort_state, make_cohort_step  # noqa: E402
from repro.core.server_pass import (  # noqa: E402
    apply_server_round,
    flatten_stacked,
    flatten_tree,
    make_flat_spec,
)
from repro.launch.mesh import make_round_mesh  # noqa: E402
from repro.models.lenet import init_lenet  # noqa: E402
from repro.sim.engine import init_version_ring, run_vectorized  # noqa: E402


def _quad_loss(params, batch):
    x, y = batch
    return jnp.mean((x @ params["w"] - y) ** 2), {}


def _quad_clients(n=6, size=64, d=4, seed=0):
    from repro.data.synthetic import ClientDataset
    rng = np.random.default_rng(seed)
    w_true = np.arange(1.0, d + 1.0)
    out = []
    for i in range(n):
        x = rng.normal(size=(size, d)).astype(np.float32)
        y = (x @ w_true + 0.05 * rng.normal(size=size)).astype(np.float32)
        out.append(ClientDataset(x=x, y=y, seed=seed + 10 + i))
    return out


def _stack_noisy(params, k, key, scale):
    leaves, treedef = jax.tree.flatten(params)
    out = []
    for i, leaf in enumerate(leaves):
        sub = jax.random.fold_in(key, i)
        noise = scale * jax.random.normal(sub, (k,) + leaf.shape, jnp.float32)
        out.append(leaf[None].astype(jnp.float32) + noise)
    return jax.tree.unflatten(treedef, out)


def server_pass_errors(params, mesh, fl, mode, k=8, seed=0,
                       default_block=False):
    """Max |sharded - single| over new params / eq. 3 dists / weights.

    ``default_block=True`` passes block_n=0 to apply_server_round — the
    documented public default, which must derive a PER-SHARD-valid tile
    (regression: it used to pick from the global padded length).
    """
    key = jax.random.PRNGKey(seed)
    bases = _stack_noisy(params, k, jax.random.fold_in(key, 1), 0.1)
    deltas = _stack_noisy(params, k, jax.random.fold_in(key, 2), 0.01)
    losses = jnp.linspace(0.5, 2.0, k)
    sizes = jnp.linspace(10.0, 50.0, k)
    taus = jnp.arange(k, dtype=jnp.float32)

    def run(mesh_):
        spec = make_flat_spec(params, fl.server_pass_block_n, mesh=mesh_)
        new_x, info = apply_server_round(
            flatten_tree(spec, params), flatten_stacked(spec, bases),
            flatten_stacked(spec, deltas), losses, sizes, taus, fl,
            mode=mode, block_n=0 if default_block else spec.block_n,
            interpret=True, mesh=mesh_)
        return new_x[:spec.n], info

    ref_x, ref_info = run(None)
    got_x, got_info = run(mesh)
    return {
        "new_x": float(jnp.max(jnp.abs(got_x - ref_x))),
        "sq_dists": float(jnp.max(jnp.abs(
            got_info["sq_dists"] - ref_info["sq_dists"]))),
        "weights": float(jnp.max(jnp.abs(
            got_info["weights"] - ref_info["weights"]))),
    }


def engine_errors(mesh, rounds=6):
    """Sharded run_vectorized vs single-device: same windows, same maths."""
    fl = FLConfig(num_clients=6, buffer_size=2, local_steps=2, local_lr=0.05,
                  batch_size=8, max_staleness=4)
    eval_fn = lambda p: {"wnorm": float(jnp.sum(p["w"] ** 2))}  # noqa: E731
    runs = {}
    for name, m in (("single", None), ("sharded", mesh)):
        runs[name] = run_vectorized(
            _quad_loss, {"w": jnp.zeros(4)}, _quad_clients(), fl,
            total_rounds=rounds, eval_fn=eval_fn, eval_every=2, seed=0,
            mesh=m)
    ref, got = runs["single"], runs["sharded"]
    assert [l["clients"] for l in ref.round_log] == \
           [l["clients"] for l in got.round_log]
    assert [h["round"] for h in ref.history] == \
           [h["round"] for h in got.history]
    werr = max(float(np.max(np.abs(np.asarray(a["weights"])
                                   - np.asarray(b["weights"]))))
               for a, b in zip(ref.round_log, got.round_log))
    herr = max(abs(a["wnorm"] - b["wnorm"])
               for a, b in zip(ref.history, got.history))
    return {"weights": werr, "history_wnorm": herr,
            "num_launches": got.num_launches}


def ring_errors(mesh, rounds=6):
    """Flat-SHARDED version ring vs flat replicated ring on the SAME mesh.

    Only the ring's device placement differs (P(None, "model") slices vs
    replicated rows); the compiled round is identical, so the engine
    results must be BIT-identical — plus the per-device footprint
    contract: each device holds R * ceil(Np_pad / model_shards) * 4
    bytes of ring (the layout that makes a deep ring pod-viable)."""
    fl = FLConfig(num_clients=6, buffer_size=2, local_steps=2, local_lr=0.05,
                  batch_size=8, max_staleness=4)
    eval_fn = lambda p: {"wnorm": float(jnp.sum(p["w"] ** 2))}  # noqa: E731
    runs = {}
    for name, shard in (("replicated", False), ("sharded", True)):
        runs[name] = run_vectorized(
            _quad_loss, {"w": jnp.zeros(4)}, _quad_clients(), fl,
            total_rounds=rounds, eval_fn=eval_fn, eval_every=2, seed=0,
            mesh=mesh, shard_ring=shard)
    ref, got = runs["replicated"], runs["sharded"]
    w_bits = max(float(np.max(np.abs(np.asarray(a["weights"])
                                     - np.asarray(b["weights"]))))
                 for a, b in zip(ref.round_log, got.round_log))
    h_bits = max(abs(a["wnorm"] - b["wnorm"])
                 for a, b in zip(ref.history, got.history))

    # footprint: lenet-sized ring, every addressable shard one model slice
    lenet = init_lenet(jax.random.PRNGKey(0))
    spec, ring = init_version_ring(lenet, fl, mesh=mesh)
    depth = fl.max_staleness + 1
    expect = depth * (-(-spec.n_padded // spec.model_shards)) * 4
    byte_err = max(abs(sh.data.nbytes - expect)
                   for sh in ring.addressable_shards)
    return {"ring_weights_bits": w_bits, "ring_history_bits": h_bits,
            "ring_bytes_err": float(byte_err),
            "per_device_ring_bytes": expect}


def population_errors(mesh, rounds=8):
    """Sharded population engine vs single-device on identical streams.

    Counter draws are keyed by (cid, attempt), not by device placement,
    so sharding the (N,) client-state arrays over the data axis must not
    change a single window: cids/taus/slots are compared EXACTLY, upload
    times and round maths at f32 rounding level."""
    from repro.sim import get_scenario
    from repro.sim.population import collect_windows, run_population

    sc = get_scenario("dropout-bernoulli")
    n, k, t = 8, 4, 10
    fl = FLConfig(num_clients=n, buffer_size=k, local_steps=2,
                  local_lr=0.05, batch_size=8, max_staleness=4)
    ref = collect_windows(sc, n, fl, t, seed=3)
    got = collect_windows(sc, n, fl, t, seed=3, mesh=mesh)
    meta_err = 0.0 if (np.array_equal(ref["clients"], got["clients"])
                       and np.array_equal(ref["tau"], got["tau"])
                       and np.array_equal(ref["slots"], got["slots"])
                       and ref["num_events"] == got["num_events"]) else 1.0
    t_err = float(np.max(np.abs(ref["t"] - got["t"])))

    fl6 = FLConfig(num_clients=6, buffer_size=2, local_steps=2,
                   local_lr=0.05, batch_size=8, max_staleness=4)
    eval_fn = lambda p: {"wnorm": float(jnp.sum(p["w"] ** 2))}  # noqa: E731
    runs = {}
    for name, m in (("single", None), ("sharded", mesh)):
        runs[name] = run_population(
            _quad_loss, {"w": jnp.zeros(4)}, _quad_clients(), fl6,
            total_rounds=rounds, eval_fn=eval_fn, eval_every=2,
            scenario=sc, seed=0, mesh=m)
    ref_r, got_r = runs["single"], runs["sharded"]
    assert [l["clients"] for l in ref_r.round_log] == \
           [l["clients"] for l in got_r.round_log]
    assert [l["tau"] for l in ref_r.round_log] == \
           [l["tau"] for l in got_r.round_log]
    werr = max(float(np.max(np.abs(np.asarray(a["weights"])
                                   - np.asarray(b["weights"]))))
               for a, b in zip(ref_r.round_log, got_r.round_log))
    herr = max(abs(a["wnorm"] - b["wnorm"])
               for a, b in zip(ref_r.history, got_r.history))
    return {"win_meta": meta_err, "win_t": t_err,
            "pop_weights": werr, "pop_wnorm": herr}


def cohort_errors(mesh, cohort=4, seed=0):
    """Sharded make_cohort_step vs single-device on one quad round."""
    fl = FLConfig(buffer_size=cohort, local_steps=2, local_lr=0.1,
                  weighting="paper")
    params = {"w": jnp.array([1.0, -1.0, 0.5, 2.0])}
    key = jax.random.PRNGKey(seed)

    def quad_batch(k_):
        k1, k2 = jax.random.split(k_)
        x = jax.random.normal(k1, (8, 4))
        y = x @ jnp.arange(1.0, 5.0) + 0.01 * jax.random.normal(k2, (8,))
        return x, y

    batch = {
        "local": jax.tree.map(
            lambda *xs: jnp.stack(xs).reshape(cohort, 2, 4, *xs[0].shape[1:]),
            *[quad_batch(jax.random.fold_in(key, i)) for i in range(cohort)]),
        "probe": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[quad_batch(jax.random.fold_in(key, 100 + i))
              for i in range(cohort)]),
        "arrival": jnp.array([1.0] * (cohort - 1) + [0.0]),  # one straggler
        "data_sizes": jnp.linspace(10.0, 40.0, cohort),
    }
    outs = {}
    for name, m in (("single", None), ("sharded", mesh)):
        step = make_cohort_step(_quad_loss, fl, mesh=m)
        state = init_cohort_state(params, cohort)
        new_state, mets = step(state, batch)
        outs[name] = (new_state, mets)
    ref_s, ref_m = outs["single"]
    got_s, got_m = outs["sharded"]
    return {
        "global": float(jnp.max(jnp.abs(ref_s.global_params["w"]
                                        - got_s.global_params["w"]))),
        "client_params": float(max(
            jnp.max(jnp.abs(a - b)) for a, b in
            zip(jax.tree.leaves(ref_s.client_params),
                jax.tree.leaves(got_s.client_params)))),
        "metrics": float(max(abs(float(ref_m[k_]) - float(got_m[k_]))
                             for k_ in ref_m)),
    }


def run_all():
    assert len(jax.devices()) >= 8, len(jax.devices())
    mesh_m8 = make_round_mesh(data=1, model=8)
    mesh_d2m4 = make_round_mesh(data=2, model=4)
    fl = FLConfig(weighting="paper")
    report = {"devices": len(jax.devices())}
    # acceptance gate: lenet_fmnist flat pass, 8-way model sharding
    lenet = init_lenet(jax.random.PRNGKey(0))
    for mode in ("reference", "batched"):
        report[f"lenet_pass_{mode}"] = server_pass_errors(
            lenet, mesh_m8, fl, mode)
    report["lenet_pass_d2m4"] = server_pass_errors(lenet, mesh_d2m4, fl,
                                                   "reference")
    # block_n=0 default on a tiny tree: per-shard tile must stay valid
    report["small_pass_default_block"] = server_pass_errors(
        {"w": jnp.linspace(-1.0, 1.0, 100)}, mesh_m8, fl, "batched", k=4,
        default_block=True)
    report["engine"] = engine_errors(mesh_d2m4)
    report["cohort"] = cohort_errors(mesh_d2m4)
    # population engine: data-axis-sharded client state, exact windows
    report["population"] = population_errors(mesh_d2m4)
    # sharded-ring vs replicated-ring: bit parity + per-device footprint
    report["ring"] = ring_errors(mesh_d2m4)
    report["ring_m8"] = ring_errors(mesh_m8)
    return report


if __name__ == "__main__":
    import json
    print(json.dumps(run_all()))

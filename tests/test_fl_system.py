"""Integration tests of the FL system: server/buffer semantics, simulator
behaviour, compiled-cohort vs event-driven agreement, convergence ordering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import (
    AsyncServer,
    LatencyModel,
    UpdateBuffer,
    init_cohort_state,
    make_cohort_step,
    make_dist_step,
    init_dist_state,
    run_async,
    run_sync,
)
from repro.core.buffer import BufferEntry
from repro.data import make_federated_image_dataset
from repro.models.lenet import apply_lenet, init_lenet, lenet_loss
from repro.utils import tree_flatten_to_vector


def _quad_loss(params, batch):
    """Convex toy problem: params w; loss = mean (x.w - y)^2."""
    x, y = batch
    pred = x @ params["w"]
    loss = jnp.mean((pred - y) ** 2)
    return loss, {}


def _quad_batch(key, n=16, d=4):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (n, d))
    w_true = jnp.arange(1.0, d + 1.0)
    y = x @ w_true + 0.01 * jax.random.normal(k2, (n,))
    return x, y


class TestBuffer:
    def test_fifo_and_overflow(self):
        buf = UpdateBuffer(2)
        for i in range(3):
            buf.add(BufferEntry(i, {"w": jnp.zeros(1)}, 0, 10))
        assert buf.ready()
        first = buf.drain()
        assert [e.client_id for e in first] == [0, 1]
        assert len(buf) == 1  # overflow entry retained

    def test_version_history_bound_is_exact(self):
        """put() must retain AT MOST max_versions snapshots (the old
        pruning floor kept max_versions + 1) and exactly the newest
        window [version - max_versions + 1, version]."""
        from repro.core.buffer import VersionHistory
        hist = VersionHistory(3)
        for v in range(10):
            hist.put(v, {"w": jnp.full(2, float(v))})
            assert len(hist._snaps) <= 3
        assert sorted(hist._snaps) == [7, 8, 9]
        assert hist.oldest() == 7
        assert 6 not in hist and 9 in hist


class TestAsyncServer:
    def _server(self, weighting="paper", k=2):
        fl = FLConfig(buffer_size=k, weighting=weighting, global_lr=1.0)
        params = {"w": jnp.zeros(4)}
        return AsyncServer(params, fl, lambda p, b: _quad_loss(p, b)[0]), fl

    def test_aggregates_exactly_at_k(self):
        server, _ = self._server()
        d = {"w": jnp.ones(4)}
        batch = _quad_batch(jax.random.PRNGKey(0))
        assert not server.receive(0, d, 0, 10, lambda: batch)
        assert server.receive(1, d, 0, 10, lambda: batch)
        assert server.version == 1

    def test_fedbuff_matches_plain_average(self):
        server, _ = self._server("fedbuff")
        batch = _quad_batch(jax.random.PRNGKey(0))
        server.receive(0, {"w": jnp.ones(4)}, 0, 10, lambda: batch)
        server.receive(1, {"w": 3 * jnp.ones(4)}, 0, 10, lambda: batch)
        np.testing.assert_allclose(np.asarray(server.params["w"]),
                                   -2.0 * np.ones(4), rtol=1e-5)

    def test_version_history_pruned(self):
        fl = FLConfig(buffer_size=1, max_staleness=3)
        server = AsyncServer({"w": jnp.zeros(2)}, fl,
                             lambda p, b: _quad_loss(p, b)[0])
        batch = _quad_batch(jax.random.PRNGKey(0), d=2)
        for i in range(6):
            server.receive(0, {"w": jnp.ones(2) * 0.1}, server.version, 10,
                           lambda: batch)
        assert 0 not in server.history
        assert server.version in server.history

    def test_round_log_records_paper_quantities(self):
        server, _ = self._server("paper")
        batch = _quad_batch(jax.random.PRNGKey(0))
        server.receive(0, {"w": jnp.ones(4)}, 0, 10, lambda: batch)
        server.receive(1, {"w": jnp.ones(4)}, 0, 20, lambda: batch)
        log = server.round_log[0]
        assert set(log) >= {"weights", "staleness_deg", "stat_effect", "tau"}
        # same staleness, P proportional to N_i => client 1 weighted higher
        assert log["weights"][1] > log["weights"][0]


@pytest.mark.slow  # multi-round event-driven simulator runs
class TestSimulator:
    @pytest.fixture(scope="class")
    def fed_setup(self):
        clients, (xt, yt) = make_federated_image_dataset(
            num_clients=6, samples_per_client=120, alpha=0.2, noise=0.8,
            seed=3)
        params = init_lenet(jax.random.PRNGKey(0))
        ev = jax.jit(lambda p: jnp.mean(
            (jnp.argmax(apply_lenet(p, xt[:256]), -1) == yt[:256])
            .astype(jnp.float32)))
        return clients, params, (lambda p: {"acc": float(ev(p))})

    def test_async_beats_sync_wall_clock(self, fed_setup):
        """The core async-FL claim: same #rounds, far less simulated time."""
        clients, params, ev = fed_setup
        fl = FLConfig(num_clients=6, buffer_size=3, local_steps=2,
                      local_lr=0.05, batch_size=16)
        lat = LatencyModel.heterogeneous(6, max_slowdown=10.0, seed=0)
        res_a = run_async(lenet_loss, params, clients, fl, total_rounds=6,
                          eval_fn=ev, latency=lat, seed=0)
        res_s = run_sync(lenet_loss, params, clients, fl, total_rounds=6,
                         eval_fn=ev, latency=lat, seed=0)
        assert res_a.server_rounds == res_s.server_rounds == 6
        assert res_a.sim_time < res_s.sim_time  # stragglers don't block

    def test_straggler_updates_are_stale(self, fed_setup):
        clients, params, ev = fed_setup
        fl = FLConfig(num_clients=6, buffer_size=3, local_steps=2,
                      local_lr=0.05, batch_size=16)
        res = run_async(lenet_loss, params, clients, fl, total_rounds=8,
                        eval_fn=ev, seed=0)
        taus = [t for log in res.round_log for t in log["tau"]]
        assert max(taus) >= 1  # staleness actually occurs
        s_degrees = [s for log in res.round_log for s in log["staleness_deg"]]
        assert min(s_degrees) < 1.0  # eq. 3 differentiates updates

    def test_paper_weighting_trains(self, fed_setup):
        clients, params, ev = fed_setup
        fl = FLConfig(num_clients=6, buffer_size=3, local_steps=2,
                      local_lr=0.05, batch_size=16, weighting="paper")
        res = run_async(lenet_loss, params, clients, fl, total_rounds=15,
                        eval_fn=ev, eval_every=15, seed=0)
        assert res.history[-1]["acc"] > res.history[0]["acc"] + 0.2


class TestCohortStep:
    def test_matches_manual_equations(self):
        """One compiled cohort round == hand-computed eq. 3/4/5."""
        fl = FLConfig(buffer_size=2, local_steps=1, local_lr=0.1,
                      weighting="paper", normalize="mean", global_lr=1.0)
        params = {"w": jnp.array([1.0, -1.0, 0.5, 2.0])}
        cohort = 2
        state = init_cohort_state(params, cohort)
        key = jax.random.PRNGKey(0)
        batches = [_quad_batch(jax.random.fold_in(key, i)) for i in range(4)]
        batch = {
            "local": jax.tree.map(
                lambda *xs: jnp.stack(xs).reshape(cohort, 1, *xs[0].shape),
                *batches[:2]),
            "probe": jax.tree.map(lambda *xs: jnp.stack(xs), *batches[2:]),
            "arrival": jnp.ones(cohort),
            "data_sizes": jnp.array([10.0, 30.0]),
        }
        step = make_cohort_step(_quad_loss, fl)
        new_state, mets = step(state, batch)

        # manual: both clients fresh (dist 0) => S = 1; P = N_i * probe loss
        g0 = jax.grad(lambda p: _quad_loss(p, batches[0])[0])(params)["w"]
        g1 = jax.grad(lambda p: _quad_loss(p, batches[1])[0])(params)["w"]
        d0, d1 = 0.1 * g0, 0.1 * g1  # Delta = base - end = lr * grad
        p0 = 10.0 * _quad_loss(params, batches[2])[0]
        p1 = 30.0 * _quad_loss(params, batches[3])[0]
        w = jnp.array([p0, p1])
        w = w * 2 / jnp.sum(w)
        expect = params["w"] - (jnp.stack([d0, d1]) * w[:, None]).sum(0) / 2
        np.testing.assert_allclose(np.asarray(new_state.global_params["w"]),
                                   np.asarray(expect), rtol=1e-5)
        assert int(new_state.version) == 1

    def test_straggler_keeps_progress_and_goes_stale(self):
        fl = FLConfig(buffer_size=1, local_steps=1, local_lr=0.1,
                      weighting="paper")
        params = {"w": jnp.zeros(4)}
        state = init_cohort_state(params, 2)
        step = jax.jit(make_cohort_step(_quad_loss, fl))
        key = jax.random.PRNGKey(0)
        batch = {
            "local": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (2, 1) + x.shape),
                _quad_batch(key)),
            "probe": jax.tree.map(lambda x: jnp.broadcast_to(x, (2,) + x.shape),
                                  _quad_batch(jax.random.fold_in(key, 9))),
            "arrival": jnp.array([1.0, 0.0]),  # slot 1 is a straggler
            "data_sizes": jnp.ones(2),
        }
        s1, _ = step(state, batch)
        assert int(s1.client_version[0]) == 1
        assert int(s1.client_version[1]) == 0  # still on its old base
        # straggler's local params differ from both base and new global
        w_stale = np.asarray(jax.tree.leaves(s1.client_params)[0][1])
        w_base = np.asarray(jax.tree.leaves(s1.client_base)[0][1])
        assert not np.allclose(w_stale, w_base)
        s2, mets = step(s1, batch)
        # telemetry is arrival-masked: the still-absent straggler must NOT
        # drag staleness_min below 1 (only the fresh slot 0 arrived)
        assert float(mets["staleness_min"]) == pytest.approx(1.0)
        # ... but once the straggler ARRIVES, its staleness is visible
        batch_both = dict(batch, arrival=jnp.ones(2))
        _, mets3 = step(s2, batch_both)
        assert float(mets3["staleness_min"]) < 1.0

    def test_fedbuff_policy_reduces_to_uniform(self):
        fl_p = FLConfig(buffer_size=2, local_steps=1, local_lr=0.1,
                        weighting="fedbuff")
        params = {"w": jnp.array([0.3, -0.7])}
        state = init_cohort_state(params, 2)
        key = jax.random.PRNGKey(1)
        batch = {
            "local": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (2, 1) + x.shape),
                _quad_batch(key, d=2)),
            "probe": jax.tree.map(lambda x: jnp.broadcast_to(x, (2,) + x.shape),
                                  _quad_batch(jax.random.fold_in(key, 2), d=2)),
            "arrival": jnp.ones(2),
            "data_sizes": jnp.array([10.0, 99.0]),  # must be ignored
        }
        step = make_cohort_step(_quad_loss, fl_p)
        s1, _ = step(state, batch)
        g = jax.grad(lambda p: _quad_loss(p, jax.tree.map(lambda x: x[0, 0],
                                                          batch["local"]))[0])(params)
        expect = params["w"] - 0.1 * g["w"]  # both deltas identical
        np.testing.assert_allclose(np.asarray(s1.global_params["w"]),
                                   np.asarray(expect), rtol=1e-5)


class TestServerCohortAgreement:
    """The event-driven ``AsyncServer`` and the compiled replicated-client
    ``make_cohort_step`` must implement the same round maths (the claim in
    server.py's docstring): same batches, same probes -> same new global."""

    @pytest.mark.parametrize("weighting", ["paper", "fedbuff"])
    def test_one_round_matches(self, weighting):
        fl = FLConfig(buffer_size=2, local_steps=1, local_lr=0.1,
                      weighting=weighting, normalize="mean", global_lr=1.0)
        params = {"w": jnp.array([1.0, -1.0, 0.5, 2.0])}
        key = jax.random.PRNGKey(0)
        local = [_quad_batch(jax.random.fold_in(key, i)) for i in range(2)]
        probe = [_quad_batch(jax.random.fold_in(key, 10 + i)) for i in range(2)]
        sizes = [10, 30]

        # compiled cohort round (local training happens inside the step)
        state = init_cohort_state(params, 2)
        batch = {
            "local": jax.tree.map(
                lambda *xs: jnp.stack(xs).reshape(2, 1, *xs[0].shape), *local),
            "probe": jax.tree.map(lambda *xs: jnp.stack(xs), *probe),
            "arrival": jnp.ones(2),
            "data_sizes": jnp.asarray(sizes, jnp.float32),
        }
        step = make_cohort_step(_quad_loss, fl)
        cohort_state, _ = step(state, batch)

        # event-driven server fed the very same deltas and probes
        from repro.core.client import make_local_update_fn
        local_update = make_local_update_fn(_quad_loss, fl.local_steps,
                                            fl.local_lr, fl.local_momentum)
        server = AsyncServer(params, fl, lambda p, b: _quad_loss(p, b)[0])
        for cid in range(2):
            batches = jax.tree.map(lambda x: x[None], local[cid])
            delta, _ = local_update(params, batches)
            server.receive(cid, delta, 0, sizes[cid],
                           fresh_batch_fn=lambda c=cid: probe[c])

        assert server.version == 1
        np.testing.assert_allclose(
            np.asarray(server.params["w"]),
            np.asarray(cohort_state.global_params["w"]), rtol=1e-5)


class TestDistStep:
    def test_streaming_equals_batch_aggregation(self):
        """K sequential dist-steps == one cohort aggregation (paper policy,
        mean normalisation: the eq.-3 min cancels)."""
        fl = FLConfig(buffer_size=2, local_steps=1, local_lr=0.1,
                      weighting="fedbuff", global_lr=1.0)
        params = {"w": jnp.array([1.0, 2.0, 3.0])}
        step = jax.jit(make_dist_step(_quad_loss, fl))
        state = init_dist_state(params, fl)
        key = jax.random.PRNGKey(0)
        deltas = []
        for i in range(2):
            b = _quad_batch(jax.random.fold_in(key, i), d=3)
            batch = {"local": jax.tree.map(lambda x: x[None], b),
                     "probe": _quad_batch(jax.random.fold_in(key, 10 + i), d=3),
                     "tau": jnp.int32(0), "data_size": jnp.float32(10.0)}
            g = jax.grad(lambda p: _quad_loss(p, b)[0])(params)
            deltas.append(0.1 * g["w"])
            state, _ = step(state, batch)
        assert int(state.version) == 1
        expect = params["w"] - (deltas[0] + deltas[1]) / 2
        np.testing.assert_allclose(np.asarray(state.global_params["w"]),
                                   np.asarray(expect), rtol=1e-5)

"""Coverage for data/partition.py: exact assignment, floors, IID limit."""
import numpy as np
import pytest

from repro.data.partition import dirichlet_partition, shard_partition


def _labels(n=1200, num_classes=10, seed=0):
    return np.random.default_rng(seed).integers(0, num_classes, size=n)


class TestDirichletPartition:
    @pytest.mark.parametrize("alpha", [0.1, 1.0, 100.0])
    def test_every_sample_assigned_exactly_once(self, alpha):
        y = _labels()
        parts = dirichlet_partition(y, num_clients=8, alpha=alpha, seed=1)
        allidx = np.concatenate(parts)
        assert allidx.size == y.size
        np.testing.assert_array_equal(np.sort(allidx), np.arange(y.size))

    @pytest.mark.parametrize("min_per_client", [1, 8, 40])
    def test_min_per_client_honored(self, min_per_client):
        y = _labels()
        parts = dirichlet_partition(y, num_clients=10, alpha=0.1, seed=2,
                                    min_per_client=min_per_client)
        assert min(len(p) for p in parts) >= min_per_client

    def test_alpha_to_inf_approaches_iid(self):
        """α→∞: every client's label histogram converges to the global one
        (the limit the IID scenarios rely on); small α stays far from it."""
        y = _labels(n=5000)
        global_hist = np.bincount(y, minlength=10) / y.size

        def max_dev(alpha):
            parts = dirichlet_partition(y, num_clients=5, alpha=alpha, seed=3)
            devs = []
            for p in parts:
                h = np.bincount(y[p], minlength=10) / len(p)
                devs.append(np.abs(h - global_hist).max())
            return max(devs)

        assert max_dev(1e5) < 0.02  # IID limit: histograms match
        assert max_dev(0.05) > 0.2  # extreme skew: they do not

    def test_low_alpha_concentrates_labels(self):
        y = _labels(n=2000)
        parts = dirichlet_partition(y, num_clients=10, alpha=0.05, seed=4)
        # most clients see only a few classes
        classes_seen = [np.unique(y[p]).size for p in parts]
        assert np.median(classes_seen) <= 5

    def test_deterministic_given_seed(self):
        y = _labels()
        a = dirichlet_partition(y, 6, alpha=0.3, seed=7)
        b = dirichlet_partition(y, 6, alpha=0.3, seed=7)
        for x, z in zip(a, b):
            np.testing.assert_array_equal(x, z)


class TestShardPartition:
    def test_every_sample_assigned_exactly_once(self):
        y = _labels(n=800)
        parts = shard_partition(y, num_clients=8, shards_per_client=2, seed=0)
        allidx = np.concatenate(parts)
        np.testing.assert_array_equal(np.sort(allidx), np.arange(y.size))

    def test_pathological_skew(self):
        y = np.sort(_labels(n=1000))
        parts = shard_partition(y, num_clients=10, shards_per_client=2, seed=1)
        classes_seen = [np.unique(y[p]).size for p in parts]
        assert max(classes_seen) <= 4  # each client holds ~2 shards of labels

"""core/version_store.py: the codec-pluggable compressed version ring.

Gates for the DESIGN.md §11 refactor:

* the fused int8 dequantize-distance kernel matches its pure-jnp
  reference (interpret mode, shape/qblock sweep);
* codec roundtrips obey their error bounds (int8: half a quantization
  step per entry; delta: exact when the residual fits in m);
* run_vectorized under int8/delta tracks the f32 engine within codec
  tolerance across EVERY weighting policy — and f32 itself *is* the
  pre-refactor program (the sharded/multihost bit-parity pins live in
  ``_shard_worker.py``);
* the bytes-per-device contract: allocated ring bytes equal
  ``codec.device_bytes`` exactly, and int8 is >= 3x smaller than f32;
* checkpoint resume is bit-identical per codec, and restore errors name
  the codec and its expected layout;
* stale-base resync and the population K > N exact-fallback behave
  identically under every codec;
* every ``configs/registry.py`` arch flattens through the spec and gets
  a finite bytes-per-ring-row quote per codec (the large-model smoke).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.base import FLConfig
from repro.core.server_pass import make_flat_spec
from repro.core.version_store import (
    CODECS,
    DeltaCodec,
    F32Codec,
    Int8Codec,
    build_ring,
    resolve_qblock,
    ring_device_bytes,
    ring_state_to_host,
)
from repro.kernels.ring_codec import int8_sq_dists, int8_sq_dists_ref
from repro.kernels.ring_codec.kernel import int8_sq_dists_pallas
from repro.sim import get_scenario
from repro.sim.engine import (
    engine_state_from_tree,
    engine_state_to_tree,
    init_version_ring,
    run_vectorized,
)
from repro.sim.population import run_population

from _shard_worker import _quad_clients, _quad_loss

ALL_POLICIES = ("paper", "multiplicative", "fedbuff", "polynomial",
                "fedasync_constant", "fedasync_hinge", "fedasync_poly")

FL = FLConfig(num_clients=6, buffer_size=3, local_steps=2, local_lr=0.05,
              batch_size=8, max_staleness=4)


def _fl(codec, **kw):
    return dataclasses.replace(FL, ring_codec=codec, **kw)


def _eval(p):
    return {"wnorm": float(jnp.sum(p["w"] ** 2))}


def _run(fl, rounds=8, **kw):
    return run_vectorized(_quad_loss, {"w": jnp.zeros(4)}, _quad_clients(),
                          fl, total_rounds=rounds, eval_fn=_eval,
                          eval_every=2, seed=0, **kw)


def _quant_arrays(key, k, n, qblock, scale=1.0):
    """Random (codes, scales, zeros, x) with non-degenerate blocks."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    codes = jax.random.randint(k1, (k, n), -127, 128, jnp.int32) \
        .astype(jnp.int8)
    scales = scale * jax.random.uniform(
        k2, (k, n // qblock), jnp.float32, 1e-4, 2e-2)
    zeros = jax.random.normal(k3, (k, n // qblock), jnp.float32)
    x = jax.random.normal(k4, (n,), jnp.float32)
    return codes, scales, zeros, x


class TestInt8Kernel:
    """Fused dequantize-distance kernel vs the jnp reference."""

    @pytest.mark.parametrize("k,n,qblock,block_n", [
        (1, 256, 128, 256),
        (3, 512, 128, 256),
        (5, 1024, 256, 512),
        (8, 2048, 64, 256),
        (4, 640, 128, 128),  # block_n == qblock, odd tile count
    ])
    def test_kernel_matches_ref(self, k, n, qblock, block_n):
        codes, scales, zeros, x = _quant_arrays(
            jax.random.PRNGKey(k * 1000 + n), k, n, qblock)
        ref = int8_sq_dists_ref(x, codes, scales, zeros, qblock)
        got = int8_sq_dists_pallas(x, codes, scales, zeros, qblock=qblock,
                                   block_n=block_n, interpret=True)
        assert got.shape == (k,)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_ops_dispatch_parity(self):
        """ops.int8_sq_dists: ref path == kernel path == decode-then-
        subtract (the naive dense computation the fusion replaces)."""
        qblock, k, n = 128, 4, 1024
        codes, scales, zeros, x = _quant_arrays(
            jax.random.PRNGKey(7), k, n, qblock)
        ref = int8_sq_dists(x, codes, scales, zeros, qblock=qblock)
        ker = int8_sq_dists(x, codes, scales, zeros, qblock=qblock,
                            use_kernel=True, interpret=True)
        from repro.kernels.ring_codec import dequant_ref
        dense = dequant_ref(codes, scales, zeros, qblock)
        naive = jnp.sum((x[None] - dense) ** 2, axis=1)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(naive),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ker), np.asarray(naive),
                                   rtol=1e-5, atol=1e-5)

    def test_kernel_handles_indivisible_block_via_ops(self):
        """ops falls back to one whole-row tile when block_n does not
        divide n (tiny models)."""
        qblock, k, n = 128, 2, 384
        codes, scales, zeros, x = _quant_arrays(
            jax.random.PRNGKey(9), k, n, qblock)
        ref = int8_sq_dists_ref(x, codes, scales, zeros, qblock)
        got = int8_sq_dists(x, codes, scales, zeros, qblock=qblock,
                            block_n=256, use_kernel=True, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


class TestCodecRoundTrip:
    """encode -> decode error bounds on the flat padded layout."""

    def _spec(self, d=4000):
        return make_flat_spec({"w": jnp.zeros(d)}, 256)

    def test_resolve_qblock_divides_tile(self):
        spec = self._spec()
        for req in (256, 512, 100, 7, 1):
            qb = resolve_qblock(spec, req)
            assert qb >= 1 and spec.block_n % qb == 0

    def test_f32_roundtrip_is_identity(self):
        spec = self._spec()
        row = jax.random.normal(jax.random.PRNGKey(0), (spec.n_padded,))
        codec = F32Codec()
        state = codec.init_state(spec, row, 5)
        out = codec.decode(spec, state, jnp.arange(3))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.tile(np.asarray(row), (3, 1)))

    def test_int8_error_bounded_by_half_step(self):
        """Per-entry |decode(encode(v)) - v| <= scale/2 + rounding slack:
        the affine quantizer's worst case (DESIGN.md §11 bound)."""
        spec = self._spec()
        codec = Int8Codec(qblock=256)
        qb = resolve_qblock(spec, 256)
        row = jax.random.normal(jax.random.PRNGKey(1), (spec.n_padded,))
        state = codec.init_state(spec, jnp.zeros(spec.n_padded), 4)
        state = codec.encode(spec, state, 2, row)
        out = codec.decode(spec, state, jnp.asarray([2]))[0]
        err = np.abs(np.asarray(out - row)).reshape(-1, qb)
        v = np.asarray(row).reshape(-1, qb)
        step = (v.max(axis=1) - v.min(axis=1)) / 254.0
        assert np.all(err.max(axis=1) <= step * 0.5 + 1e-6)

    def test_int8_constant_block_is_exact(self):
        """A zero-range block has scale 0: decode must return the exact
        constant, not NaN from a 0/0."""
        spec = self._spec(512)
        codec = Int8Codec(qblock=256)
        row = jnp.full((spec.n_padded,), 3.25)
        state = codec.init_state(spec, row, 2)
        out = codec.decode(spec, state, jnp.asarray([0]))[0]
        np.testing.assert_array_equal(np.asarray(out), np.asarray(row))

    def test_delta_exact_when_residual_fits(self):
        """Residual sparser than m: roundtrip AND distances are exact."""
        spec = self._spec(1000)
        codec = DeltaCodec(density=0.02)  # m ~ 20 slots
        base = jax.random.normal(jax.random.PRNGKey(2), (spec.n_padded,))
        state = codec.init_state(spec, base, 4)
        row = base.at[jnp.asarray([3, 100, 777])].add(
            jnp.asarray([1.0, -2.0, 0.5]))
        state = codec.encode(spec, state, 1, row)
        out = codec.decode(spec, state, jnp.asarray([1]))[0]
        np.testing.assert_allclose(np.asarray(out), np.asarray(row),
                                   rtol=1e-6, atol=1e-6)
        x = jax.random.normal(jax.random.PRNGKey(3), (spec.n_padded,))
        d = codec.distance_sq(spec, state, jnp.asarray([1]), x)
        ref = jnp.sum((x - row) ** 2)
        np.testing.assert_allclose(np.asarray(d[0]), float(ref),
                                   rtol=1e-5)

    def test_delta_base_refresh_zeroes_written_slot(self):
        """The refresh write becomes the new base: its residual is empty
        and retained rows still decode to their values."""
        spec = self._spec(1000)
        codec = DeltaCodec(density=0.05, base_refresh=2)
        base = jnp.zeros(spec.n_padded)
        state = codec.init_state(spec, base, 3)
        r1 = base.at[5].add(1.0)
        state = codec.encode(spec, state, 1, r1)  # write 1: normal
        r2 = base.at[9].add(2.0)
        state = codec.encode(spec, state, 2, r2)  # write 2: refresh
        np.testing.assert_allclose(np.asarray(state.base), np.asarray(r2))
        assert float(jnp.sum(jnp.abs(state.val[2]))) == 0.0
        out = codec.decode(spec, state, jnp.asarray([1, 2]))
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(r1),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(out[1]), np.asarray(r2),
                                   atol=1e-6)


class TestEngineParity:
    """run_vectorized per codec vs f32 across every weighting policy."""

    @pytest.mark.parametrize("weighting", ALL_POLICIES)
    def test_int8_tracks_f32_all_policies(self, weighting):
        ref = _run(_fl("f32", weighting=weighting))
        got = _run(_fl("int8", weighting=weighting))
        # the host event walk is codec-independent: exact stream parity
        assert [l["clients"] for l in ref.round_log] == \
               [l["clients"] for l in got.round_log]
        assert [l["tau"] for l in ref.round_log] == \
               [l["tau"] for l in got.round_log]
        # quantization perturbs bases/distances within codec tolerance
        for a, b in zip(ref.round_log, got.round_log):
            np.testing.assert_allclose(a["weights"], b["weights"],
                                       rtol=0.05, atol=5e-3)
        for a, b in zip(ref.history, got.history):
            assert a["round"] == b["round"]
            np.testing.assert_allclose(a["wnorm"], b["wnorm"], rtol=0.05)

    @pytest.mark.parametrize("weighting", ALL_POLICIES)
    def test_delta_tracks_f32_all_policies(self, weighting):
        ref = _run(_fl("f32", weighting=weighting))
        got = _run(_fl("delta", weighting=weighting))
        assert [l["tau"] for l in ref.round_log] == \
               [l["tau"] for l in got.round_log]
        for a, b in zip(ref.round_log, got.round_log):
            np.testing.assert_allclose(a["weights"], b["weights"],
                                       rtol=0.05, atol=5e-3)
        for a, b in zip(ref.history, got.history):
            np.testing.assert_allclose(a["wnorm"], b["wnorm"], rtol=0.05)

    def test_delta_full_density_is_close_to_exact(self):
        """m = Np keeps the whole residual: the run must match f32 to
        f32 rounding (the distances use the exact expansion)."""
        ref = _run(_fl("f32"))
        got = _run(_fl("delta", ring_delta_density=1.0))
        for a, b in zip(ref.history, got.history):
            np.testing.assert_allclose(a["wnorm"], b["wnorm"], rtol=1e-5)

    def test_unknown_codec_raises(self):
        with pytest.raises(ValueError, match="ring_codec"):
            _run(_fl("lz4"))


class TestBytesContract:
    """Allocated ring bytes == device_bytes quotes; int8 >= 3x smaller."""

    def _alloc_bytes(self, fl, d=5000):
        params = {"w": jnp.zeros(d)}
        spec, state = init_version_ring(params, fl)
        got = sum(leaf.nbytes for leaf in jax.tree.leaves(state))
        return spec, got

    @pytest.mark.parametrize("codec", CODECS)
    def test_device_bytes_matches_allocation(self, codec):
        fl = _fl(codec)
        spec, got = self._alloc_bytes(fl)
        assert got == ring_device_bytes(fl, spec)

    def test_int8_is_at_least_3x_smaller(self):
        spec, f32_bytes = self._alloc_bytes(_fl("f32"))
        _, int8_bytes = self._alloc_bytes(_fl("int8"))
        assert f32_bytes / int8_bytes >= 3.0

    def test_delta_beats_f32_on_deep_rings(self):
        fl = _fl("delta", max_staleness=15)
        spec, delta_bytes = self._alloc_bytes(fl)
        _, f32_bytes = self._alloc_bytes(_fl("f32", max_staleness=15))
        assert f32_bytes / delta_bytes >= 3.0

    def test_sharded_quote_divides_dense_terms(self):
        """model_shards > 1 splits the dense arrays, not the sparse
        replicated ones."""
        fl = _fl("int8")
        spec = make_flat_spec({"w": jnp.zeros(4096)}, 256)
        whole = ring_device_bytes(fl, spec, model_shards=1)
        split = ring_device_bytes(fl, spec, model_shards=4)
        assert whole / 4 <= split <= whole / 4 + 1024


class TestCheckpointResume:
    """Per-codec: capture -> disk -> restore -> resume, bit-identical."""

    @pytest.mark.parametrize("codec", CODECS)
    def test_resume_is_bit_identical(self, codec, tmp_path):
        fl = _fl(codec)
        full = _run(fl, 8, capture_state=True)
        half = _run(fl, 4, capture_state=True)
        tree = engine_state_to_tree(half.final_state)
        path = str(tmp_path / f"{codec}.npz")
        save_checkpoint(path, tree, step=4)
        loaded, step = load_checkpoint(path, like=tree)
        assert step == 4
        resumed = _run(fl, 8, init_state=engine_state_from_tree(loaded),
                       capture_state=True)
        assert resumed.round_log == full.round_log
        assert resumed.history == full.history
        for a, b in zip(jax.tree.leaves(resumed.final_state.ring),
                        jax.tree.leaves(full.final_state.ring)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_f32_host_state_is_bare_matrix(self):
        """Pre-codec checkpoints stay byte-compatible: the f32 codec's
        host form is the raw (R, Np) f32 array, not a dict."""
        half = _run(_fl("f32"), 2, capture_state=True)
        assert isinstance(half.final_state.ring, np.ndarray)
        assert half.final_state.ring.dtype == np.float32

    @pytest.mark.parametrize("codec", ("int8", "delta"))
    def test_compressed_host_state_is_stamped_dict(self, codec):
        half = _run(_fl(codec), 2, capture_state=True)
        ring = half.final_state.ring
        assert isinstance(ring, dict)
        assert str(np.asarray(ring["codec"])) == codec


class TestRestoreErrors:
    """Codec-aware mismatch messages: name the codec + expected layout."""

    def _spec_fl(self, codec):
        fl = _fl(codec)
        params = {"w": jnp.zeros(1000)}
        spec, state = init_version_ring(params, fl)
        return params, fl, spec, state

    def test_dict_into_f32_names_both_codecs(self):
        params, fl, spec, state = self._spec_fl("int8")
        host = ring_state_to_host(fl, jax.device_get(state))
        with pytest.raises(ValueError) as e:
            build_ring(params, _fl("f32"), rows=host)
        assert "'int8'" in str(e.value)
        assert "ring_codec='f32'" in str(e.value)
        assert "ring:" in str(e.value)  # the expected f32 layout

    def test_matrix_into_int8_names_codec_and_layout(self):
        params, fl, spec, state = self._spec_fl("f32")
        host = ring_state_to_host(fl, jax.device_get(state))
        with pytest.raises(ValueError) as e:
            build_ring(params, _fl("int8"), rows=host)
        assert "f32 matrix" in str(e.value)
        assert "ring_codec='int8'" in str(e.value)
        assert "codes:" in str(e.value) and "scale:" in str(e.value)

    def test_wrong_f32_shape_names_layout(self):
        params = {"w": jnp.zeros(1000)}
        with pytest.raises(ValueError, match="f32 ring shape"):
            build_ring(params, _fl("f32"),
                       rows=np.zeros((3, 17), np.float32))

    def test_missing_field_named(self):
        params, fl, spec, state = self._spec_fl("delta")
        host = ring_state_to_host(fl, jax.device_get(state))
        del host["idx"]
        with pytest.raises(ValueError, match="missing field 'idx'"):
            build_ring(params, fl, rows=host)

    def test_wrong_field_shape_names_codec(self):
        params, fl, spec, state = self._spec_fl("int8")
        host = ring_state_to_host(fl, jax.device_get(state))
        host["scale"] = host["scale"][:, :-1]
        with pytest.raises(ValueError) as e:
            build_ring(params, fl, rows=host)
        assert "'int8' ring field 'scale'" in str(e.value)

    def test_stamp_mismatch_between_compressed_codecs(self):
        params, fl, spec, state = self._spec_fl("delta")
        host = ring_state_to_host(fl, jax.device_get(state))
        with pytest.raises(ValueError) as e:
            build_ring(params, _fl("int8"), rows=host)
        assert "'delta'" in str(e.value)
        assert "ring_codec='int8'" in str(e.value)


class TestStaleResyncAndPopulation:
    """Stale-base resync + population K > N fallback, per codec."""

    def test_resync_configuration_actually_resyncs(self):
        """Guard for the parametrized test below: with the tight ring the
        tau stream differs from a loose-ring run, i.e. clients really
        fell out of the ring and resynced to tau 0."""
        tight = _run(_fl("f32", num_clients=8, buffer_size=2,
                         max_staleness=2), 10)
        loose = _run(_fl("f32", num_clients=8, buffer_size=2,
                         max_staleness=12), 10)
        assert [l["tau"] for l in tight.round_log] != \
               [l["tau"] for l in loose.round_log]
        assert max(t for l in tight.round_log for t in l["tau"]) <= 2

    @pytest.mark.parametrize("codec", ("int8", "delta"))
    def test_resync_parity_per_codec(self, codec):
        """Ring-overflow resyncs (tau -> 0 re-pull) under a compressed
        codec: same event stream, same taus, weights within tolerance."""
        mk = lambda c: _fl(c, num_clients=8, buffer_size=2,  # noqa: E731
                           max_staleness=2)
        ref = _run(mk("f32"), 10)
        got = _run(mk(codec), 10)
        assert [l["clients"] for l in ref.round_log] == \
               [l["clients"] for l in got.round_log]
        assert [l["tau"] for l in ref.round_log] == \
               [l["tau"] for l in got.round_log]
        for a, b in zip(ref.round_log, got.round_log):
            np.testing.assert_allclose(a["weights"], b["weights"],
                                       rtol=0.05, atol=5e-3)

    @pytest.mark.parametrize("codec", CODECS)
    def test_population_k_exceeds_n_per_codec(self, codec):
        """K > N forces the exact while_loop window fallback; the codec
        rides the same ring interface inside the population scan."""
        sc = get_scenario("paper-fig1")
        fl = _fl(codec, num_clients=3, buffer_size=5, max_staleness=6)
        res = run_population(_quad_loss, {"w": jnp.zeros(4)},
                             _quad_clients(n=3), fl, total_rounds=6,
                             eval_fn=_eval, eval_every=2, scenario=sc,
                             seed=1)
        assert res.server_rounds == 6
        assert all(len(l["clients"]) == 5 for l in res.round_log)
        assert np.isfinite(res.history[-1]["wnorm"])

    def test_population_codec_parity(self):
        """run_population int8 vs f32: exact window streams, weights and
        eval within codec tolerance (the engine-side parity, population
        flavor)."""
        sc = get_scenario("dropout-bernoulli")
        runs = {}
        for codec in ("f32", "int8"):
            runs[codec] = run_population(
                _quad_loss, {"w": jnp.zeros(4)}, _quad_clients(),
                _fl(codec), total_rounds=8, eval_fn=_eval, eval_every=2,
                scenario=sc, seed=3)
        ref, got = runs["f32"], runs["int8"]
        assert [l["clients"] for l in ref.round_log] == \
               [l["clients"] for l in got.round_log]
        assert [l["tau"] for l in ref.round_log] == \
               [l["tau"] for l in got.round_log]
        for a, b in zip(ref.history, got.history):
            np.testing.assert_allclose(a["wnorm"], b["wnorm"], rtol=0.05)


class TestRegistrySmoke:
    """Every registry arch flattens through the spec (abstractly — no
    parameter allocation) and quotes finite ring bytes per codec."""

    def _abstract_params(self, arch_id):
        from repro.configs.registry import get_arch
        if arch_id == "lenet":
            # vision family: built by models/lenet, not build_model
            from repro.models.lenet import init_lenet
            return jax.eval_shape(lambda: init_lenet(jax.random.PRNGKey(0)))
        from repro.models.model import build_model
        model = build_model(get_arch(arch_id).model)
        return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))

    def _arch_ids(self):
        from repro.configs import registry
        return sorted(registry._MODULES)

    def test_all_archs_flatten_and_quote_bytes(self):
        fl32, fl8, fld = _fl("f32"), _fl("int8"), _fl("delta")
        rows = []
        for aid in self._arch_ids():
            shapes = self._abstract_params(aid)
            spec = make_flat_spec(shapes, 0)
            n_params = spec.n
            assert n_params > 0
            quotes = {c.ring_codec: ring_device_bytes(c, spec)
                      for c in (fl32, fl8, fld)}
            assert all(q > 0 for q in quotes.values())
            # per-ring-ROW bytes: depth-normalized f32 vs int8
            depth = fl32.max_staleness + 1
            assert quotes["f32"] / quotes["int8"] >= 3.0
            rows.append((aid, n_params, quotes["f32"] // depth,
                         quotes["int8"] // depth))
        # the large-model headliners the refactor unlocks must be present
        ids = [r[0] for r in rows]
        assert "gemma-7b" in ids and "qwen1.5-110b" in ids
        big = dict((r[0], r[1]) for r in rows)
        assert big["gemma-7b"] > 5e9
        assert big["qwen1.5-110b"] > 1e11

    def test_sharded_spec_for_largest_arch(self):
        """The 110B arch's ring quote under 8-way model sharding fits the
        per-device math (dense terms split 8 ways)."""
        shapes = self._abstract_params("qwen1.5-110b")
        spec = make_flat_spec(shapes, 0)
        fl = _fl("int8")
        whole = ring_device_bytes(fl, spec, model_shards=1)
        split = ring_device_bytes(fl, spec, model_shards=8)
        assert abs(split - whole / 8) / whole < 0.01

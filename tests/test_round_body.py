"""The shared round body (core/round_body.py) and the mesh-sharded round
substrate (DESIGN.md §5): engine==cohort agreement through the single
implementation, ShardedFlatSpec padding, and multi-device parity of the
sharded pass against the single-device path (in-process when the session
has >= 8 devices — the CI multi-device job — else via a subprocess with
8 forced host devices)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.client import make_local_update_fn
from repro.core.cohort import (
    init_cohort_state,
    init_dist_state,
    make_cohort_step,
    make_dist_step,
)
from repro.core.round_body import make_ring_round, make_round_body
from repro.core.server_pass import (
    FlatSpec,
    ShardedFlatSpec,
    apply_server_round,
    flatten_stacked,
    flatten_tree,
    make_flat_spec,
    unflatten_like,
    unflatten_stacked,
)
from repro.core.weighting import POLICIES

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _quad_loss(params, batch):
    x, y = batch
    return jnp.mean((x @ params["w"] - y) ** 2), {}


def _quad_batch(key, n=8, d=4):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (n, d))
    y = x @ jnp.arange(1.0, d + 1.0) + 0.01 * jax.random.normal(k2, (n,))
    return x, y


def _round_inputs(k=3, steps=2, seed=0):
    key = jax.random.PRNGKey(seed)
    params = {"w": jnp.array([1.0, -1.0, 0.5, 2.0])}
    local = jax.tree.map(
        lambda *xs: jnp.stack(xs).reshape(k, steps, -1, *xs[0].shape[1:])
        if xs[0].ndim > 1 else jnp.stack(xs).reshape(k, steps, -1),
        *[_quad_batch(jax.random.fold_in(key, i), n=steps * 4)
          for i in range(k)])
    probe = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_quad_batch(jax.random.fold_in(key, 100 + i)) for i in range(k)])
    sizes = jnp.linspace(10.0, 30.0, k)
    taus = jnp.arange(k, dtype=jnp.float32)
    return params, local, probe, sizes, taus


FL = FLConfig(buffer_size=3, local_steps=2, local_lr=0.05, weighting="paper")


class TestSharedRoundBody:
    """engine path == cohort path through the ONE round implementation."""

    def test_engine_and_cohort_paths_agree(self):
        """With fresh slots (client_params == pulled base) the cohort path
        must reproduce the engine path: identical new_params and info."""
        params, local, probe, sizes, taus = _round_inputs()
        body = make_round_body(_quad_loss, FL)
        bases = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (3,) + x.shape),
                             params)
        new_e, end_e, info_e = body(params, bases, local, probe, sizes, taus)
        new_c, end_c, info_c = body(params, bases, local, probe, sizes, taus,
                                    client_params=bases,
                                    arrival_mask=jnp.ones(3))
        assert end_e is None and end_c is not None
        np.testing.assert_allclose(np.asarray(new_e["w"]),
                                   np.asarray(new_c["w"]),
                                   rtol=1e-5, atol=1e-6)
        assert set(info_e) == set(info_c)
        for k_ in info_e:
            np.testing.assert_allclose(np.asarray(info_e[k_]),
                                       np.asarray(info_c[k_]),
                                       rtol=1e-5, atol=1e-6, err_msg=k_)

    def test_cohort_step_matches_ring_round(self):
        """The shared-round fixture: one make_cohort_step round (all slots
        arrive, fresh bases) == one engine ring round on the same inputs —
        same new global params AND same info/round-log quantities."""
        params, local, probe, sizes, taus = _round_inputs()
        k = 3

        # engine side: depth-1 FLAT ring holding x^t, everyone pulls slot 0
        ring_round = make_ring_round(_quad_loss, FL)
        spec = make_flat_spec(params, FL.server_pass_block_n)
        ring = flatten_tree(spec, params)[None] * 1
        new_p, new_ring, info = ring_round(
            params, ring, jnp.zeros(k, jnp.int32), local, probe, sizes,
            jnp.zeros(k, jnp.float32), jnp.int32(0))

        # cohort side: same batches through the compiled cohort state machine
        step = make_cohort_step(_quad_loss, FL)
        state = init_cohort_state(params, k)
        batch = {"local": local, "probe": probe, "arrival": jnp.ones(k),
                 "data_sizes": sizes}
        new_state, mets = step(state, batch)

        np.testing.assert_allclose(np.asarray(new_p["w"]),
                                   np.asarray(new_state.global_params["w"]),
                                   rtol=1e-5, atol=1e-6)
        # the flat ring write holds the same new params (row 0 = new x')
        np.testing.assert_allclose(np.asarray(new_ring[0][:spec.n]),
                                   np.asarray(new_p["w"]), rtol=1e-6)
        np.testing.assert_allclose(float(jnp.mean(info["fresh_loss"])),
                                   float(mets["fresh_loss_mean"]), rtol=1e-5)
        np.testing.assert_allclose(float(jnp.min(info["staleness"])),
                                   float(mets["staleness_min"]), rtol=1e-5)
        np.testing.assert_allclose(float(jnp.max(info["weights"])),
                                   float(mets["weights_max"]), rtol=1e-5)

    def test_flat_ring_write_is_dtype_faithful(self):
        """Non-f32 params: the ring row must hold exactly the values
        clients receive, so a fresh (tau=0) client's eq. 3 distance is
        exactly 0 (the write re-flattens the dtype-cast tree)."""
        params = {"w": jnp.array([1.0, -1.0, 0.5, 2.0], jnp.bfloat16)}
        _, local, probe, sizes, _ = _round_inputs()
        ring_round = make_ring_round(_quad_loss, FL)
        spec = make_flat_spec(params, FL.server_pass_block_n)
        ring = flatten_tree(spec, params)[None] * 1
        zeros = jnp.zeros(3, jnp.float32)
        p1, ring, _ = ring_round(params, ring, jnp.zeros(3, jnp.int32),
                                 local, probe, sizes, zeros, jnp.int32(0))
        assert jax.tree.leaves(p1)[0].dtype == jnp.bfloat16
        _, _, info = ring_round(p1, ring, jnp.zeros(3, jnp.int32), local,
                                probe, sizes, zeros, jnp.int32(0))
        np.testing.assert_array_equal(np.asarray(info["sq_dists"]), 0.0)

    def test_non_dividing_k_warns_and_falls_back(self):
        """K not divisible by the data axis degrades to the plain vmap —
        but loudly, naming K and the shard count."""
        params, local, probe, sizes, taus = _round_inputs()  # K = 3
        bases = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (3,) + x.shape), params)
        body = make_round_body(_quad_loss, FL, mesh=_FakeMesh(data=2, model=1))
        with pytest.warns(RuntimeWarning, match="do not divide the data"):
            got, _, _ = body(params, bases, local, probe, sizes, taus)
        ref, _, _ = make_round_body(_quad_loss, FL)(
            params, bases, local, probe, sizes, taus)
        np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(ref["w"]),
                                   rtol=1e-6)

    def test_straggler_semantics_preserved(self):
        """The refactored cohort still carries straggler progress (the
        behaviour its old in-module round implemented)."""
        fl = FLConfig(buffer_size=1, local_steps=1, local_lr=0.1,
                      weighting="paper")
        params = {"w": jnp.zeros(4)}
        state = init_cohort_state(params, 2)
        step = jax.jit(make_cohort_step(_quad_loss, fl))
        key = jax.random.PRNGKey(0)
        batch = {
            "local": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (2, 1) + x.shape),
                _quad_batch(key)),
            "probe": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (2,) + x.shape),
                _quad_batch(jax.random.fold_in(key, 9))),
            "arrival": jnp.array([1.0, 0.0]),
            "data_sizes": jnp.ones(2),
        }
        s1, _ = step(state, batch)
        w_stale = np.asarray(jax.tree.leaves(s1.client_params)[0][1])
        w_base = np.asarray(jax.tree.leaves(s1.client_base)[0][1])
        assert not np.allclose(w_stale, w_base)  # progress carried


class TestStreamingRoundBody:
    """The streaming (distributed-client) entry shape vs the exact
    flat-vector path on identical inputs. Before the fix, the dist step
    carried its own weighting (``paper``: v = p * d, NO ``s_min`` cap;
    ``normalize`` ignored), so these fail on the pre-fix code."""

    @pytest.mark.parametrize("policy", list(POLICIES))
    @pytest.mark.parametrize("normalize", ["mean", "none"])
    def test_dist_step_matches_exact_path(self, policy, normalize):
        """K sequential dist steps (staleness 0..max against a seeded
        update-norm ring) == one exact ``apply_server_round`` fed bases
        whose eq. 3 distances equal the ring distances. The fill holds a
        fresh (tau=0) upload, so the streaming form's pinned reference
        equals the buffer min and parity is exact, cap included."""
        k = 4
        fl = FLConfig(buffer_size=k, local_steps=1, local_lr=0.1,
                      weighting=policy, normalize=normalize, global_lr=1.0,
                      max_staleness=k)
        params = {"w": jnp.array([1.0, -1.0, 0.5, 2.0])}
        norm_ring = jnp.array([0.3, 0.2, 0.1, 0.05])
        state = init_dist_state(params, fl)._replace(
            update_norm_ring=norm_ring)
        step = jax.jit(make_dist_step(_quad_loss, fl))
        local_update = make_local_update_fn(_quad_loss, fl.local_steps,
                                            fl.local_lr, fl.local_momentum)
        taus = [0, 1, 2, 3]
        sizes = [10.0, 20.0, 30.0, 40.0]
        key = jax.random.PRNGKey(0)
        deltas, losses = [], []
        for i in range(k):
            b = _quad_batch(jax.random.fold_in(key, i))
            pb = _quad_batch(jax.random.fold_in(key, 100 + i))
            stacked = jax.tree.map(lambda x: x[None], b)
            batch = {"local": stacked, "probe": pb,
                     "tau": jnp.int32(taus[i]),
                     "data_size": jnp.float32(sizes[i])}
            deltas.append(local_update(params, stacked)[0])
            losses.append(_quad_loss(params, pb)[0])
            state, mets = step(state, batch)
        assert int(mets["applied"]) == 1
        assert int(mets["buffered"]) == k  # pre-apply fill count

        # exact path: bases crafted so ||x - b_i||^2 == the ring distance
        dists = np.array([float(jnp.sum(norm_ring[:t])) for t in taus])
        spec = make_flat_spec(params, fl.server_pass_block_n)
        x = flatten_tree(spec, params)
        onehot = jnp.eye(spec.n_padded)[:k]
        bases = x[None] - jnp.sqrt(jnp.asarray(dists, jnp.float32))[:, None] \
            * onehot
        deltas_flat = flatten_stacked(
            spec, jax.tree.map(lambda *xs: jnp.stack(xs), *deltas))
        new_x, info = apply_server_round(
            x, bases, deltas_flat, jnp.asarray(losses, jnp.float32),
            jnp.asarray(sizes, jnp.float32),
            jnp.asarray(taus, jnp.float32), fl,
            mode="reference", block_n=spec.block_n)
        expect = unflatten_like(spec, new_x, params)
        np.testing.assert_allclose(np.asarray(state.global_params["w"]),
                                   np.asarray(expect["w"]),
                                   rtol=2e-5, atol=1e-6)

    def test_s_min_caps_stale_weight(self):
        """The bugfix itself: a hugely stale upload's streaming weight is
        bounded by P / s_min — it can no longer dominate unboundedly."""
        fl = FLConfig(buffer_size=2, local_steps=1, local_lr=0.1,
                      weighting="paper", max_staleness=4)
        params = {"w": jnp.array([1.0, -1.0, 0.5, 2.0])}
        state = init_dist_state(params, fl)._replace(
            update_norm_ring=jnp.array([1e6, 0.0, 0.0, 0.0]))
        step = jax.jit(make_dist_step(_quad_loss, fl))
        key = jax.random.PRNGKey(1)
        b = _quad_batch(key)
        vs = []
        for tau in (0, 1):  # same data, same probe: only staleness differs
            batch = {"local": jax.tree.map(lambda x: x[None], b),
                     "probe": _quad_batch(jax.random.fold_in(key, 9)),
                     "tau": jnp.int32(tau), "data_size": jnp.float32(10.0)}
            state, mets = step(state, batch)
            vs.append(float(mets["v_weight"]))
        assert vs[1] / vs[0] <= 1.0 / fl.s_min * 1.01  # capped at P/s_min
        assert vs[1] > vs[0]  # the paper's literal read still up-weights

    def test_unknown_normalize_raises_at_build(self):
        """The streaming path must reject bad normalize strings exactly
        like contribution_weights does on the exact paths — not silently
        fall through to 'none' semantics."""
        with pytest.raises(ValueError, match="normalize"):
            make_dist_step(_quad_loss, FLConfig(normalize="typo"))

    def test_flat_ring_roundtrip(self):
        """unflatten_stacked inverts flatten_stacked on the ring layout."""
        tree = {"a": jnp.arange(7.0), "b": jnp.ones((3, 5), jnp.bfloat16)}
        spec = make_flat_spec(tree)
        stacked = jax.tree.map(
            lambda x: jnp.stack([x, 2 * x.astype(x.dtype)]), tree)
        back = unflatten_stacked(spec, flatten_stacked(spec, stacked), tree)
        for a, b in zip(jax.tree.leaves(stacked), jax.tree.leaves(back)):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_allclose(np.asarray(a, jnp.float32),
                                       np.asarray(b, jnp.float32))


class _FakeMesh:
    """Duck-typed mesh (axis names/sizes only) for spec-layout tests."""

    def __init__(self, data=2, model=4):
        self.axis_names = ("data", "model")
        self.devices = np.empty((data, model))


class TestShardedFlatSpec:
    def test_padding_is_per_shard_whole_tiles(self):
        tree = {"a": jnp.arange(7.0), "b": jnp.ones((3, 5))}
        for model in (2, 4, 8):
            spec = make_flat_spec(tree, mesh=_FakeMesh(model=model))
            assert isinstance(spec, ShardedFlatSpec)
            assert spec.model_shards == model
            assert spec.n_padded % (spec.block_n * model) == 0
            assert spec.n == 22

    def test_model_axis_of_one_falls_back_to_flat_spec(self):
        spec = make_flat_spec({"a": jnp.arange(7.0)},
                              mesh=_FakeMesh(data=8, model=1))
        assert isinstance(spec, FlatSpec)
        assert not isinstance(spec, ShardedFlatSpec)

    def test_roundtrip_with_extra_padding(self):
        tree = {"a": jnp.arange(7.0),
                "b": {"c": jnp.ones((3, 5), jnp.bfloat16)}}
        spec = make_flat_spec(tree, mesh=_FakeMesh(model=8))
        vec = flatten_tree(spec, tree)
        assert vec.shape == (spec.n_padded,)
        back = unflatten_like(spec, vec, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_allclose(np.asarray(a, jnp.float32),
                                       np.asarray(b, jnp.float32))


TOL = {"new_x": 1e-5, "sq_dists": 1e-3, "weights": 1e-5,
       "global": 1e-5, "client_params": 1e-5, "metrics": 1e-5,
       "history_wnorm": 1e-5,
       # population engine: window metadata is EXACT under sharding
       "win_meta": 0.0, "win_t": 1e-5,
       "pop_weights": 1e-5, "pop_wnorm": 1e-5,
       # sharded ring vs replicated ring: same program, BIT-identical
       "ring_weights_bits": 0.0, "ring_history_bits": 0.0,
       "ring_bytes_err": 0.0}


def _assert_report(report):
    assert report["devices"] >= 8
    for check, errs in report.items():
        if not isinstance(errs, dict):
            continue
        for key, err in errs.items():
            if key in TOL:
                assert err <= TOL[key], (check, key, err)
    assert report["engine"]["num_launches"] >= 1


class TestMultiDeviceParity:
    """Sharded pass == single-device pass, 8 forced host devices."""

    def test_sharded_matches_single_device(self):
        if len(jax.devices()) >= 8:
            # already multi-device (CI multi-device job): run in-process
            from _shard_worker import run_all
            _assert_report(run_all())
            return
        env = dict(os.environ)
        env.update({
            "JAX_PLATFORMS": "cpu",
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "PYTHONPATH": os.pathsep.join(
                [os.path.join(ROOT, "src"),
                 env.get("PYTHONPATH", "")]).rstrip(os.pathsep),
        })
        proc = subprocess.run(
            [sys.executable, os.path.join(ROOT, "tests", "_shard_worker.py")],
            capture_output=True, text=True, env=env, timeout=900)
        assert proc.returncode == 0, proc.stderr[-4000:]
        report = json.loads(proc.stdout.strip().splitlines()[-1])
        _assert_report(report)

"""The shared round body (core/round_body.py) and the mesh-sharded round
substrate (DESIGN.md §5): engine==cohort agreement through the single
implementation, ShardedFlatSpec padding, and multi-device parity of the
sharded pass against the single-device path (in-process when the session
has >= 8 devices — the CI multi-device job — else via a subprocess with
8 forced host devices)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.cohort import init_cohort_state, make_cohort_step
from repro.core.round_body import make_ring_round, make_round_body
from repro.core.server_pass import (
    FlatSpec,
    ShardedFlatSpec,
    flatten_tree,
    make_flat_spec,
    unflatten_like,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _quad_loss(params, batch):
    x, y = batch
    return jnp.mean((x @ params["w"] - y) ** 2), {}


def _quad_batch(key, n=8, d=4):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (n, d))
    y = x @ jnp.arange(1.0, d + 1.0) + 0.01 * jax.random.normal(k2, (n,))
    return x, y


def _round_inputs(k=3, steps=2, seed=0):
    key = jax.random.PRNGKey(seed)
    params = {"w": jnp.array([1.0, -1.0, 0.5, 2.0])}
    local = jax.tree.map(
        lambda *xs: jnp.stack(xs).reshape(k, steps, -1, *xs[0].shape[1:])
        if xs[0].ndim > 1 else jnp.stack(xs).reshape(k, steps, -1),
        *[_quad_batch(jax.random.fold_in(key, i), n=steps * 4)
          for i in range(k)])
    probe = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_quad_batch(jax.random.fold_in(key, 100 + i)) for i in range(k)])
    sizes = jnp.linspace(10.0, 30.0, k)
    taus = jnp.arange(k, dtype=jnp.float32)
    return params, local, probe, sizes, taus


FL = FLConfig(buffer_size=3, local_steps=2, local_lr=0.05, weighting="paper")


class TestSharedRoundBody:
    """engine path == cohort path through the ONE round implementation."""

    def test_engine_and_cohort_paths_agree(self):
        """With fresh slots (client_params == pulled base) the cohort path
        must reproduce the engine path: identical new_params and info."""
        params, local, probe, sizes, taus = _round_inputs()
        body = make_round_body(_quad_loss, FL)
        bases = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (3,) + x.shape),
                             params)
        new_e, end_e, info_e = body(params, bases, local, probe, sizes, taus)
        new_c, end_c, info_c = body(params, bases, local, probe, sizes, taus,
                                    client_params=bases,
                                    arrival_mask=jnp.ones(3))
        assert end_e is None and end_c is not None
        np.testing.assert_allclose(np.asarray(new_e["w"]),
                                   np.asarray(new_c["w"]),
                                   rtol=1e-5, atol=1e-6)
        assert set(info_e) == set(info_c)
        for k_ in info_e:
            np.testing.assert_allclose(np.asarray(info_e[k_]),
                                       np.asarray(info_c[k_]),
                                       rtol=1e-5, atol=1e-6, err_msg=k_)

    def test_cohort_step_matches_ring_round(self):
        """The shared-round fixture: one make_cohort_step round (all slots
        arrive, fresh bases) == one engine ring round on the same inputs —
        same new global params AND same info/round-log quantities."""
        params, local, probe, sizes, taus = _round_inputs()
        k = 3

        # engine side: depth-1 ring holding x^t, everyone pulls slot 0
        ring_round = make_ring_round(_quad_loss, FL)
        ring = jax.tree.map(lambda x: x[None] * 1, params)
        new_p, new_ring, info = ring_round(
            params, ring, jnp.zeros(k, jnp.int32), local, probe, sizes,
            jnp.zeros(k, jnp.float32), jnp.int32(0))

        # cohort side: same batches through the compiled cohort state machine
        step = make_cohort_step(_quad_loss, FL)
        state = init_cohort_state(params, k)
        batch = {"local": local, "probe": probe, "arrival": jnp.ones(k),
                 "data_sizes": sizes}
        new_state, mets = step(state, batch)

        np.testing.assert_allclose(np.asarray(new_p["w"]),
                                   np.asarray(new_state.global_params["w"]),
                                   rtol=1e-5, atol=1e-6)
        # the ring write holds the same new params
        np.testing.assert_allclose(np.asarray(new_ring["w"][0]),
                                   np.asarray(new_p["w"]), rtol=1e-6)
        np.testing.assert_allclose(float(jnp.mean(info["fresh_loss"])),
                                   float(mets["fresh_loss_mean"]), rtol=1e-5)
        np.testing.assert_allclose(float(jnp.min(info["staleness"])),
                                   float(mets["staleness_min"]), rtol=1e-5)
        np.testing.assert_allclose(float(jnp.max(info["weights"])),
                                   float(mets["weights_max"]), rtol=1e-5)

    def test_non_dividing_k_warns_and_falls_back(self):
        """K not divisible by the data axis degrades to the plain vmap —
        but loudly, naming K and the shard count."""
        params, local, probe, sizes, taus = _round_inputs()  # K = 3
        bases = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (3,) + x.shape), params)
        body = make_round_body(_quad_loss, FL, mesh=_FakeMesh(data=2, model=1))
        with pytest.warns(RuntimeWarning, match="do not divide the data"):
            got, _, _ = body(params, bases, local, probe, sizes, taus)
        ref, _, _ = make_round_body(_quad_loss, FL)(
            params, bases, local, probe, sizes, taus)
        np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(ref["w"]),
                                   rtol=1e-6)

    def test_straggler_semantics_preserved(self):
        """The refactored cohort still carries straggler progress (the
        behaviour its old in-module round implemented)."""
        fl = FLConfig(buffer_size=1, local_steps=1, local_lr=0.1,
                      weighting="paper")
        params = {"w": jnp.zeros(4)}
        state = init_cohort_state(params, 2)
        step = jax.jit(make_cohort_step(_quad_loss, fl))
        key = jax.random.PRNGKey(0)
        batch = {
            "local": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (2, 1) + x.shape),
                _quad_batch(key)),
            "probe": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (2,) + x.shape),
                _quad_batch(jax.random.fold_in(key, 9))),
            "arrival": jnp.array([1.0, 0.0]),
            "data_sizes": jnp.ones(2),
        }
        s1, _ = step(state, batch)
        w_stale = np.asarray(jax.tree.leaves(s1.client_params)[0][1])
        w_base = np.asarray(jax.tree.leaves(s1.client_base)[0][1])
        assert not np.allclose(w_stale, w_base)  # progress carried


class _FakeMesh:
    """Duck-typed mesh (axis names/sizes only) for spec-layout tests."""

    def __init__(self, data=2, model=4):
        self.axis_names = ("data", "model")
        self.devices = np.empty((data, model))


class TestShardedFlatSpec:
    def test_padding_is_per_shard_whole_tiles(self):
        tree = {"a": jnp.arange(7.0), "b": jnp.ones((3, 5))}
        for model in (2, 4, 8):
            spec = make_flat_spec(tree, mesh=_FakeMesh(model=model))
            assert isinstance(spec, ShardedFlatSpec)
            assert spec.model_shards == model
            assert spec.n_padded % (spec.block_n * model) == 0
            assert spec.n == 22

    def test_model_axis_of_one_falls_back_to_flat_spec(self):
        spec = make_flat_spec({"a": jnp.arange(7.0)},
                              mesh=_FakeMesh(data=8, model=1))
        assert isinstance(spec, FlatSpec)
        assert not isinstance(spec, ShardedFlatSpec)

    def test_roundtrip_with_extra_padding(self):
        tree = {"a": jnp.arange(7.0),
                "b": {"c": jnp.ones((3, 5), jnp.bfloat16)}}
        spec = make_flat_spec(tree, mesh=_FakeMesh(model=8))
        vec = flatten_tree(spec, tree)
        assert vec.shape == (spec.n_padded,)
        back = unflatten_like(spec, vec, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_allclose(np.asarray(a, jnp.float32),
                                       np.asarray(b, jnp.float32))


TOL = {"new_x": 1e-5, "sq_dists": 1e-3, "weights": 1e-5,
       "global": 1e-5, "client_params": 1e-5, "metrics": 1e-5,
       "history_wnorm": 1e-5}


def _assert_report(report):
    assert report["devices"] >= 8
    for check, errs in report.items():
        if not isinstance(errs, dict):
            continue
        for key, err in errs.items():
            if key in TOL:
                assert err <= TOL[key], (check, key, err)
    assert report["engine"]["num_launches"] >= 1


class TestMultiDeviceParity:
    """Sharded pass == single-device pass, 8 forced host devices."""

    def test_sharded_matches_single_device(self):
        if len(jax.devices()) >= 8:
            # already multi-device (CI multi-device job): run in-process
            from _shard_worker import run_all
            _assert_report(run_all())
            return
        env = dict(os.environ)
        env.update({
            "JAX_PLATFORMS": "cpu",
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "PYTHONPATH": os.pathsep.join(
                [os.path.join(ROOT, "src"),
                 env.get("PYTHONPATH", "")]).rstrip(os.pathsep),
        })
        proc = subprocess.run(
            [sys.executable, os.path.join(ROOT, "tests", "_shard_worker.py")],
            capture_output=True, text=True, env=env, timeout=900)
        assert proc.returncode == 0, proc.stderr[-4000:]
        report = json.loads(proc.stdout.strip().splitlines()[-1])
        _assert_report(report)

"""Emit the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline import MULTI, SINGLE, full_table  # noqa: E402

DRY = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

ARCHS = ["stablelm-12b", "arctic-480b", "hymba-1.5b", "qwen1.5-110b",
         "pixtral-12b", "gemma-7b", "deepseek-moe-16b", "qwen3-1.7b",
         "falcon-mamba-7b", "whisper-tiny"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_b(n):
    for u, s in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(n) >= s:
            return f"{n / s:.2f}{u}"
    return f"{n:.0f}B"


def dryrun_table():
    print("| arch | shape | mesh | status | compile_s | HLO flops/dev | "
          "HLO coll bytes | arg bytes/dev | temp bytes/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            for mesh in ("single", "multi"):
                f = os.path.join(DRY, f"{a}_{s}_{mesh}.json")
                if not os.path.exists(f):
                    print(f"| {a} | {s} | {mesh} | MISSING | | | | | |")
                    continue
                r = json.load(open(f))
                tag = "2x16x16" if mesh == "multi" else "16x16"
                if r.get("skipped"):
                    print(f"| {a} | {s} | {tag} | SKIP (by design) | | | | | |")
                    continue
                m = r.get("memory", {})
                print(f"| {a} | {s} | {tag} | OK | {r['compile_s']} | "
                      f"{r['flops']:.2e} | "
                      f"{fmt_b(r['collective_bytes'].get('total', 0))} | "
                      f"{fmt_b(m.get('argument_size_in_bytes', 0))} | "
                      f"{fmt_b(m.get('temp_size_in_bytes', 0))} |")


def roofline_table(mesh, tag):
    print(f"\n### Roofline — {tag}\n")
    print("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) |"
          " dominant | MODEL_FLOPS/HLO | what would move the dominant term |")
    print("|---|---|---|---|---|---|---|---|")
    hints = {
        ("compute",): "higher per-chip utilisation: fused attention kernel, "
                      "larger per-slot batch",
        ("memory",): "flash-attention kernel (no HBM score traffic) / "
                     "fp8 weights / larger arithmetic intensity",
        ("collective",): "reduce FSDP all-gather volume (cache params across "
                         "local steps), quantised deltas, wider TP",
    }
    for r in full_table(mesh):
        hint = hints[(r["dominant"],)]
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
              f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
              f"**{r['dominant']}** | {r['useful_ratio']:.2f} | {hint} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        dryrun_table()
    if which in ("all", "roofline"):
        roofline_table(SINGLE, "single pod (16x16, 256 chips)")
        roofline_table(MULTI, "multi-pod (2x16x16, 512 chips)")

"""Gate a serve_fl --trace-out artifact: schema + span coverage.

The CI serving smoke lane runs this after
``python -m repro.launch.serve_fl ... --trace-out serve_trace.json``:

* the file must be loadable Chrome-trace-event JSON (the object form
  with ``traceEvents``; every complete event carries name/ph/ts/pid/tid
  and a non-negative ``dur``) — ``obs.trace.validate_trace``;
* the union of the round-lifecycle spans (``collect_window`` + ``apply``
  by default) must cover at least ``--min-coverage`` of the measured
  round window — ``obs.trace.span_coverage`` — so the trace actually
  accounts for where round wall-time goes instead of sampling slivers.

Exits non-zero with a reason on any violation.

Usage:
    PYTHONPATH=src python scripts/validate_trace.py serve_trace.json \
        --min-coverage 0.95
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.trace import SPAN_NAMES, span_coverage, validate_trace


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome-trace JSON written by --trace-out")
    ap.add_argument("--min-coverage", type=float, default=0.95,
                    help="required fraction of the round window covered "
                         "by collect_window/apply spans")
    ap.add_argument("--min-events", type=int, default=1)
    args = ap.parse_args()

    with open(args.trace) as f:
        doc = json.load(f)
    n = validate_trace(doc)
    if n < args.min_events:
        print(f"FAIL: {args.trace}: {n} events < {args.min_events}")
        return 1
    unknown = {ev["name"] for ev in doc["traceEvents"]} - set(SPAN_NAMES)
    if unknown:
        print(f"FAIL: {args.trace}: span names outside the fixed taxonomy "
              f"(DESIGN.md §9): {sorted(unknown)}")
        return 1
    cov = span_coverage(doc)
    if cov < args.min_coverage:
        print(f"FAIL: {args.trace}: span coverage {cov:.4f} < "
              f"{args.min_coverage} — the trace does not account for the "
              "round wall-time")
        return 1
    print(f"ok: {args.trace}: {n} events, span coverage {cov:.4f} "
          f">= {args.min_coverage}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

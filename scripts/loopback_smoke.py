"""CI loopback smoke: real transport end to end, gated on parity.

Boots ``serve_fl`` with a REAL socket ingress (``--transport http`` by
default — the slowest, most header-sensitive path), points N separate
``client_fl`` PROCESSES at it via ``--port-file`` discovery, and gates
on the §12 acceptance criteria:

* **fold-journal parity** — the live concurrent run records every fold
  (client, draw seq, base version, payload sha) in fold order;
  ``serve_fl --replay-journal`` re-folds that stream in-process from
  the seeded datasets and must land on the byte-identical
  ``params_sha256`` (the deterministic twin of a racy live run);
* **trace validity** — the server's ``--trace-out`` artifact passes
  ``scripts/validate_trace.py`` (schema + >= --min-coverage of round
  wall-time accounted for by collect_window/apply spans), now with the
  transport decode/offer spans riding along.

Client processes that lose the shutdown race (the server exits once
``--rounds`` is reached; a client mid-pull gets a connection error) are
tolerated — the gate is the digest + the trace, not client exit codes.

Usage (the CI fast lane):
    PYTHONPATH=src python scripts/loopback_smoke.py
    PYTHONPATH=src python scripts/loopback_smoke.py --transport tcp
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env() -> dict:
    env = dict(os.environ)
    src = os.path.join(ROOT, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--transport", default="http",
                    choices=("tcp", "http"))
    ap.add_argument("--num-clients", type=int, default=4,
                    help="client PROCESSES to launch")
    ap.add_argument("--population", type=int, default=8,
                    help="scenario population (--clients on both sides)")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--buffer-k", type=int, default=2)
    ap.add_argument("--timeout", type=float, default=240.0,
                    help="per-process wait budget (jax import dominates)")
    ap.add_argument("--min-coverage", type=float, default=0.95)
    args = ap.parse_args()

    tmp = tempfile.mkdtemp(prefix="loopback_smoke_")
    port_file = os.path.join(tmp, "port")
    journal = os.path.join(tmp, "folds.jsonl")
    trace = os.path.join(tmp, "serve_trace.json")
    common = ["--clients", str(args.population), "--seed", "0"]
    try:
        srv_cmd = [sys.executable, "-m", "repro.launch.serve_fl",
                   "--transport", args.transport, "--port", "0",
                   "--port-file", port_file,
                   "--rounds", str(args.rounds),
                   "--buffer-k", str(args.buffer_k),
                   "--adapt-every", "0",  # journal parity needs fixed K
                   "--max-staleness", "100",
                   "--journal-out", journal, "--trace-out", trace,
                   "--max-wall-time", str(args.timeout / 2),
                   "--json", "--log-level", "info"] + common
        print(f"[smoke] server: {' '.join(srv_cmd)}")
        srv = subprocess.Popen(srv_cmd, cwd=ROOT, env=_env(),
                               stdout=subprocess.PIPE, text=True)

        clients = []
        for cid in range(args.num_clients):
            c_cmd = [sys.executable, "-m", "repro.launch.client_fl",
                     "--port-file", port_file,
                     "--transport", args.transport,
                     "--cid", str(cid), "--uploads", "16",
                     "--stop-at-version", str(args.rounds),
                     "--port-wait", str(args.timeout / 2),
                     "--log-level", "warning"] + common
            clients.append(subprocess.Popen(c_cmd, cwd=ROOT, env=_env(),
                                            stdout=subprocess.PIPE,
                                            text=True))
        for cid, c in enumerate(clients):
            try:
                out, _ = c.communicate(timeout=args.timeout)
            except subprocess.TimeoutExpired:
                c.kill()
                print(f"[smoke] FAIL: client {cid} hung")
                return 1
            # shutdown-race losers are fine; a hung client is not
            print(f"[smoke] client {cid} exit={c.returncode}: "
                  f"{out.strip().splitlines()[-1] if out.strip() else ''}")
        try:
            srv_out, _ = srv.communicate(timeout=args.timeout)
        except subprocess.TimeoutExpired:
            srv.kill()
            print("[smoke] FAIL: server never reached the round target")
            return 1
        if srv.returncode != 0:
            print(f"[smoke] FAIL: server exit={srv.returncode}")
            return 1
        live = json.loads(srv_out)
        print(f"[smoke] live: version={live['version']} "
              f"folded={live['folded']} sha={live['params_sha256'][:16]}")
        if live["version"] < args.rounds:
            print("[smoke] FAIL: wall-time bound hit before the round "
                  f"target ({live['version']} < {args.rounds})")
            return 1

        replay_cmd = [sys.executable, "-m", "repro.launch.serve_fl",
                      "--replay-journal", journal,
                      "--buffer-k", str(args.buffer_k),
                      "--max-staleness", "100",
                      "--json", "--log-level", "warning"] + common
        replay = json.loads(subprocess.run(
            replay_cmd, cwd=ROOT, env=_env(), capture_output=True,
            text=True, timeout=args.timeout, check=True).stdout)
        print(f"[smoke] replay: version={replay['version']} "
              f"folded={replay['replayed']} "
              f"sha={replay['params_sha256'][:16]}")
        if replay["params_sha256"] != live["params_sha256"]:
            print("[smoke] FAIL: journal replay digest != live digest — "
                  "the socket path and the in-process twin diverged")
            return 1
        print("[smoke] parity OK: replay digest == live digest")

        rc = subprocess.run(
            [sys.executable, os.path.join(ROOT, "scripts",
                                          "validate_trace.py"),
             trace, "--min-coverage", str(args.min_coverage)],
            cwd=ROOT, env=_env(), timeout=args.timeout).returncode
        if rc != 0:
            print("[smoke] FAIL: trace validation")
            return 1
        print("[smoke] PASS")
        return 0
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main())
